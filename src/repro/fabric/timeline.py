"""Circuit timeline: the one source of truth for schedule timing.

A ``ParallelSchedule`` says *what* each switch serves; this module says
*when*. ``build_timeline`` replays each switch's slot list as
(reconfigure δ → serve α) and emits one ``CircuitWindow`` per served
configuration — absolute ``[start, end)`` serve intervals with the δ
windows in between. Both consumers of circuit timing read it:

* ``repro.fabric.simulator.simulate`` — matrix-granularity replay
  (coverage / finish-time checks), and
* ``repro.flowsim`` — the flow-level discrete-event simulator
  (per-flow FCTs, buffers, VLB indirection).

Keeping the (δ → α) event construction here means the two can never
disagree about when a circuit is up: flowsim's finish time *is*
``Timeline.finish``, which is the makespan ``simulate`` asserts against.

Online replay: ``installed`` carries the configuration left on each
switch by the previous controller period. A switch whose *first* slot
equals its installed permutation serves it without paying δ (the circuit
is already up) — the online controller's reuse credit.

Float discipline: per-switch time accumulates in slot order exactly as
the pre-refactor ``simulate`` loop did (``t += δ; t += α``), so finish
times are bit-identical to the historical replay.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from ..core.schedule import ParallelSchedule

__all__ = ["CircuitWindow", "Timeline", "build_timeline"]


@dataclass(frozen=True)
class CircuitWindow:
    """One configuration's serve interval on one switch.

    ``alpha`` is the scheduled serve duration; ``end - start`` equals it
    up to float addition, but consumers accumulating served demand must
    use ``alpha`` (the schedule's own weight) so matrix replay stays
    bit-identical to summing the schedule directly.
    """

    switch: int        # OCS index h
    slot: int          # position in that switch's slot list
    perm: np.ndarray   # (n,) int destination port per source port
    alpha: float       # serve duration (demand-time units)
    start: float       # absolute serve start (after any δ)
    end: float         # start + alpha
    reused: bool       # first slot served δ-free via the installed config


@dataclass
class Timeline:
    """All serve windows of a schedule, switch-major in slot order."""

    windows: list[CircuitWindow]
    switch_finish: np.ndarray    # (s,) last serve end per switch
    reused_switches: np.ndarray  # (s,) bool — δ-free first slot
    delta: float
    s: int

    @property
    def finish(self) -> float:
        """Replay finish time: when the last switch goes quiet."""
        return float(self.switch_finish.max()) if self.s else 0.0

    def delta_time(self) -> np.ndarray:
        """Per-switch total reconfiguration time actually paid."""
        paid = np.zeros(self.s, dtype=np.float64)
        for w in self.windows:
            if not w.reused:
                paid[w.switch] += self.delta
        return paid


def build_timeline(
    sched,
    *,
    installed: Sequence[np.ndarray | None] | None = None,
    tol: float = 1e-9,
) -> Timeline:
    """Replay ``sched`` into absolute circuit serve windows.

    Accepts a ``ParallelSchedule`` or anything carrying one under
    ``.schedule`` (``repro.api.SolveReport``, ``SpectraResult``). Raises
    ``AssertionError`` on negative durations or non-permutation
    configurations — the same independent checks ``simulate`` has always
    made, now made once for every timing consumer.
    """
    sched = getattr(sched, "schedule", sched)
    if not isinstance(sched, ParallelSchedule):
        raise TypeError(f"cannot build a timeline for {type(sched).__name__}")
    if installed is not None and len(installed) != sched.s:
        raise ValueError(
            f"need one installed permutation (or None) per switch: "
            f"got {len(installed)} for s={sched.s}"
        )
    windows: list[CircuitWindow] = []
    switch_finish = np.zeros(sched.s, dtype=np.float64)
    reused = np.zeros(sched.s, dtype=bool)
    for h, sw in enumerate(sched.switches):
        t = 0.0
        carried = None if installed is None else installed[h]
        for j, (perm, a) in enumerate(zip(sw.perms, sw.alphas)):
            a = float(a)
            if a < -tol:
                raise AssertionError("negative duration in schedule")
            perm = np.asarray(perm, dtype=np.int64)
            # Independent port-conflict check: perm must be a permutation.
            if len(np.unique(perm)) != len(perm):
                raise AssertionError("configuration is not a permutation")
            slot_reused = (
                j == 0
                and carried is not None
                and np.array_equal(perm, np.asarray(carried, dtype=np.int64))
            )
            if slot_reused:
                reused[h] = True  # circuit already up: no reconfiguration
            else:
                t += sched.delta  # reconfiguration before each configuration
            start = t
            t += a
            windows.append(
                CircuitWindow(
                    switch=h, slot=j, perm=perm, alpha=a,
                    start=start, end=t, reused=slot_reused,
                )
            )
        switch_finish[h] = t
    return Timeline(
        windows=windows,
        switch_finish=switch_finish,
        reused_switches=reused,
        delta=sched.delta,
        s=sched.s,
    )
