"""Optical-circuit-switch fabric model (Fig. 1 topology).

`n` racks, each ToR connected to every one of `s` parallel OCSes; a central
controller periodically schedules the rack-level demand matrix D onto the
switches. Demand is normalized so one unit of demand takes one unit of time
on one switch link; ``OCSFabric.seconds()`` converts a makespan in those
units to wall-clock seconds given per-link bandwidth.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

from ..core.spectra import SpectraResult


@dataclass(frozen=True)
class OCSFabric:
    """A datacenter core of s parallel optical circuit switches."""

    num_switches: int  # s
    reconfig_delay_s: float  # δ, in seconds
    link_bandwidth_Bps: float = 400e9 / 8  # 400 Gb/s optical ports

    def normalize(self, demand_bytes: np.ndarray) -> tuple[np.ndarray, float]:
        """Demand in bytes → time units; returns (D, seconds-per-unit).

        All-zero demand has no peak to scale by: the contract is
        ``unit_s = 0.0`` with D returned as-is (all zeros), and every
        downstream consumer must treat ``unit_s == 0.0`` as "nothing to
        serve" — zero δ-in-units, zero CCT — rather than dividing by it.
        """
        demand_bytes = np.asarray(demand_bytes, dtype=np.float64)
        peak = float(demand_bytes.max(initial=0.0))
        if peak <= 0:
            return np.zeros_like(demand_bytes), 0.0
        unit_s = peak / self.link_bandwidth_Bps
        return demand_bytes / peak, unit_s

    def delta_units(self, unit_s: float) -> float:
        """δ expressed in normalized demand-time units."""
        if unit_s <= 0:
            return 0.0
        return self.reconfig_delay_s / unit_s

    def schedule_bytes(
        self,
        demand_bytes: np.ndarray,
        scheduler: str | Callable[..., SpectraResult] = "spectra",
        **kw,
    ) -> tuple[SpectraResult, float]:
        """Schedule a byte-demand matrix; returns (result, CCT seconds).

        ``scheduler`` is a ``repro.api`` registry solver name (preferred) or
        a legacy callable ``(D, s, delta, **kw) -> SpectraResult``-like. On
        the registry path, pass ``options=SolveOptions(...)`` — or legacy
        kwargs like ``validate=False`` / ``compute_lb=False``, which are
        mapped onto SolveOptions (anything else lands in ``extra``).

        All-zero demand (``normalize`` → ``unit_s = 0.0``) is well-defined:
        the solver sees the zero matrix with δ = 0 (no circuits needed, so
        no reconfigurations either) and returns an empty zero-makespan
        schedule; the CCT is exactly 0.0 seconds, never NaN/∞ from a δ/0
        conversion.
        """
        D, unit_s = self.normalize(demand_bytes)
        delta = self.delta_units(unit_s) if unit_s > 0.0 else 0.0
        if callable(scheduler):
            res = scheduler(D, self.num_switches, delta, **kw)
        else:
            from ..api import Problem, SolveOptions, solve

            options = kw.pop("options", None)
            if options is None:
                options = SolveOptions(
                    validate=kw.pop("validate", True),
                    compute_lb=kw.pop("compute_lb", True),
                    validate_tol=kw.pop("validate_tol", None),
                    extra=kw,
                )
            elif kw:
                raise TypeError(
                    f"pass either options= or legacy kwargs, not both: {sorted(kw)}"
                )
            res = solve(
                Problem(D, self.num_switches, delta),
                solver=scheduler,
                options=options,
            )
        return res, (res.makespan * unit_s if unit_s > 0.0 else 0.0)
