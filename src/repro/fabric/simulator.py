"""Event-level simulator validating ParallelSchedule timing and service.

Replays each switch's schedule as (reconfigure δ → serve α at line rate)
events and checks that (a) every demand entry is fully served by the
schedule's claimed makespan, and (b) at no instant does any switch serve
more than one circuit per input/output port (guaranteed by permutations but
re-checked independently here).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.schedule import ParallelSchedule


@dataclass
class SimReport:
    finish_time: float
    served: np.ndarray
    demand_met: bool
    max_shortfall: float


def simulate(sched, D: np.ndarray, tol: float = 1e-9) -> SimReport:
    """Accepts a ParallelSchedule, or anything carrying one under
    ``.schedule`` (``repro.api.SolveReport``, ``SpectraResult``)."""
    sched = getattr(sched, "schedule", sched)
    if not isinstance(sched, ParallelSchedule):
        raise TypeError(f"cannot simulate {type(sched).__name__}")
    D = np.asarray(D, dtype=np.float64)
    n = D.shape[0]
    rows = np.arange(n)
    served = np.zeros_like(D)
    finish = 0.0
    for sw in sched.switches:
        t = 0.0
        for perm, a in zip(sw.perms, sw.alphas):
            if a < -tol:
                raise AssertionError("negative duration in schedule")
            # Independent port-conflict check: perm must be a permutation.
            if len(np.unique(perm)) != n:
                raise AssertionError("configuration is not a permutation")
            t += sched.delta  # reconfiguration before each configuration
            served[rows, perm] += a
            t += a
        finish = max(finish, t)
    shortfall = float((D - served).max())
    if abs(finish - sched.makespan()) > 1e-6 * max(1.0, finish):
        raise AssertionError(
            f"simulated finish {finish} != claimed makespan {sched.makespan()}"
        )
    return SimReport(
        finish_time=finish,
        served=served,
        demand_met=shortfall <= tol,
        max_shortfall=max(shortfall, 0.0),
    )
