"""Event-level simulator validating ParallelSchedule timing and service.

Replays each switch's schedule as (reconfigure δ → serve α at line rate)
events and checks that (a) every demand entry is fully served by the
schedule's claimed makespan, and (b) at no instant does any switch serve
more than one circuit per input/output port (guaranteed by permutations but
re-checked independently here).

The (δ → α) event construction itself lives in ``repro.fabric.timeline``
— the one source of truth for circuit timing, shared with the flow-level
simulator in ``repro.flowsim`` — so matrix replay here and flow replay
there can never disagree about when a circuit is up.

Online replay: ``installed`` carries the configurations left on the
switches by the previous controller period. A switch whose *first*
configuration equals its installed permutation serves it without paying δ —
the circuit is already up — which is exactly the online controller's reuse
credit. The finish-time check then validates against the credit-aware
makespan instead of the schedule's nominal one.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from ..core.schedule import ParallelSchedule
from .timeline import build_timeline


@dataclass
class SimReport:
    """Matrix-granularity replay verdict.

    ``reused_switches`` is always a well-defined per-switch bool array of
    shape ``(s,)``: which switches served their first configuration δ-free
    against a carried ``installed`` state. A stateless replay (no
    ``installed``) has nothing to reuse, so the contract is **all-False**
    — never ``None`` — letting consumers sum or index it unconditionally.
    """

    finish_time: float
    served: np.ndarray
    demand_met: bool
    max_shortfall: float
    reused_switches: np.ndarray = None  # (s,) bool; zeros for stateless replay


def simulate(
    sched,
    D: np.ndarray,
    tol: float = 1e-9,
    *,
    installed: Sequence[np.ndarray | None] | None = None,
    expected_makespan: float | None = None,
) -> SimReport:
    """Accepts a ParallelSchedule, or anything carrying one under
    ``.schedule`` (``repro.api.SolveReport``, ``SpectraResult``).

    ``installed`` enables online replay (see module doc): one permutation —
    or None — per switch. ``expected_makespan`` overrides the finish-time
    assertion target (the online controller's credit-aware makespan);
    without it the target is the schedule's nominal makespan minus the
    replay's observed reuse credit.
    """
    sched = getattr(sched, "schedule", sched)
    if not isinstance(sched, ParallelSchedule):
        raise TypeError(f"cannot simulate {type(sched).__name__}")
    D = np.asarray(D, dtype=np.float64)
    n = D.shape[0]
    rows = np.arange(n)
    tl = build_timeline(sched, installed=installed, tol=tol)
    served = np.zeros_like(D)
    for w in tl.windows:
        if len(w.perm) != n:
            raise AssertionError("configuration is not a permutation")
        served[rows, w.perm] += w.alpha
    finish = tl.finish
    shortfall = float((D - served).max())
    if expected_makespan is None:
        expected_makespan = sched.makespan()
        if installed is not None:
            loads = sched.loads() - sched.delta * tl.reused_switches
            expected_makespan = float(loads.max()) if len(loads) else 0.0
    if abs(finish - expected_makespan) > 1e-6 * max(1.0, finish):
        raise AssertionError(
            f"simulated finish {finish} != claimed makespan {expected_makespan}"
        )
    return SimReport(
        finish_time=finish,
        served=served,
        demand_met=shortfall <= tol,
        max_shortfall=max(shortfall, 0.0),
        reused_switches=tl.reused_switches,
    )
