"""Event-level simulator validating ParallelSchedule timing and service.

Replays each switch's schedule as (reconfigure δ → serve α at line rate)
events and checks that (a) every demand entry is fully served by the
schedule's claimed makespan, and (b) at no instant does any switch serve
more than one circuit per input/output port (guaranteed by permutations but
re-checked independently here).

Online replay: ``installed`` carries the configurations left on the
switches by the previous controller period. A switch whose *first*
configuration equals its installed permutation serves it without paying δ —
the circuit is already up — which is exactly the online controller's reuse
credit. The finish-time check then validates against the credit-aware
makespan instead of the schedule's nominal one.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from ..core.schedule import ParallelSchedule


@dataclass
class SimReport:
    finish_time: float
    served: np.ndarray
    demand_met: bool
    max_shortfall: float
    reused_switches: np.ndarray | None = None  # per-switch δ-free first config


def simulate(
    sched,
    D: np.ndarray,
    tol: float = 1e-9,
    *,
    installed: Sequence[np.ndarray | None] | None = None,
    expected_makespan: float | None = None,
) -> SimReport:
    """Accepts a ParallelSchedule, or anything carrying one under
    ``.schedule`` (``repro.api.SolveReport``, ``SpectraResult``).

    ``installed`` enables online replay (see module doc): one permutation —
    or None — per switch. ``expected_makespan`` overrides the finish-time
    assertion target (the online controller's credit-aware makespan);
    without it the target is the schedule's nominal makespan minus the
    replay's observed reuse credit.
    """
    sched = getattr(sched, "schedule", sched)
    if not isinstance(sched, ParallelSchedule):
        raise TypeError(f"cannot simulate {type(sched).__name__}")
    D = np.asarray(D, dtype=np.float64)
    n = D.shape[0]
    rows = np.arange(n)
    if installed is not None and len(installed) != sched.s:
        raise ValueError(
            f"need one installed permutation (or None) per switch: "
            f"got {len(installed)} for s={sched.s}"
        )
    served = np.zeros_like(D)
    finish = 0.0
    reused = np.zeros(sched.s, dtype=bool)
    for h, sw in enumerate(sched.switches):
        t = 0.0
        carried = None if installed is None else installed[h]
        for j, (perm, a) in enumerate(zip(sw.perms, sw.alphas)):
            if a < -tol:
                raise AssertionError("negative duration in schedule")
            # Independent port-conflict check: perm must be a permutation.
            if len(np.unique(perm)) != n:
                raise AssertionError("configuration is not a permutation")
            if (
                j == 0
                and carried is not None
                and np.array_equal(
                    np.asarray(perm, dtype=np.int64),
                    np.asarray(carried, dtype=np.int64),
                )
            ):
                reused[h] = True  # circuit already up: no reconfiguration
            else:
                t += sched.delta  # reconfiguration before each configuration
            served[rows, perm] += a
            t += a
        finish = max(finish, t)
    shortfall = float((D - served).max())
    if expected_makespan is None:
        expected_makespan = sched.makespan()
        if installed is not None:
            loads = sched.loads() - sched.delta * reused
            expected_makespan = float(loads.max()) if len(loads) else 0.0
    if abs(finish - expected_makespan) > 1e-6 * max(1.0, finish):
        raise AssertionError(
            f"simulated finish {finish} != claimed makespan {expected_makespan}"
        )
    return SimReport(
        finish_time=finish,
        served=served,
        demand_met=shortfall <= tol,
        max_shortfall=max(shortfall, 0.0),
        reused_switches=reused if installed is not None else None,
    )
