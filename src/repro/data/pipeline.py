"""Deterministic sharded synthetic token pipeline.

Production-shaped data path without external data: a counter-based PRNG
token stream that is (a) fully deterministic given (seed, step) — so a
restart reproduces the exact same batches, which the fault-tolerance tests
rely on — (b) shardable by host (each host materializes only its slice),
and (c) stateless: the "iterator state" checkpointed with the model is
just the step counter.

Structured sequences (Zipf-ish marginals + short-range repetition) so the
cross-entropy actually decreases during the example runs.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def _batch_tokens(cfg: DataConfig, step: int) -> np.ndarray:
    """(host_batch, seq_len) int32, deterministic in (seed, step, host)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id])
    )
    B, S, V = cfg.host_batch, cfg.seq_len, cfg.vocab_size
    # Zipf-like marginal over a smallish working set, then inject
    # copy-structure: each sequence repeats a short motif with noise.
    working = min(V, 4096)
    base = rng.zipf(1.3, size=(B, S)).astype(np.int64)
    tokens = (base - 1) % working
    motif_len = 16
    motif = tokens[:, :motif_len]
    reps = S // motif_len
    motifed = np.tile(motif, (1, reps))[:, :S]
    mask = rng.random((B, S)) < 0.7
    tokens = np.where(mask, motifed, tokens)
    return tokens.astype(np.int32)


class TokenStream:
    """Stateless-resumable iterator: next_batch(step) → {"tokens": ...}."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def next_batch(self, step: int) -> dict:
        return {"tokens": jnp.asarray(_batch_tokens(self.cfg, step))}

    def state(self, step: int) -> dict:
        return {"step": step, "seed": self.cfg.seed}


def make_stream(vocab_size: int, seq_len: int, global_batch: int,
                seed: int = 0) -> TokenStream:
    return TokenStream(DataConfig(vocab_size, seq_len, global_batch, seed))
