"""SPECTRA core: the paper's contribution (DECOMPOSE / SCHEDULE / EQUALIZE).

Preferred entry point — the unified solver API (re-exported here)::

    from repro.core import Problem, solve
    report = solve(Problem(D, s, delta), solver="spectra")

Stage-level names:
    spectra, spectra_pp        — full pipelines (paper-faithful / improved)
    decompose, Decomposition   — Alg. 1 + REFINE (Alg. 2)
    schedule_lpt, equalize     — Alg. 3, Alg. 4
    lower_bound                — §IV Theorems 1-2 + Property 2
    baseline_less, eclipse_decompose — §V comparison algorithms

The direct pipeline entry points (``spectra``/``spectra_pp``/…) remain the
underlying implementations and keep working; new code should address
algorithms by registry name through ``solve``/``solve_many``.
"""

from .baselines import baseline_less, eclipse_decompose, less_split
from .decompose import Decomposition, decompose, degree, refine_greedy, refine_lp, refine_signed
from .equalize import equalize
from .lower_bounds import lb_theorem1, lb_theorem2, lower_bound
from .matching import (
    hungarian_min_cost,
    max_weight_perfect_matching,
    mwm_node_coverage,
    perm_matrix,
)
from .improved import local_search, schedule_wrap, spectra_pp
from .schedule import ParallelSchedule, SwitchSchedule, schedule_lpt
from .schedule_ir import (
    DeviceSchedule,
    LazySchedule,
    ir_coverage,
    ir_loads,
    ir_makespan,
    ir_num_configs,
    ir_to_schedule,
    schedule_to_ir,
)
from .spectra import SpectraResult, spectra

# Unified solver API re-exports, resolved lazily to avoid the import cycle
# (repro.api's stage tables import the implementations defined above).
_API_NAMES = (
    "Pipeline", "Problem", "SolveOptions", "SolveReport", "get_solver",
    "list_solvers", "register_solver", "register_stage", "solve",
    "solve_all", "solve_many",
)

__all__ = [
    "Decomposition", "DeviceSchedule", "LazySchedule", "ParallelSchedule",
    "SpectraResult", "SwitchSchedule",
    "baseline_less", "decompose", "degree", "eclipse_decompose", "equalize",
    "hungarian_min_cost", "ir_coverage", "ir_loads", "ir_makespan",
    "ir_num_configs", "ir_to_schedule", "lb_theorem1", "lb_theorem2",
    "less_split", "local_search", "lower_bound",
    "max_weight_perfect_matching", "mwm_node_coverage", "perm_matrix",
    "refine_greedy", "refine_lp", "refine_signed", "schedule_lpt",
    "schedule_to_ir", "schedule_wrap", "spectra", "spectra_pp",
    *_API_NAMES,
]


def __getattr__(name: str):
    if name in _API_NAMES:
        from .. import api

        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
