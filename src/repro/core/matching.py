"""Maximum-weight perfect matching with node-coverage constraints.

This is the inner solver of SPECTRA's DECOMPOSE step (Alg. 1, line 4).

Given the remaining demand ``D_rem`` (weights) and the remaining *uncovered*
support ``S_rem``, we must return a permutation that

  (a) matches every *critical* row/column (a line with ``degree(S_rem)``
      uncovered entries) through one of its uncovered support entries —
      this guarantees the degree of ``S_rem`` drops by one per round, and
  (b) among all such permutations, maximizes the served demand
      ``sum_a D_rem[a, perm[a]]``.

Both are achieved with a single unconstrained max-weight perfect matching by
*weight augmentation*: every uncovered support entry incident to a critical
row or column receives a bonus ``M > sum(D_rem)`` per critical endpoint.  A
perfect matching covering all critical nodes through support edges always
exists (any color class of a König edge coloring covers every maximum-degree
node), and because ``M`` lexicographically dominates the demand weights, the
MWM attains the maximum possible bonus — i.e. covers all critical nodes —
before optimizing served demand.

The assignment itself is solved with the Jonker–Volgenant algorithm: scipy's
``linear_sum_assignment`` (Crouse's JV variant — the same implementation the
paper cites [22][23]) with a pure-numpy O(n^3) Hungarian fallback that is
cross-checked in the tests.
"""

from __future__ import annotations

import numpy as np

try:  # scipy is available in this environment; keep a fallback regardless.
    from scipy.optimize import linear_sum_assignment as _scipy_lsa
except Exception:  # pragma: no cover - exercised only without scipy
    _scipy_lsa = None


def hungarian_min_cost(cost: np.ndarray) -> np.ndarray:
    """Pure-numpy O(n^3) Hungarian algorithm (potentials + shortest paths).

    Returns ``perm`` with ``perm[i] = j`` minimizing ``sum_i cost[i, perm[i]]``
    over permutations. Classic "e-maxx" formulation, vectorized over columns.
    """
    cost = np.asarray(cost, dtype=np.float64)
    n = cost.shape[0]
    if cost.shape != (n, n):
        raise ValueError(f"cost must be square, got {cost.shape}")
    INF = np.inf
    u = np.zeros(n + 1)
    v = np.zeros(n + 1)
    # p[j] = row matched to column j (rows/cols 1..n; column 0 is virtual).
    p = np.zeros(n + 1, dtype=np.int64)
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = np.full(n + 1, INF)
        way = np.zeros(n + 1, dtype=np.int64)
        used = np.zeros(n + 1, dtype=bool)
        while True:
            used[j0] = True
            i0 = p[j0]
            # Relax all unused columns from column j0.
            cols = ~used[1:]
            cur = cost[i0 - 1, :] - u[i0] - v[1:]
            better = cols & (cur < minv[1:])
            minv[1:] = np.where(better, cur, minv[1:])
            way[1:] = np.where(better, j0, way[1:])
            masked = np.where(used[1:], INF, minv[1:])
            j1 = int(np.argmin(masked)) + 1
            delta = masked[j1 - 1]
            # Update potentials.
            used_cols = used.copy()
            rows_of_used = p[used_cols]
            u[rows_of_used] += delta
            v[used_cols] -= delta
            minv[1:] = np.where(used[1:], minv[1:], minv[1:] - delta)
            j0 = j1
            if p[j0] == 0:
                break
        # Augment along the alternating path.
        while j0 != 0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
    perm = np.empty(n, dtype=np.int64)
    perm[p[1:] - 1] = np.arange(n)
    return perm


def max_weight_perfect_matching(weights: np.ndarray, *, use_scipy: bool | None = None) -> np.ndarray:
    """Permutation ``perm`` maximizing ``sum_i weights[i, perm[i]]``."""
    weights = np.asarray(weights, dtype=np.float64)
    if use_scipy is None:
        use_scipy = _scipy_lsa is not None
    if use_scipy and _scipy_lsa is not None:
        rows, cols = _scipy_lsa(weights, maximize=True)
        perm = np.empty(weights.shape[0], dtype=np.int64)
        perm[rows] = cols
        return perm
    return hungarian_min_cost(-weights)


def critical_lines(S_rem: np.ndarray) -> tuple[np.ndarray, np.ndarray, int]:
    """Critical rows/cols of a 0/1 support matrix and its degree ``k``."""
    S_rem = np.asarray(S_rem)
    row_deg = S_rem.sum(axis=1)
    col_deg = S_rem.sum(axis=0)
    k = int(max(row_deg.max(initial=0), col_deg.max(initial=0)))
    return row_deg == k, col_deg == k, k


def mwm_node_coverage(
    D_rem: np.ndarray,
    S_rem: np.ndarray,
    *,
    use_scipy: bool | None = None,
    validate: bool = True,
) -> np.ndarray:
    """MWM under node-coverage constraints (Alg. 1 line 4).

    Returns a permutation covering every critical line of ``S_rem`` through an
    uncovered support entry, maximizing total ``D_rem`` weight among those.
    """
    D_rem = np.asarray(D_rem, dtype=np.float64)
    S = np.asarray(S_rem).astype(bool)
    n = D_rem.shape[0]
    crit_r, crit_c, k = critical_lines(S)
    if k == 0:
        raise ValueError("S_rem is empty; nothing to cover")
    base = np.maximum(D_rem, 0.0)
    M = float(base.sum()) + 1.0
    bonus = (crit_r[:, None].astype(np.float64) + crit_c[None, :]) * M
    W = base + np.where(S, bonus, 0.0)
    perm = max_weight_perfect_matching(W, use_scipy=use_scipy)
    if validate:
        rows = np.arange(n)
        on_support = S[rows, perm]
        if not np.all(on_support[crit_r]):
            raise AssertionError("critical row left uncovered by support edge")
        covered_cols = np.zeros(n, dtype=bool)
        covered_cols[perm[on_support]] = True
        if not np.all(covered_cols[crit_c]):
            raise AssertionError("critical column left uncovered by support edge")
    return perm


def perm_matrix(perm: np.ndarray) -> np.ndarray:
    """Dense 0/1 permutation matrix from ``perm[i] = j``."""
    n = len(perm)
    P = np.zeros((n, n), dtype=np.float64)
    P[np.arange(n), perm] = 1.0
    return P
