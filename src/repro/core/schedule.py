"""SCHEDULE (Alg. 3): LPT assignment of weighted permutations to s switches.

Classic Longest-Processing-Time-first for makespan minimization on identical
parallel machines, with a per-job setup cost ``δ`` (one reconfiguration per
permutation placed on a switch).
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

from .decompose import Decomposition


@dataclass
class SwitchSchedule:
    """One OCS's schedule: a sequence of (permutation, weight) pairs."""

    perms: list[np.ndarray] = field(default_factory=list)
    alphas: list[float] = field(default_factory=list)

    def load(self, delta: float) -> float:
        return float(sum(self.alphas) + delta * len(self.alphas))

    def longest(self) -> int:
        """Index of the longest-duration permutation (-1 if empty)."""
        if not self.alphas:
            return -1
        return int(np.argmax(self.alphas))


@dataclass
class ParallelSchedule:
    """Schedules for s parallel switches plus the reconfiguration delay."""

    switches: list[SwitchSchedule]
    delta: float

    @property
    def s(self) -> int:
        return len(self.switches)

    def loads(self) -> np.ndarray:
        return np.array([sw.load(self.delta) for sw in self.switches])

    def makespan(self) -> float:
        return float(self.loads().max()) if self.switches else 0.0

    def num_configs(self) -> int:
        return sum(len(sw.perms) for sw in self.switches)

    def coverage(self, n: int) -> np.ndarray:
        out = np.zeros((n, n), dtype=np.float64)
        rows = np.arange(n)
        for sw in self.switches:
            for perm, a in zip(sw.perms, sw.alphas):
                out[rows, perm] += a
        return out

    def validate(self, D: np.ndarray, tol: float = 1e-9) -> None:
        """Assert the schedules cover D (Eq. 3) with nonnegative weights."""
        D = np.asarray(D)
        for sw in self.switches:
            for a in sw.alphas:
                if a < -tol:
                    raise AssertionError(f"negative weight {a}")
        cov = self.coverage(D.shape[0])
        gap = float((D - cov).max())
        if gap > tol:
            raise AssertionError(f"schedule does not cover D: max gap {gap}")


def schedule_lpt(dec: Decomposition, s: int, delta: float) -> ParallelSchedule:
    """Alg. 3: sort by non-increasing weight, greedily place on least-loaded."""
    if s < 1:
        raise ValueError("need at least one switch")
    order = np.argsort(-np.asarray(dec.alphas), kind="stable")
    switches = [SwitchSchedule() for _ in range(s)]
    # (load, switch index) min-heap — ties broken by lowest index, as in Alg.3.
    heap = [(0.0, h) for h in range(s)]
    heapq.heapify(heap)
    for i in order:
        load, h = heapq.heappop(heap)
        switches[h].perms.append(dec.perms[i])
        switches[h].alphas.append(float(dec.alphas[i]))
        heapq.heappush(heap, (load + delta + float(dec.alphas[i]), h))
    return ParallelSchedule(switches=switches, delta=delta)
