"""Lower bounds on the parallel-OCS scheduling makespan (§IV).

* Theorem 1 (any line i with k_i nonzeros, weight w_i):
    LB1_i = (w_i + δ·max(k_i, s)) / s
* Theorem 2 (line i with exactly k_i = s nonzeros x_1 ≥ … ≥ x_s):
    LB2_i = δ + min( x_1,
                     max(x_2, (w_i + δ)/s, x_s + δ),
                     min_{2 ≤ m ≤ s²} max(x_{m+1}, (w_i + m·δ)/s) )
  with x_j := 0 for j > s (only s nonzeros exist).
* Property 2: the max over all 2n lines (and all bound families) is itself a
  lower bound for D.
"""

from __future__ import annotations

import numpy as np


def optimality_gap(makespan: float, lower_bound: float) -> float:
    """makespan / lower_bound; 1.0 for the degenerate 0/0 (empty demand)."""
    if lower_bound <= 0:
        return 1.0 if makespan <= 0 else float("inf")
    return makespan / lower_bound


def lb_theorem1(w: float, k: int, s: int, delta: float) -> float:
    return (w + delta * max(k, s)) / s


def lb_theorem2(x: np.ndarray, s: int, delta: float) -> float:
    """Theorem 2 for one line whose nonzeros are ``x`` (requires len(x)==s)."""
    x = np.sort(np.asarray(x, dtype=np.float64))[::-1]
    if len(x) != s:
        raise ValueError("Theorem 2 requires exactly s nonzero elements")
    w = float(x.sum())
    # x_{j} with 1-based j, zero-padded beyond s. Need up to j = s²+1.
    pad = np.zeros(s * s + 2)
    pad[: len(x)] = x
    xj = lambda j: float(pad[j - 1]) if j >= 1 else 0.0  # noqa: E731
    opt0 = xj(1)
    opt1 = max(xj(2), (w + delta) / s, xj(s) + delta)
    opts_m = [
        max(xj(m + 1), (w + m * delta) / s)
        for m in range(2, s * s + 1)
    ]
    inner = min([opt0, opt1] + (opts_m if opts_m else []))
    return delta + inner


def lower_bound(D: np.ndarray, s: int, delta: float) -> float:
    """Property 2: max over all rows/columns of all applicable bounds."""
    D = np.asarray(D, dtype=np.float64)
    n = D.shape[0]
    best = 0.0
    for axis in (1, 0):  # rows then columns
        for i in range(n):
            line = D[i, :] if axis == 1 else D[:, i]
            nz = line[line > 0]
            k_i = len(nz)
            if k_i == 0:
                continue
            w_i = float(nz.sum())
            best = max(best, lb_theorem1(w_i, k_i, s, delta))
            if k_i == s:
                best = max(best, lb_theorem2(nz, s, delta))
    return best
