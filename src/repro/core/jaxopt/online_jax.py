"""Online (cross-period) SPECTRA on device: stateful steps and a rolling scan.

The stateless pipeline re-pays the reconfiguration delay δ for every
configuration every controller period. AI training traffic is heavily
periodic, so consecutive periods reuse most permutations — an online
controller that remembers each switch's *installed* permutation can serve a
matching configuration first with **zero** δ (reuse credit) and warm-start
the next period's decomposition from the previous one.

State carried across periods (``OnlineDeviceState``):

    installed   (s, n) int32   permutation left installed on each switch at
                               the end of the previous period (-1 row: never
                               configured)
    prev_perms  (n, n) int32   previous period's decomposition permutations
                               (warm-start seed), live rounds packed first
    prev_k      ()     int32   number of live previous rounds
    prices      (n,)   float32 matcher dual-price carry (see ``matching``)
    fresh_ratio ()     float32 tightest fresh-decomposition weight ratio
                               observed — the warm-quality gate reference
    cache_*     (C,…)          device-resident support-pattern cache (the
                               host controller's ``support_cache`` in the
                               scan carry): memoized supports, perm sets,
                               live counts, quality references, and a
                               round-robin eviction cursor; C=0 disables

The warm tiers mirror the host controller: the previous period's set is
tried first (adjacency), then — only if that fails — the support-pattern
cache is probed with this period's exact support, serving phase-cycling
traffic (e.g. MoE routing phases) without re-decomposing.

Per-period algorithm (``online_step_jax``):

1. **Warm-start decomposition** — re-REFINE the previous period's
   permutation set against the new demand (one greedy pass, no matching
   solves). If it covers the new support AND passes the quality gate (round
   count ≤ degree(D); scale-free weight ratio within ``warm_slack`` of the
   tightest fresh decomposition observed — coverage alone doesn't bound
   quality when weights drift), the expensive auction DECOMPOSE is skipped
   entirely (``lax.cond``); otherwise a fresh device decomposition runs
   (optionally warm-starting the auction's dual prices from the carry).
2. **Reuse-then-LPT** — each switch greedily claims a round whose
   permutation equals its installed configuration (serving it first, δ-free),
   then the remaining rounds are placed by plain LPT.
3. **Credit-aware EQUALIZE** — Alg. 4 over the slot table with a −δ load
   offset on every switch holding a carried configuration.
4. **Best-of selection** — the stateless candidate (plain LPT + uncredited
   EQUALIZE of the *same* decomposition) is always computed too; applying
   the reuse credit to it post-hoc is free, so the chosen schedule's
   effective makespan is ≤ the *same-decomposition* stateless makespan by
   construction. (``run_scenario`` additionally clamps every period
   against the independently solved TRUE stateless baseline on the host —
   see ``repro.scenarios.runner``.)
5. **State update** — each switch's new installed permutation is the last
   configuration it serves (slot-index order, reused config first, EQUALIZE
   splits last).

``spectra_online_scan`` rolls the step over a whole (T, n, n) trace under
``lax.scan`` with the switch state as carry: an entire training run's
scheduling is ONE device dispatch, no host round-trips between periods.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..schedule_ir import DeviceSchedule
from .decompose_jax import (
    JaxDecomposition,
    _decompose,
    lpt_schedule_jax,
)
from .equalize_jax import device_loads, equalize_ir
from .lower_bounds_jax import lower_bound_jax


class OnlineDeviceState(NamedTuple):
    """Cross-period carry of the online controller (see module doc)."""

    installed: jax.Array   # (s, n) int32; -1 row = unconfigured switch
    prev_perms: jax.Array  # (n, n) int32; previous decomposition, packed
    prev_k: jax.Array      # () int32; live previous rounds
    prices: jax.Array      # (n,) float32; matcher dual-price carry
    fresh_ratio: jax.Array  # () float32; last FRESH dec's Σα / max-line-sum
                            # — the warm-acceptance quality reference
    # Device-resident support-pattern cache (the host controller's
    # ``SwitchState.support_cache`` moved into the scan carry). Capacity C
    # is a *shape* — it travels with the state, so jitted steps need no
    # extra static argument; C=0 disables the cache with zero-size arrays.
    cache_supports: jax.Array  # (C, n, n) bool — memoized support patterns
    cache_perms: jax.Array     # (C, n, n) int32 — each pattern's perm set
    cache_k: jax.Array         # (C,) int32 — live rounds per entry (0=empty)
    cache_ratio: jax.Array     # (C,) float32 — quality reference at insert
    cache_ptr: jax.Array       # () int32 — round-robin eviction cursor


class OnlineStepResult(NamedTuple):
    """One period's device-resident online outcome."""

    schedule: DeviceSchedule       # chosen slot table (credit-aware)
    reused: jax.Array              # (R,) bool — slots served δ-free
    makespan: jax.Array            # () float32 — credit-aware makespan
    stateless_makespan: jax.Array  # () float32 — same-dec uncredited makespan
    reuse_count: jax.Array         # () int32 — switches with a carried config
    warm: jax.Array                # () bool — warm-start decomposition used
    lb: jax.Array                  # () float32 — §IV (stateless) lower bound
    k: jax.Array                   # () int32 — decomposition rounds
    converged: jax.Array           # () bool — matcher convergence
    eq_exhausted: jax.Array        # () bool — EQUALIZE headroom exhausted
    cache_hit: jax.Array           # () bool — warm came from the support cache


def online_initial_state(
    n: int, s: int, cache_size: int = 0
) -> OnlineDeviceState:
    """Fresh controller state: no configurations installed anywhere.

    ``cache_size`` sizes the device-resident support-pattern cache carried
    with the state (0 = disabled — the pre-cache state shape, and the
    default for raw steps; the serving layer and ``run_scenario`` opt in)."""
    identity = jnp.arange(n, dtype=jnp.int32)[None, :]
    return OnlineDeviceState(
        installed=jnp.full((s, n), -1, jnp.int32),
        prev_perms=jnp.broadcast_to(identity, (n, n)),
        prev_k=jnp.int32(0),
        prices=jnp.zeros((n,), jnp.float32),
        # +inf = "no fresh reference yet"; harmless because warm-start
        # cannot trigger before the first (necessarily fresh) period.
        fresh_ratio=jnp.float32(jnp.inf),
        cache_supports=jnp.zeros((cache_size, n, n), bool),
        cache_perms=jnp.broadcast_to(identity[None], (cache_size, n, n)),
        cache_k=jnp.zeros((cache_size,), jnp.int32),
        cache_ratio=jnp.full((cache_size,), jnp.inf, jnp.float32),
        cache_ptr=jnp.int32(0),
    )


def _warm_refine(D: jax.Array, perms: jax.Array, k: jax.Array):
    """Greedy REFINE of ``D`` along a *given* permutation set (weights from
    zero). Returns ``(alphas, residual)`` — residual is the demand no
    permutation in the set can serve."""
    n = D.shape[0]
    arange = jnp.arange(n)

    def body(r, carry):
        R, alphas = carry
        perm = perms[r]
        d = jnp.maximum(R[arange, perm].max(), 0.0)
        d = jnp.where(r < k, d, 0.0)
        alphas = alphas.at[r].set(d)
        R = jnp.maximum(R.at[arange, perm].add(-d), 0.0)
        return R, alphas

    R, alphas = jax.lax.fori_loop(
        0, n, body, (D, jnp.zeros((n,), jnp.float32))
    )
    return alphas, R


def _switch_credit(
    perms: jax.Array,
    switch: jax.Array,
    installed: jax.Array,
    s: int,
):
    """Per-switch reuse marks on a slot table.

    Returns ``(reused (R,) bool, has (s,) bool)``: at most one live slot per
    switch (the first, by slot index) whose permutation equals that switch's
    installed configuration — the slot the switch can serve δ-free.
    """
    R = switch.shape[0]
    live = switch >= 0
    inst_valid = installed[:, 0] >= 0
    arange = jnp.arange(R)
    reused = jnp.zeros((R,), bool)
    has = []
    for h in range(s):
        m = (
            live
            & (switch == h)
            & inst_valid[h]
            & (perms == installed[h][None, :]).all(axis=-1)
        )
        hit = m.any()
        reused = reused | (hit & (arange == jnp.argmax(m)))
        has.append(hit)
    return reused, jnp.stack(has)


def _reuse_then_lpt(
    dec: JaxDecomposition,
    installed: jax.Array,
    s: int,
    delta: jax.Array,
):
    """Reuse-aware Alg. 3: each switch first claims a round matching its
    installed permutation (no δ), the rest is plain LPT on the credited
    loads. Returns ``(assignment (n,), reused_rounds (n,) bool)``."""
    n = dec.alphas.shape[0]
    arange = jnp.arange(n)
    valid = (arange < dec.k) & (dec.alphas > 0)
    inst_valid = installed[:, 0] >= 0

    taken = jnp.zeros((n,), bool)
    assignment = jnp.full((n,), -1, jnp.int32)
    loads = jnp.zeros((s,), jnp.float32)
    for h in range(s):
        m = (
            valid
            & ~taken
            & inst_valid[h]
            & (dec.perms == installed[h][None, :]).all(axis=-1)
        )
        hit = m.any()
        r = jnp.argmax(m)
        sel = hit & (arange == r)
        taken = taken | sel
        assignment = jnp.where(sel, h, assignment)
        loads = loads.at[h].add(jnp.where(hit, dec.alphas[r], 0.0))
    reused_rounds = taken

    remaining = valid & ~taken
    order = jnp.argsort(jnp.where(remaining, -dec.alphas, jnp.inf))

    def place(loads, idx):
        a = dec.alphas[idx]
        is_real = jnp.take(remaining, idx)
        h = jnp.argmin(loads)
        loads = jnp.where(is_real, loads.at[h].add(delta + a), loads)
        return loads, jnp.where(is_real, h, -1)

    loads, placed = jax.lax.scan(place, loads, order)
    assignment = jnp.where(
        remaining,
        jnp.full((n,), -1, jnp.int32).at[order].set(placed.astype(jnp.int32)),
        assignment,
    )
    return assignment, reused_rounds


def _build_table(dec, assignment, delta, extra_slots: int) -> DeviceSchedule:
    n = dec.perms.shape[-1]
    pad_perms = jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32)[None, :], (extra_slots, n)
    )
    return DeviceSchedule(
        perms=jnp.concatenate([dec.perms, pad_perms], axis=0),
        alphas=jnp.concatenate(
            [dec.alphas, jnp.zeros((extra_slots,), jnp.float32)]
        ),
        switch=jnp.concatenate(
            [assignment, jnp.full((extra_slots,), -1, jnp.int32)]
        ),
        delta=delta,
    )


def _credited_makespan(ds: DeviceSchedule, installed, s: int, delta):
    """(makespan, reused marks, per-switch credit flags) of a final table."""
    reused, has = _switch_credit(ds.perms, ds.switch, installed, s)
    loads = device_loads(ds.alphas, ds.switch, delta, s) - delta * has
    return loads.max(), reused, has


def _last_served(ds: DeviceSchedule, reused, installed, s: int) -> jax.Array:
    """New installed state: the last configuration each switch serves.

    Serve order is slot-index order with the reused slot moved first, so
    the last non-reused live slot (EQUALIZE splits sit at the highest
    indices) is what remains installed; a switch serving only its carried
    configuration — or nothing — keeps its previous state.
    """
    R = ds.switch.shape[0]
    live = ds.switch >= 0
    idx = jnp.arange(R)
    rows = []
    for h in range(s):
        nr = live & (ds.switch == h) & ~reused
        last = jnp.max(jnp.where(nr, idx, -1))
        rows.append(
            jnp.where(nr.any(), ds.perms[jnp.maximum(last, 0)], installed[h])
        )
    return jnp.stack(rows)


def _online_step(
    state: OnlineDeviceState,
    D: jax.Array,
    s: int,
    delta,
    *,
    use_kernel: bool,
    do_equalize: bool,
    merge_aware: bool,
    extra_slots: int,
    matcher: str,
    repair_rounds: int,
    warm_start: bool,
    warm_prices: bool,
    warm_slack: float,
) -> tuple[OnlineStepResult, OnlineDeviceState]:
    D = jnp.asarray(D, jnp.float32)
    n = D.shape[0]
    delta = jnp.asarray(delta, jnp.float32)
    line_sum = jnp.maximum(D.sum(axis=0).max(), D.sum(axis=1).max())
    line_sum_safe = jnp.maximum(line_sum, 1e-30)

    # ---- 1. decomposition: warm (re-REFINE previous set) or fresh --------
    def fresh(op):
        D_, prices_ = op
        dec_, prices_out = _decompose(
            D_,
            use_kernel=use_kernel,
            matcher=matcher,
            repair_rounds=repair_rounds,
            carry_prices=warm_prices,
            prices0=prices_ if warm_prices else None,
        )
        return dec_, prices_out if warm_prices else prices_

    S = D > 0
    deg = jnp.maximum(S.sum(axis=0).max(), S.sum(axis=1).max())
    cache_size = state.cache_supports.shape[0]

    def try_warm(perms, k, ref_ratio):
        """Re-REFINE ``D`` along a candidate permutation set; returns the
        packed decomposition plus its coverage/quality acceptance.

        Quality gate: re-REFINE along a stale permutation set can badly
        over-provision when weights drift (coverage alone doesn't bound
        it). Σα / max-line-sum is scale-free and ≥ 1 for any cover, so
        comparing against the reference FRESH decomposition's ratio bounds
        the warm excess to ``warm_slack``; the round count may not exceed
        degree(D) (a fresh decomposition's exact k) either.
        """
        alphas_w, residual = _warm_refine(D, perms, k)
        covered = residual.max() <= 1e-5 * jnp.maximum(D.max(), 1e-30)
        live = alphas_w > 0
        order = jnp.argsort(~live, stable=True)
        dec_ = JaxDecomposition(
            perms=perms[order],
            alphas=jnp.where(live, alphas_w, 0.0)[order],
            k=live.sum().astype(jnp.int32),
            converged=jnp.bool_(True),
        )
        warm_ratio = alphas_w.sum() / line_sum_safe
        quality_ok = (
            (dec_.k <= deg) & (warm_ratio <= ref_ratio * (1.0 + warm_slack))
        )
        return dec_, covered & quality_ok

    if warm_start:
        warm_dec, adj_ok = try_warm(
            state.prev_perms, state.prev_k, state.fresh_ratio
        )
        use_adj = adj_ok & (state.prev_k > 0)
        if cache_size:
            # Support-pattern cache tier: consulted only when the adjacency
            # warm start fails — the exact lookup order of the host
            # controller. An entry matches when its memoized support equals
            # this period's (and is live); its perm set then re-REFINEs
            # under the same coverage/quality gates, referenced against the
            # quality ratio memoized at insert time.
            match = (
                (state.cache_supports == S[None]).all(axis=(1, 2))
                & (state.cache_k > 0)
            )
            hit = match.any()
            slot = jnp.argmax(match)
            cache_dec, cache_ok = try_warm(
                state.cache_perms[slot],
                jnp.where(hit, state.cache_k[slot], 0),
                state.cache_ratio[slot],
            )
            use_cache = ~use_adj & hit & cache_ok
        else:
            cache_dec, use_cache = warm_dec, jnp.bool_(False)
        use_warm = use_adj | use_cache
        warm_pick = jax.tree_util.tree_map(
            lambda a, c: jnp.where(use_adj, a, c), warm_dec, cache_dec
        )
        dec, prices = jax.lax.cond(
            use_warm,
            lambda op: (warm_pick, op[1]),
            fresh,
            (D, state.prices),
        )
    else:
        use_warm = use_cache = jnp.bool_(False)
        dec, prices = fresh((D, state.prices))

    # ---- 2+3. two candidates over the same decomposition -----------------
    # A: plain LPT + uncredited EQUALIZE — the stateless reference.
    assignment_a, _, _ = lpt_schedule_jax(dec, s, delta)
    ds_a = _build_table(dec, assignment_a, delta, extra_slots)
    # B: reuse-then-LPT + EQUALIZE on credited loads.
    assignment_b, reused_rounds = _reuse_then_lpt(dec, state.installed, s, delta)
    ds_b = _build_table(dec, assignment_b, delta, extra_slots)
    _, has_b = _switch_credit(
        ds_b.perms, ds_b.switch, state.installed, s
    )
    ex_a = ex_b = jnp.bool_(False)
    if do_equalize:
        ds_a, ex_a = equalize_ir(ds_a, s, merge_aware=merge_aware)
        ds_b, ex_b = equalize_ir(
            ds_b, s, merge_aware=merge_aware, load_offset=-delta * has_b
        )

    # ---- 4. best-of selection (credit applied to both final tables) ------
    stateless_mk = device_loads(ds_a.alphas, ds_a.switch, delta, s).max()
    mk_a, reused_a, has_a = _credited_makespan(ds_a, state.installed, s, delta)
    mk_b, reused_b, has_b_f = _credited_makespan(ds_b, state.installed, s, delta)
    use_b = mk_b <= mk_a
    ds = jax.tree_util.tree_map(
        lambda b, a: jnp.where(use_b, b, a), ds_b, ds_a
    )
    reused = jnp.where(use_b, reused_b, reused_a)
    makespan = jnp.minimum(mk_b, mk_a)
    reuse_count = jnp.where(use_b, has_b_f, has_a).sum().astype(jnp.int32)
    eq_exhausted = jnp.where(use_b, ex_b, ex_a)

    # ---- 5. state update --------------------------------------------------
    # The warm-quality reference ratchets only on FRESH periods, and only
    # DOWNWARD (running min): a warm period accepted at ref·(1+slack) must
    # never raise the bar, and the tightest fresh ratio ever observed is
    # the honest reference. Zero-demand periods (no line sum) leave it
    # untouched.
    new_fresh_ratio = jnp.where(
        use_warm | (line_sum <= 0),
        state.fresh_ratio,
        jnp.minimum(state.fresh_ratio, dec.alphas.sum() / line_sum_safe),
    )
    if cache_size:
        # Insert/update every period (the host controller's semantics):
        # a matching support slot is updated in place, otherwise the
        # round-robin cursor picks the eviction victim. Stored quality
        # reference is the post-ratchet fresh ratio, exactly what the host
        # memoizes alongside the perm set.
        ins_match = (state.cache_supports == S[None]).all(axis=(1, 2))
        ins_hit = ins_match.any()
        ins_slot = jnp.where(
            ins_hit, jnp.argmax(ins_match), state.cache_ptr % cache_size
        )
        cache_supports = state.cache_supports.at[ins_slot].set(S)
        cache_perms = state.cache_perms.at[ins_slot].set(dec.perms)
        cache_k = state.cache_k.at[ins_slot].set(dec.k)
        cache_ratio = state.cache_ratio.at[ins_slot].set(new_fresh_ratio)
        cache_ptr = jnp.where(ins_hit, state.cache_ptr, state.cache_ptr + 1)
    else:
        cache_supports = state.cache_supports
        cache_perms = state.cache_perms
        cache_k = state.cache_k
        cache_ratio = state.cache_ratio
        cache_ptr = state.cache_ptr
    new_state = OnlineDeviceState(
        installed=_last_served(ds, reused, state.installed, s),
        prev_perms=dec.perms,
        prev_k=dec.k,
        prices=prices,
        fresh_ratio=new_fresh_ratio,
        cache_supports=cache_supports,
        cache_perms=cache_perms,
        cache_k=cache_k,
        cache_ratio=cache_ratio,
        cache_ptr=cache_ptr,
    )
    result = OnlineStepResult(
        schedule=ds,
        reused=reused,
        makespan=makespan,
        stateless_makespan=stateless_mk,
        reuse_count=reuse_count,
        warm=use_warm,
        lb=lower_bound_jax(D, s, delta),
        k=dec.k,
        converged=dec.converged,
        eq_exhausted=eq_exhausted,
        cache_hit=use_cache,
    )
    return result, new_state


_ONLINE_STATICS = (
    "s", "use_kernel", "do_equalize", "merge_aware", "extra_slots",
    "matcher", "repair_rounds", "warm_start", "warm_prices", "warm_slack",
)


@functools.partial(jax.jit, static_argnames=_ONLINE_STATICS)
def online_step_jax(
    state: OnlineDeviceState,
    D: jax.Array,
    s: int,
    delta,
    *,
    use_kernel: bool = False,
    do_equalize: bool = True,
    merge_aware: bool = False,
    extra_slots: int = 64,
    matcher: str = "auction",
    repair_rounds: int = 0,
    warm_start: bool = True,
    warm_prices: bool = False,
    warm_slack: float = 0.05,
) -> tuple[OnlineStepResult, OnlineDeviceState]:
    """One stateful controller period on device; see module doc.

    The chosen schedule's credit-aware makespan is ≤ the same-decomposition
    stateless makespan by construction (the stateless candidate with the
    credit applied post-hoc is always in the running).
    """
    return _online_step(
        state, D, s, delta,
        use_kernel=use_kernel, do_equalize=do_equalize,
        merge_aware=merge_aware, extra_slots=extra_slots, matcher=matcher,
        repair_rounds=repair_rounds, warm_start=warm_start,
        warm_prices=warm_prices, warm_slack=warm_slack,
    )


@functools.partial(
    jax.jit, static_argnames=_ONLINE_STATICS + ("cache_size",)
)
def spectra_online_scan(
    Ds: jax.Array,
    s: int,
    deltas,
    *,
    use_kernel: bool = False,
    do_equalize: bool = True,
    merge_aware: bool = False,
    extra_slots: int = 64,
    matcher: str = "auction",
    repair_rounds: int = 0,
    warm_start: bool = True,
    warm_prices: bool = False,
    warm_slack: float = 0.05,
    cache_size: int = 0,
) -> tuple[OnlineStepResult, OnlineDeviceState]:
    """Roll the online step over a whole (T, n, n) trace in ONE dispatch.

    ``lax.scan`` over the T axis with the switch state as carry — the
    device-resident analogue of a controller loop, minus T-1 host
    round-trips. ``deltas`` is a scalar or a (T,) per-period δ vector.
    ``cache_size`` sizes the in-carry support-pattern cache (0 = off), the
    device analogue of the host controller's phase-cycling memoization.
    Returns the per-period results stacked over T plus the final state.
    """
    Ds = jnp.asarray(Ds, jnp.float32)
    T, n = Ds.shape[0], Ds.shape[1]
    deltas = jnp.broadcast_to(jnp.asarray(deltas, jnp.float32), (T,))

    def step(state, xs):
        D, d = xs
        result, state = _online_step(
            state, D, s, d,
            use_kernel=use_kernel, do_equalize=do_equalize,
            merge_aware=merge_aware, extra_slots=extra_slots,
            matcher=matcher, repair_rounds=repair_rounds,
            warm_start=warm_start, warm_prices=warm_prices,
            warm_slack=warm_slack,
        )
        return state, result

    final_state, results = jax.lax.scan(
        step, online_initial_state(n, s, cache_size), (Ds, deltas)
    )
    return results, final_state
