"""Batched ε-scaling auction algorithm for max-weight assignment, in JAX.

TPU adaptation of SPECTRA's Hungarian/JV matching step (DESIGN.md §4):
JV's shortest augmenting path is inherently sequential, while Bertsekas'
auction exposes per-row parallelism — every unassigned row bids at once
(Jacobi variant), each column keeps the best bid. All state is dense
``(n,)``/``(n, n)`` arrays updated with masked vector ops inside
``lax.while_loop``, so the whole solver jits and ``vmap``s over batches of
matrices (one TPU core scheduling many demand matrices concurrently).

Guarantee: with ε-scaling down to ``eps_final``, the assignment is within
``n·eps_final`` of optimal (exact for integer weights if eps_final < 1/n).
The node-coverage constraint of DECOMPOSE survives unchanged because it is
encoded purely in the weights (M-bonus), and M dominates ``n·eps_final``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_NEG = -1e30


def _bid_step(W, row2col, col2row, prices, eps, use_kernel: bool):
    """One parallel bidding round: all unassigned rows bid, columns take max."""
    n = W.shape[0]
    arange = jnp.arange(n)
    unassigned = row2col < 0
    if use_kernel:
        from ...kernels.auction_bid.ops import masked_row_top2

        v1, v2, j1 = masked_row_top2(W, prices)
    else:
        from ...kernels.auction_bid.ref import masked_row_top2_ref

        v1, v2, j1 = masked_row_top2_ref(W, prices)
    # Row i's bid for its favorite column j1[i].
    bid = jnp.where(unassigned, W[arange, j1] - v2 + eps, _NEG)
    # Columns take the best bid (scatter-max via a dense (n, n) mask).
    B = jnp.full((n, n), _NEG, W.dtype).at[arange, j1].set(bid)
    col_best = B.max(axis=0)
    col_winner = B.argmax(axis=0)
    has_bid = col_best > _NEG / 2
    # Kick out previous owners of re-auctioned columns.
    kicked = jnp.where(has_bid & (col2row >= 0), col2row, n)
    row2col = row2col.at[kicked].set(-1, mode="drop")
    # Install winners.
    winner = jnp.where(has_bid, col_winner, n)
    row2col = row2col.at[winner].set(jnp.where(has_bid, arange, -1), mode="drop")
    col2row = jnp.where(has_bid, col_winner, col2row)
    prices = jnp.where(has_bid, col_best, prices)
    return row2col, col2row, prices


@functools.partial(jax.jit, static_argnames=("num_phases", "max_iters", "use_kernel"))
def auction_maximize(
    W: jax.Array,
    *,
    num_phases: int = 8,
    max_iters: int = 10_000,
    use_kernel: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Max-weight assignment of square matrix W.

    Returns ``(perm, converged)`` with ``perm[i] = j``. ``use_kernel=True``
    routes the bid top-2 reduction through the Pallas kernel.
    """
    W = W.astype(jnp.float32)
    n = W.shape[0]
    wmax = jnp.maximum(jnp.abs(W).max(), 1e-12)
    eps_final = wmax * 1e-6 / n

    def phase(state, eps):
        row2col, col2row, prices = state
        # Each phase restarts the assignment but keeps learned prices.
        row2col = jnp.full((n,), -1, jnp.int32)
        col2row = jnp.full((n,), -1, jnp.int32)

        def cond(c):
            row2col, _, _, it = c
            return (row2col < 0).any() & (it < max_iters)

        def body(c):
            row2col, col2row, prices, it = c
            row2col, col2row, prices = _bid_step(
                W, row2col, col2row, prices, eps, use_kernel
            )
            return row2col, col2row, prices, it + 1

        row2col, col2row, prices, _ = jax.lax.while_loop(
            cond, body, (row2col, col2row, prices, 0)
        )
        return (row2col, col2row, prices), None

    prices0 = jnp.zeros((n,), jnp.float32)
    state = (jnp.full((n,), -1, jnp.int32), jnp.full((n,), -1, jnp.int32), prices0)
    # ε schedule: wmax/2 → eps_final, geometric.
    ratio = (eps_final / (wmax / 2.0)) ** (1.0 / max(num_phases - 1, 1))
    eps_sched = (wmax / 2.0) * ratio ** jnp.arange(num_phases)
    state, _ = jax.lax.scan(phase, state, eps_sched)
    row2col, _, _ = state
    converged = (row2col >= 0).all()
    return row2col, converged


def auction_maximize_batch(W: jax.Array, **kw) -> tuple[jax.Array, jax.Array]:
    """vmap'd auction over a batch of matrices (B, n, n) → (B, n)."""
    return jax.vmap(lambda w: auction_maximize(w, **kw))(W)
