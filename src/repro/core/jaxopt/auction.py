"""Batched ε-scaling auction for max-weight assignment (legacy entry point).

The implementation moved to :mod:`repro.core.jaxopt.matching`, which packages
this forward auction plus a combined forward-reverse variant behind a small
``MATCHERS`` registry with an n- and spread-aware ε-schedule. This module
keeps the original call surface: ``auction_maximize(W)`` is the registry's
``"auction"`` matcher with its n-aware defaults.
"""

from __future__ import annotations

import jax

from .matching import MATCHERS, get_matcher, list_matchers, match_auction

_NEG = -1e30  # re-exported for back-compat


def auction_maximize(
    W: jax.Array,
    *,
    num_phases: int | None = None,
    max_iters: int | None = None,
    use_kernel: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Max-weight assignment of square matrix W.

    Returns ``(perm, converged)`` with ``perm[i] = j``. ``use_kernel=True``
    routes the bid top-2 reduction through the Pallas kernel. ``num_phases``
    and ``max_iters`` default to the n-aware schedule of
    :mod:`repro.core.jaxopt.matching`.
    """
    return match_auction(
        W, num_phases=num_phases, max_iters=max_iters, use_kernel=use_kernel
    )


def auction_maximize_batch(W: jax.Array, **kw) -> tuple[jax.Array, jax.Array]:
    """vmap'd auction over a batch of matrices (B, n, n) → (B, n)."""
    return jax.vmap(lambda w: auction_maximize(w, **kw))(W)


__all__ = [
    "MATCHERS",
    "auction_maximize",
    "auction_maximize_batch",
    "get_matcher",
    "list_matchers",
]
