"""Pluggable device matchers: max-weight assignment solvers for DECOMPOSE.

This is the device counterpart of :mod:`repro.core.matching` — the inner
solver of SPECTRA's DECOMPOSE step — packaged as a small registry of
jittable, ``vmap``-able matchers sharing one bidding engine:

    auction      ε-scaling forward auction (Bertsekas, Jacobi variant):
                 every unassigned row bids at once, columns keep the best
                 bid. The default — fastest on the paper workloads.
    auction_fr   combined forward-reverse auction (Bertsekas-Castañón):
                 alternates row-side and column-side bidding rounds,
                 switching sides whenever the assignment grows. Dual-side
                 bidding breaks the one-sided price wars that sparse
                 large-n instances can trigger, at ~2 top-2 reductions per
                 round.
    auction_fused  the whole hot loop owned by one fused implementation
                 (``kernels/auction_fused``): with ``use_kernel`` a single
                 Pallas kernel runs bid → price-update → assignment-flip
                 across ε-phase grid steps with prices in VMEM scratch and
                 lane-aligned 128-column tiles (no XLA round-trip between
                 rounds); without it, an exactly-matching jnp reference
                 whose O(n) segment-scatter rounds are the fast large-n
                 host path. The default matcher at n > 128.

``auction``/``auction_fr`` share the Pallas ``kernels/auction_bid`` top-2
reduction via ``use_kernel`` (the reverse rounds call it on ``W.T``);
``auction_fused`` swaps the whole loop for ``kernels/auction_fused``.

The ε-schedule is n- and spread-aware. Two failure modes of a fixed
schedule, both observed at the paper's n=100 benchmark workload:

* **float32 price livelock** — with the node-coverage M-bonus folded into
  the weights, prices climb to ~``wmax``; once ε drops below the float32
  ulp at that magnitude, ``price + ε`` is a no-op and bidding loops
  forever (this alone produced the 1.36× quality gap: the matcher timed
  out, returned partial assignments, and DECOMPOSE inflated k from 16
  to 20). ``eps_floor`` pins the final ε at 2 ulps of ``wmax``.
* **phase-budget starvation** — 8 phases spanning ``wmax/2 → wmax·1e-6/n``
  shrink ε ~13× per phase at n=100, so late phases need thousands of
  bidding rounds. The phase count now grows with n so each phase refines
  ε by a bounded factor.

Matchers return ``(perm, converged)``. ``perm`` is always a valid
permutation: if the iteration budget is exhausted, leftover rows are paired
with leftover columns greedily (rank order) rather than returning ``-1``
sentinels — a ``-1`` silently corrupts downstream gathers — and
``converged=False`` reports the quality loss.

Optimality: with ε-scaling down to ``eps_final``, the assignment is within
``n·eps_final`` of the max weight (exact for integer weights when
``eps_final < 1/n``). The node-coverage constraint survives because the
M-bonus dominates ``n·eps_final``.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

_NEG = -1e30

# float32 prices saturate once ε < ulp(price); prices reach ~wmax, whose
# ulp is wmax·2^-23..2^-24 — floor ε at two ulps so increments always land.
_EPS_FLOOR = 2.0**-22


def default_num_phases(n: int) -> int:
    """n-aware ε-schedule length: bounded per-phase ε shrink factor.

    The ulp-floored final ε sits ~21 bits below ``wmax/2``; 8 phases keep
    the per-phase shrink ≤ ~8× at small n, 12 up to the paper's n=100
    benchmark, 16 in the pod-scale n ∈ {512, 1024} regime (at n > 256 the
    1e-6/n target is already below the float32 ulp floor, so extra phases
    buy smaller jumps, not smaller ε — measured necessary for the
    property-test optimality rate at n=512).
    """
    if n <= 32:
        return 8
    if n <= 256:
        return 12
    return 16


# Shape-bucket autotuning: the matcher ``repro.api`` picks per shape bucket
# when the caller didn't name one. ``auction`` wins below the first
# threshold (fastest on the paper workloads); between the thresholds the
# combined forward-reverse auction's dual-side bidding is the robust
# default against the one-sided price wars sparse large-n instances can
# trigger (measured at moe n=64 and benchmark n=100: identical 1.0000
# quality, converged); above the second, the fused auction owns the loop —
# re-measured on the BENCH_matching workload (sum-of-16-permutations + the
# DECOMPOSE M-bonus, CPU host, jnp paths): per-dispatch auction_fused vs
# auction 0.37s vs 0.72s at n=256 (1.9×), 2.8s vs 10.8s at n=512 (3.8×),
# 22.9s vs 66.9s at n=1024 (2.9×), all at quality ratio 1.0000 (fused is
# also fastest at n=100: 20ms vs 34ms, but auction_fr's dual-side bidding
# stays the mid-range default for robustness on sparse instances).
# Override per call via ``SolveOptions.extra["matcher"]`` or globally via
# ``set_default_matcher_policy``.
AUTOTUNE_N_THRESHOLD = 32
AUTOTUNE_FUSED_N_THRESHOLD = 128

_DEFAULT_POLICY = None  # None → built-in threshold rule


def default_matcher(n: int) -> str:
    """Registry default for an (n, n) instance (see AUTOTUNE_N_THRESHOLD /
    AUTOTUNE_FUSED_N_THRESHOLD)."""
    if _DEFAULT_POLICY is not None:
        name = _DEFAULT_POLICY(n)
        if name not in MATCHERS:
            # The install-time probe only sees one n; an n-dependent policy
            # can still return a bad name for other sizes — fail here with
            # the policy named, not deep inside a jitted dispatch.
            raise KeyError(
                f"default matcher policy returned unknown matcher {name!r} "
                f"for n={n}; available: {list_matchers()}"
            )
        return name
    if n <= AUTOTUNE_N_THRESHOLD:
        return "auction"
    if n <= AUTOTUNE_FUSED_N_THRESHOLD:
        return "auction_fr"
    return "auction_fused"


def set_default_matcher_policy(policy) -> None:
    """Install ``policy(n) -> matcher name`` as the autotuning rule
    (``None`` restores the built-in threshold rule)."""
    global _DEFAULT_POLICY
    if policy is not None:
        name = policy(8)
        if name not in MATCHERS:
            raise KeyError(
                f"policy returned unknown matcher {name!r}; "
                f"available: {list_matchers()}"
            )
    _DEFAULT_POLICY = policy


def default_max_iters(n: int) -> int:
    """Per-phase bidding-round budget; contested columns serialize, so the
    budget grows with n."""
    return max(2000, 60 * n)


def _top2(W, prices, use_kernel: bool):
    """Per-row top-2 of ``W - prices`` — the shared bid reduction."""
    if use_kernel:
        from ...kernels.auction_bid.ops import masked_row_top2

        return masked_row_top2(W, prices)
    from ...kernels.auction_bid.ref import masked_row_top2_ref

    return masked_row_top2_ref(W, prices)


def _forward_round(W, row2col, col2row, prices, profits, eps, use_kernel):
    """One Jacobi bidding round: all unassigned rows bid, columns take max.

    Also maintains row profits (``π_i = v2 - ε`` for winners) so the same
    round serves as one side of the forward-reverse matcher; the plain
    forward matcher threads a zero array through unchanged cost.
    """
    n = W.shape[0]
    arange = jnp.arange(n)
    unassigned = row2col < 0
    v1, v2, j1 = _top2(W, prices, use_kernel)
    # Row i's bid for its favorite column j1[i].
    bid = jnp.where(unassigned, W[arange, j1] - v2 + eps, _NEG)
    # Columns take the best bid (scatter-max via a dense (n, n) mask).
    B = jnp.full((n, n), _NEG, W.dtype).at[arange, j1].set(bid)
    col_best = B.max(axis=0)
    col_winner = B.argmax(axis=0)
    has_bid = col_best > _NEG / 2
    # Kick out previous owners of re-auctioned columns.
    kicked = jnp.where(has_bid & (col2row >= 0), col2row, n)
    row2col = row2col.at[kicked].set(-1, mode="drop")
    # Install winners.
    winner = jnp.where(has_bid, col_winner, n)
    row2col = row2col.at[winner].set(jnp.where(has_bid, arange, -1), mode="drop")
    col2row = jnp.where(has_bid, col_winner, col2row)
    prices = jnp.where(has_bid, col_best, prices)
    safe_winner = jnp.clip(col_winner, 0, n - 1)
    profits = profits.at[winner].set(
        jnp.where(has_bid, v2[safe_winner] - eps, 0.0), mode="drop"
    )
    return row2col, col2row, prices, profits


def _reverse_round(W, row2col, col2row, prices, profits, eps, use_kernel):
    """Column-side bidding: the forward round on ``W.T`` with roles swapped
    (prices ↔ profits), sharing the same top-2 reduction."""
    col2row, row2col, profits, prices = _forward_round(
        W.T, col2row, row2col, profits, prices, eps, use_kernel
    )
    return row2col, col2row, prices, profits


def _complete_greedy(row2col, col2row):
    """Pair leftover rows with leftover columns in rank order so the result
    is always a permutation (a ``-1`` corrupts downstream gathers)."""
    n = row2col.shape[0]
    un_r = row2col < 0
    un_c = col2row < 0
    rank_r = jnp.cumsum(un_r) - 1          # 0-based rank among unassigned rows
    order_c = jnp.argsort(~un_c, stable=True)  # unassigned columns first
    fill = order_c[jnp.clip(rank_r, 0, n - 1)].astype(row2col.dtype)
    return jnp.where(un_r, fill, row2col)


def _eps_schedule(W, num_phases: int):
    """Geometric ε schedule from ``wmax/2`` down to the ulp-floored final ε."""
    n = W.shape[0]
    wmax = jnp.maximum(jnp.abs(W).max(), 1e-12)
    eps_final = jnp.maximum(wmax * 1e-6 / n, wmax * _EPS_FLOOR)
    ratio = (eps_final / (wmax / 2.0)) ** (1.0 / max(num_phases - 1, 1))
    return (wmax / 2.0) * ratio ** jnp.arange(num_phases)


@functools.partial(
    jax.jit, static_argnames=("num_phases", "max_iters", "use_kernel", "with_prices")
)
def match_auction(
    W: jax.Array,
    *,
    num_phases: int | None = None,
    max_iters: int | None = None,
    use_kernel: bool = False,
    prices0: jax.Array | None = None,
    with_prices: bool = False,
) -> tuple[jax.Array, ...]:
    """Forward ε-scaling auction. Returns ``(perm, converged)``.

    ``prices0`` warm-starts the column dual prices (e.g. the final prices of
    a previous, similar instance — the online controller's cross-period
    carry). ε-scaling already re-derives the assignment from prices each
    phase, so a warm start is equivalent to having run one extra earlier
    phase: optimality is unaffected, convergence on near-repeated instances
    is faster. ``with_prices=True`` appends the final prices to the return
    for callers that carry them forward.
    """
    W = W.astype(jnp.float32)
    n = W.shape[0]
    if num_phases is None:
        num_phases = default_num_phases(n)
    if max_iters is None:
        max_iters = default_max_iters(n)
    init_prices = (
        jnp.zeros((n,), jnp.float32)
        if prices0 is None
        else jnp.asarray(prices0, jnp.float32)
    )

    def phase(state, eps):
        _, _, prices = state
        # Each phase restarts the assignment but keeps learned prices.
        row2col = jnp.full((n,), -1, jnp.int32)
        col2row = jnp.full((n,), -1, jnp.int32)
        zeros = jnp.zeros((n,), jnp.float32)

        def cond(c):
            row2col, _, _, it = c
            return (row2col < 0).any() & (it < max_iters)

        def body(c):
            row2col, col2row, prices, it = c
            row2col, col2row, prices, _ = _forward_round(
                W, row2col, col2row, prices, zeros, eps, use_kernel
            )
            return row2col, col2row, prices, it + 1

        row2col, col2row, prices, _ = jax.lax.while_loop(
            cond, body, (row2col, col2row, prices, 0)
        )
        return (row2col, col2row, prices), None

    state = (
        jnp.full((n,), -1, jnp.int32),
        jnp.full((n,), -1, jnp.int32),
        init_prices,
    )
    state, _ = jax.lax.scan(phase, state, _eps_schedule(W, num_phases))
    row2col, col2row, prices = state
    converged = (row2col >= 0).all()
    perm = _complete_greedy(row2col, col2row)
    if with_prices:
        return perm, converged, prices
    return perm, converged


@functools.partial(
    jax.jit, static_argnames=("num_phases", "max_iters", "use_kernel", "with_prices")
)
def match_auction_fr(
    W: jax.Array,
    *,
    num_phases: int | None = None,
    max_iters: int | None = None,
    use_kernel: bool = False,
    prices0: jax.Array | None = None,
    with_prices: bool = False,
) -> tuple[jax.Array, ...]:
    """Combined forward-reverse auction. Returns ``(perm, converged)``.

    Rows and columns take turns bidding; the side flips whenever a round
    grows the assignment (Bertsekas-Castañón switching rule — the matched
    count never shrinks, so alternation cannot cycle). ``prices0`` /
    ``with_prices`` behave as on ``match_auction`` (warm-started column
    prices in, final prices out).
    """
    W = W.astype(jnp.float32)
    n = W.shape[0]
    if num_phases is None:
        num_phases = default_num_phases(n)
    if max_iters is None:
        max_iters = default_max_iters(n)
    init_prices = (
        jnp.zeros((n,), jnp.float32)
        if prices0 is None
        else jnp.asarray(prices0, jnp.float32)
    )

    def phase(state, eps):
        _, _, prices, profits = state
        row2col = jnp.full((n,), -1, jnp.int32)
        col2row = jnp.full((n,), -1, jnp.int32)

        def cond(c):
            row2col, _, _, _, _, it = c
            return (row2col < 0).any() & (it < max_iters)

        def body(c):
            row2col, col2row, prices, profits, fwd, it = c
            before = (row2col >= 0).sum()
            row2col, col2row, prices, profits = jax.lax.cond(
                fwd,
                lambda a: _forward_round(W, *a, eps, use_kernel),
                lambda a: _reverse_round(W, *a, eps, use_kernel),
                (row2col, col2row, prices, profits),
            )
            grew = (row2col >= 0).sum() > before
            return row2col, col2row, prices, profits, fwd ^ grew, it + 1

        row2col, col2row, prices, profits, _, _ = jax.lax.while_loop(
            cond, body, (row2col, col2row, prices, profits, jnp.bool_(True), 0)
        )
        return (row2col, col2row, prices, profits), None

    state = (
        jnp.full((n,), -1, jnp.int32),
        jnp.full((n,), -1, jnp.int32),
        init_prices,
        jnp.zeros((n,), jnp.float32),
    )
    state, _ = jax.lax.scan(phase, state, _eps_schedule(W, num_phases))
    row2col, col2row, prices, _ = state
    converged = (row2col >= 0).all()
    perm = _complete_greedy(row2col, col2row)
    if with_prices:
        return perm, converged, prices
    return perm, converged


def _polish_2swap(W, perm, max_swaps: int):
    """Greedy best-pair 2-swap polish: upgrades the auction's guarantee
    from n·eps_final-optimal to *also 2-opt* (no single transposition can
    improve the assignment).

    eps_final is ulp-floored (``_EPS_FLOOR``), and at pod scale the floor's
    slack reaches ~n·wmax·2⁻²² ≈ 0.3 weight units (n=1024, M-bonus
    regime) — enough room, in principle, for transposition-type errors the
    polish repairs for free (one iteration ≈ one bidding round's top-2
    pass; ``gain(i,i') = W[i,σ(i')] + W[i',σ(i)] − W[i,σ(i)] −
    W[i',σ(i')]``, best strictly-positive swap applied per iteration).
    Measured on the BENCH_matching workloads the auction already lands
    2-opt (the polish is a no-op pass) — this is a cheap worst-case bound,
    not the source of the large-n quality numbers. Coverage is safe: the
    M-bonus dominates any demand gain, so a weight-increasing swap never
    drops a covered critical line.
    """
    n = W.shape[0]
    rows = jnp.arange(n)

    def cond(carry):
        _, it, improved = carry
        return improved & (it < max_swaps)

    def body(carry):
        perm, it, _ = carry
        cur = W[rows, perm]
        cross = W[:, perm]  # cross[i, i'] = W[i, perm[i']]
        gain = cross + cross.T - cur[:, None] - cur[None, :]
        flat = jnp.argmax(gain)
        i, ip = flat // n, flat % n
        do = gain[i, ip] > 0
        pi, pip = perm[i], perm[ip]
        new_perm = perm.at[i].set(jnp.where(do, pip, pi)).at[ip].set(
            jnp.where(do, pi, pip)
        )
        return new_perm, it + 1, do

    perm, _, _ = jax.lax.while_loop(
        cond, body, (perm, jnp.int32(0), jnp.bool_(True))
    )
    return perm


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_phases", "max_iters", "use_kernel", "with_prices", "interpret",
        "with_iters",
    ),
)
def match_auction_fused(
    W: jax.Array,
    *,
    num_phases: int | None = None,
    max_iters: int | None = None,
    use_kernel: bool = False,
    prices0: jax.Array | None = None,
    with_prices: bool = False,
    interpret: bool | None = None,
    with_iters: bool = False,
) -> tuple[jax.Array, ...]:
    """Fully fused forward ε-scaling auction. Returns ``(perm, converged)``.

    The whole hot loop lives in ``kernels/auction_fused``: with
    ``use_kernel=True`` a single Pallas kernel runs every bidding round of
    every ε phase on-chip (prices in VMEM scratch across the phase grid,
    lane-aligned 128-column tiles at n ≥ 256 — the pod-scale path); with
    ``use_kernel=False`` the exactly-matching jnp reference, whose
    segment-scatter rounds are themselves several times cheaper than
    ``match_auction``'s whole-matrix rounds at large n. Shares this
    module's ε-schedule (ulp floor included), ``(perm, converged)``
    contract, greedy completion, and ``prices0``/``with_prices`` warm-start
    surface, then runs the ``_polish_2swap`` sweep so the result is also
    2-opt — a cheap worst-case guard against ε-floor transposition errors
    (measured a no-op on the benchmark workloads; see its docstring).
    ``interpret`` forces/disables Pallas interpret mode (``None`` → auto:
    interpret off-TPU). ``with_iters=True`` appends the total bidding-round
    count (after prices, when both are requested) — the observable that
    shows cross-period warm starts converging in fewer rounds; the kernel
    path reports ``-1`` (its loop counter stays on-chip).

    **Warm ε-entry:** supplying ``prices0`` is declared "equivalent to
    having run the earlier phases already" (see ``match_auction``) — here
    that equivalence is cashed in. A warm dispatch enters the ε grid at its
    *tail* (the last ``max(2, num_phases // 2)`` phases), so cross-period
    price carry pays for roughly half the bidding phases instead of
    re-running the full schedule against already-converged prices. The
    optimality guarantee is unchanged — it comes from the final phase
    completing at the same ulp-floored ``eps_final`` (``converged`` still
    reports budget exhaustion); only the ramp that warm prices make
    redundant is skipped. Cold dispatches (no ``prices0``) are untouched.
    """
    from ...kernels.auction_fused.ops import fused_auction

    W = W.astype(jnp.float32)
    n = W.shape[0]
    if num_phases is None:
        num_phases = default_num_phases(n)
    if max_iters is None:
        max_iters = default_max_iters(n)
    eps_schedule = _eps_schedule(W, num_phases)
    if prices0 is None:
        init_prices = jnp.zeros((n,), jnp.float32)
    else:
        init_prices = jnp.asarray(prices0, jnp.float32)
        eps_schedule = eps_schedule[-max(2, num_phases // 2):]
    out = fused_auction(
        W,
        init_prices,
        eps_schedule,
        max_iters=max_iters,
        use_kernel=use_kernel,
        interpret=interpret,
        with_iters=with_iters,
    )
    row2col, col2row, prices = out[:3]
    converged = (row2col >= 0).all()
    perm = _complete_greedy(row2col, col2row)
    perm = _polish_2swap(W, perm, max_swaps=2 * n)
    ret: tuple[jax.Array, ...] = (perm, converged)
    if with_prices:
        ret = ret + (prices,)
    if with_iters:
        ret = ret + (out[3],)
    return ret if len(ret) > 2 else (perm, converged)


# --------------------------------------------------------------- registry

MatcherFn = Callable[..., tuple[jax.Array, jax.Array]]

MATCHERS: dict[str, MatcherFn] = {
    "auction": match_auction,
    "auction_fr": match_auction_fr,
    "auction_fused": match_auction_fused,
}


def get_matcher(name: str) -> MatcherFn:
    if name not in MATCHERS:
        raise KeyError(f"unknown matcher {name!r}; available: {list_matchers()}")
    return MATCHERS[name]


def list_matchers() -> list[str]:
    return sorted(MATCHERS)


def register_matcher(name: str, fn: MatcherFn, *, overwrite: bool = False) -> None:
    """Add a device matcher: ``fn(W, *, num_phases, max_iters, use_kernel)
    -> (perm, converged)``, jittable and vmappable. Matchers that support
    warm starts additionally accept ``prices0`` (initial dual prices) and
    ``with_prices=True`` (append final prices to the return) — the online
    controller only requests those from matchers that advertise them."""
    if name in MATCHERS and not overwrite:
        raise ValueError(f"matcher {name!r} already registered")
    replacing = name in MATCHERS
    MATCHERS[name] = fn
    if replacing:
        # Jitted consumers resolve the name at trace time and key their
        # caches on the string — drop them so the replacement takes effect.
        from .decompose_jax import decompose_jax, decompose_jax_prices
        from .e2e import spectra_jax_e2e, spectra_jax_e2e_many
        from .online_jax import online_step_jax, spectra_online_scan

        for jitted in (
            decompose_jax, decompose_jax_prices,
            spectra_jax_e2e, spectra_jax_e2e_many,
            online_step_jax, spectra_online_scan,
        ):
            jitted.clear_cache()
