"""On-device EQUALIZE (Alg. 4) over the dense ``DeviceSchedule`` IR.

Mirrors ``repro.core.equalize`` with array state inside ``lax.while_loop``:
each iteration moves a ``τ = (L_max − L_min − setup)/2`` slice of the longest
permutation on the most-loaded switch into a fresh slot on the least-loaded
switch (which pays one extra reconfiguration δ), until the spread is at most
δ, the longest permutation is too short to split, or the slot table runs out
of free capacity.

``merge_aware=True`` is the SPECTRA++ variant: when the moved permutation
already exists on the target switch its weight merges into that slot — no
extra δ. Permutation equality is resolved by hashing once up front: every
slot gets a canonical id (the first slot carrying an identical permutation),
the device analogue of the host path's ``perm.tobytes()`` hash table, so the
loop body compares single int32s instead of rescanning (R, n) rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..schedule_ir import DeviceSchedule


def _canonical_ids(perms: jax.Array) -> jax.Array:
    """canon[r] = smallest r' with perms[r'] == perms[r] (exact, no collisions).

    Folds the row-equality matrix one column at a time so peak memory is
    O(R²), not the O(R²·n) of a broadcast all-pairs comparison — at
    production fabric sizes (n ≥ 512) the latter is gigabytes per vmap lane.
    """
    n = perms.shape[1]

    def fold(j, eq):
        col = perms[:, j]
        return eq & (col[:, None] == col[None, :])

    eq0 = perms[:, 0][:, None] == perms[:, 0][None, :]  # (R, R)
    eq = jax.lax.fori_loop(1, n, fold, eq0)
    return jnp.argmax(eq, axis=1).astype(jnp.int32)


def device_loads(alphas: jax.Array, switch: jax.Array, delta, s: int) -> jax.Array:
    """Per-switch loads (Σα + δ·configs) over live slots — the single jnp
    definition of the load formula, shared by EQUALIZE and the fused e2e."""
    live = switch >= 0
    contrib = jnp.where(live, alphas + delta, 0.0)
    return jnp.zeros((s,), jnp.float32).at[jnp.where(live, switch, 0)].add(contrib)


def equalize_ir(
    ds: DeviceSchedule,
    s: int,
    *,
    merge_aware: bool = False,
    max_iters: int | None = None,
    load_offset: jax.Array | None = None,
) -> tuple[DeviceSchedule, jax.Array]:
    """Alg. 4 on device; returns ``(schedule, exhausted)`` (same capacity).

    ``load_offset`` is an optional (s,) shift on each switch's effective
    load — the online controller passes −δ for switches whose first
    configuration is carried over from the previous period (reuse credit).
    The credited slot never changes switches (splits only shrink it), so
    the offset is loop-invariant.

    ``exhausted`` is a () bool set when the slot table ran out of split
    headroom — the one stop condition the host path doesn't have, i.e. the
    only case where this result can be worse than host EQUALIZE. Callers
    should surface it (the API backend puts it in report extras; the host
    stage wrapper finishes the job with host EQUALIZE).

    Trace-safe and ``vmap``-able: once an instance converges its loop body
    becomes a no-op, so batched lanes simply coast until the slowest one
    finishes. ``max_iters`` defaults to the host path's ``64·(configs+s)+64``.
    """
    R = ds.perms.shape[0]
    perms0 = ds.perms.astype(jnp.int32)
    alphas0 = ds.alphas.astype(jnp.float32)
    switch0 = ds.switch.astype(jnp.int32)
    delta = jnp.asarray(ds.delta, jnp.float32)
    count0 = (switch0 >= 0).sum().astype(jnp.int32)
    offset = (
        jnp.zeros((s,), jnp.float32)
        if load_offset is None
        else jnp.asarray(load_offset, jnp.float32)
    )
    iter_cap = (
        jnp.int32(max_iters)
        if max_iters is not None
        else 64 * (count0 + jnp.int32(s)) + 64
    )
    canon0 = _canonical_ids(perms0) if merge_aware else jnp.zeros((R,), jnp.int32)

    def cond(st):
        _, _, _, _, _, it, done, _ = st
        return (~done) & (it < iter_cap)

    def body(st):
        perms, alphas, switch, canon, count, it, _, exhausted = st
        live = switch >= 0
        loads = device_loads(alphas, switch, delta, s) + offset
        h_max = jnp.argmax(loads)
        h_min = jnp.argmin(loads)
        spread_ok = loads[h_max] - loads[h_min] <= delta
        # Longest slot on the most-loaded switch.
        on_max = live & (switch == h_max)
        z = jnp.argmax(jnp.where(on_max, alphas, -jnp.inf))
        no_source = ~on_max.any()
        # Merge target: same canonical permutation already on the min switch.
        if merge_aware:
            mmask = live & (switch == h_min) & (canon == canon[z])
            can_merge = mmask.any()
            j = jnp.argmax(mmask)
        else:
            can_merge = jnp.bool_(False)
            j = jnp.int32(0)
        setup = jnp.where(can_merge, 0.0, delta)
        mu = (loads[h_max] + loads[h_min] + setup) / 2.0
        tau = loads[h_max] - mu
        # Exhaustion only counts when headroom was the *binding* stop reason —
        # a lane that also converged (or ran out of splittable weight) is fine.
        other_stop = spread_ok | no_source | (tau <= 0) | (alphas[z] <= tau)
        out_of_slots = (~can_merge) & (count >= R) & ~other_stop
        done = other_stop | out_of_slots
        go = ~done
        tau = jnp.where(go, tau, 0.0)
        alphas = alphas.at[z].add(-tau)
        do_merge = go & can_merge
        alphas = alphas.at[j].add(jnp.where(do_merge, tau, 0.0))
        do_split = go & ~can_merge
        alphas = alphas.at[count].set(
            jnp.where(do_split, tau, alphas[count]), mode="drop"
        )
        switch = switch.at[count].set(
            jnp.where(do_split, h_min.astype(jnp.int32), switch[count]), mode="drop"
        )
        perms = perms.at[count].set(
            jnp.where(do_split, perms[z], perms[count]), mode="drop"
        )
        canon = canon.at[count].set(
            jnp.where(do_split, canon[z], canon[count]), mode="drop"
        )
        count = count + do_split.astype(jnp.int32)
        return (
            perms, alphas, switch, canon, count, it + 1, done,
            exhausted | out_of_slots,
        )

    if s <= 1:
        out = DeviceSchedule(
            perms=perms0, alphas=alphas0, switch=switch0, delta=delta
        )
        return out, jnp.bool_(False)
    init = (
        perms0, alphas0, switch0, canon0, count0,
        jnp.int32(0), jnp.bool_(False), jnp.bool_(False),
    )
    perms, alphas, switch, _, _, _, _, exhausted = jax.lax.while_loop(
        cond, body, init
    )
    out = DeviceSchedule(perms=perms, alphas=alphas, switch=switch, delta=delta)
    return out, exhausted


@functools.partial(jax.jit, static_argnames=("s", "merge_aware", "max_iters"))
def equalize_ir_jit(
    ds: DeviceSchedule,
    s: int,
    *,
    merge_aware: bool = False,
    max_iters: int | None = None,
    load_offset: jax.Array | None = None,
):
    """Jitted ``equalize_ir``; returns ``(schedule, exhausted)``."""
    return equalize_ir(
        ds, s, merge_aware=merge_aware, max_iters=max_iters,
        load_offset=load_offset,
    )


def equalize_jax(sched, n: int | None = None, *, merge_aware: bool = False,
                 extra_slots: int = 64, max_iters: int | None = None):
    """Host convenience: ParallelSchedule → device EQUALIZE → ParallelSchedule.

    This is what the ``"jax"`` entry of the ``EQUALIZERS`` stage registry
    calls; ``n`` defaults to the fabric size of the first permutation. In
    the rare case the device pass exhausts its split headroom, host
    EQUALIZE finishes the job (it picks up exactly where the device left
    off — Alg. 4 is an iterative improvement loop).
    """
    from ..equalize import equalize
    from ..schedule_ir import ir_to_schedule, schedule_to_ir

    s = sched.s
    if n is None:
        for sw in sched.switches:
            if sw.perms:
                n = len(sw.perms[0])
                break
        else:
            return sched  # nothing scheduled anywhere
    # Bucket the capacity to a multiple of 64 so the jitted while_loop sees
    # a stable (R, n) shape across instances with different config counts —
    # otherwise every distinct num_configs would trigger a fresh XLA compile.
    needed = sched.num_configs() + extra_slots
    capacity = -(-needed // 64) * 64
    ds = schedule_to_ir(sched, n, capacity=capacity)
    out, exhausted = equalize_ir_jit(
        ds, s, merge_aware=merge_aware, max_iters=max_iters
    )
    result = ir_to_schedule(out, s)
    if bool(exhausted) and max_iters is None:
        result = equalize(result, merge_aware=merge_aware)
    return result
