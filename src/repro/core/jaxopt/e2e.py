"""Fused DECOMPOSE → SCHEDULE → EQUALIZE on device: one jitted, vmappable call.

``spectra_jax_e2e`` chains the ε-scaling auction decomposition (Alg. 1+2),
device LPT (Alg. 3), and the ``lax.while_loop`` EQUALIZE (Alg. 4) into a
single XLA program emitting a dense ``DeviceSchedule``; ``spectra_jax_e2e_many``
is its ``vmap`` over stacked demand matrices — the controller path that
re-solves scheduling for many concurrent demand matrices per period without
a host round-trip between stages.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..schedule_ir import DeviceSchedule
from .decompose_jax import JaxDecomposition, decompose_jax, lpt_schedule_jax
from .equalize_jax import device_loads, equalize_ir
from .lower_bounds_jax import lower_bound_jax


class E2EResult(NamedTuple):
    """Device-resident result of the fused pipeline (one instance per lane)."""

    schedule: DeviceSchedule      # post-EQUALIZE slot table
    dec: JaxDecomposition         # raw DECOMPOSE output (pre-EQUALIZE weights)
    makespan: jax.Array           # () float32 — max switch load after EQUALIZE
    lpt_makespan: jax.Array       # () float32 — Alg. 3 makespan before EQUALIZE
    eq_exhausted: jax.Array       # () bool — EQUALIZE ran out of split slots
                                  # (raise extra_slots; host parity not reached)
    lb: jax.Array                 # () float32 — §IV lower bound of the instance


def _ir_makespan(ds: DeviceSchedule, s: int) -> jax.Array:
    return device_loads(ds.alphas, ds.switch, ds.delta, s).max()


@functools.partial(
    jax.jit,
    static_argnames=(
        "s", "use_kernel", "do_equalize", "merge_aware", "extra_slots",
        "matcher", "repair_rounds",
    ),
)
def spectra_jax_e2e(
    D: jax.Array,
    s: int,
    delta,
    *,
    use_kernel: bool = False,
    do_equalize: bool = True,
    merge_aware: bool = False,
    extra_slots: int = 64,
    matcher: str = "auction",
    repair_rounds: int = 0,
) -> E2EResult:
    """Full SPECTRA pipeline for one (n, n) demand matrix, entirely on device.

    ``extra_slots`` is the EQUALIZE split headroom appended to the n
    decomposition slots (each non-merging split consumes one slot).
    ``matcher`` selects the device MWM solver (``matching.MATCHERS``);
    ``repair_rounds`` bounds the post-REFINE local-search sweeps.
    """
    D = jnp.asarray(D, jnp.float32)
    n = D.shape[0]
    delta = jnp.asarray(delta, jnp.float32)
    dec = decompose_jax(
        D, use_kernel=use_kernel, matcher=matcher, repair_rounds=repair_rounds
    )
    assignment, _, lpt_makespan = lpt_schedule_jax(dec, s, delta)
    pad_perms = jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32)[None, :], (extra_slots, n)
    )
    ds = DeviceSchedule(
        perms=jnp.concatenate([dec.perms, pad_perms], axis=0),
        alphas=jnp.concatenate([dec.alphas, jnp.zeros((extra_slots,), jnp.float32)]),
        switch=jnp.concatenate(
            [assignment, jnp.full((extra_slots,), -1, jnp.int32)]
        ),
        delta=delta,
    )
    eq_exhausted = jnp.bool_(False)
    if do_equalize:
        ds, eq_exhausted = equalize_ir(ds, s, merge_aware=merge_aware)
    return E2EResult(
        schedule=ds,
        dec=dec,
        makespan=_ir_makespan(ds, s),
        lpt_makespan=lpt_makespan,
        eq_exhausted=eq_exhausted,
        lb=lower_bound_jax(D, s, delta),
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "s", "use_kernel", "do_equalize", "merge_aware", "extra_slots",
        "matcher", "repair_rounds",
    ),
)
def spectra_jax_e2e_many(
    Ds: jax.Array,
    s: int,
    delta,
    *,
    use_kernel: bool = False,
    do_equalize: bool = True,
    merge_aware: bool = False,
    extra_slots: int = 64,
    matcher: str = "auction",
    repair_rounds: int = 0,
) -> E2EResult:
    """vmapped fused pipeline over stacked (B, n, n) demand matrices.

    ``delta`` may be a scalar (one δ for the whole batch) or a (B,) vector
    (per-instance δ — how trace-aware δ sweeps batch a whole trace whose
    reconfiguration delay varies per period into one dispatch).
    """
    Ds = jnp.asarray(Ds, jnp.float32)
    deltas = jnp.broadcast_to(
        jnp.asarray(delta, jnp.float32), (Ds.shape[0],)
    )
    return jax.vmap(
        lambda D, d: spectra_jax_e2e(
            D,
            s,
            d,
            use_kernel=use_kernel,
            do_equalize=do_equalize,
            merge_aware=merge_aware,
            extra_slots=extra_slots,
            matcher=matcher,
            repair_rounds=repair_rounds,
        )
    )(Ds, deltas)
