"""On-device (TPU) SPECTRA DECOMPOSE + LPT SCHEDULE, fully in JAX.

Mirrors ``repro.core.decompose``/``schedule`` with dense array state inside
``lax.while_loop``/``scan`` so the controller's scheduling computation can run
on the accelerator itself and be ``vmap``-ed over batches of demand matrices
(DESIGN.md §4). The constrained MWM uses the ε-scaling auction solver; the
node-coverage constraint is encoded in the weights (M-bonus), exactly as in
the numpy path.

EQUALIZE runs on device too: the decomposition and LPT assignment produced
here feed the dense ``repro.core.schedule_ir.DeviceSchedule`` slot table, on
which ``equalize_jax`` (Alg. 4 as a ``lax.while_loop``) operates — see
``repro.core.jaxopt.e2e.spectra_jax_e2e`` for the fused single-call pipeline.
``to_decomposition`` + ``repro.core.schedule_lpt`` + ``repro.core.equalize``
remain available to materialize/rebuild a host schedule from the raw
decomposition.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .auction import auction_maximize
from ..decompose import Decomposition


class JaxDecomposition(NamedTuple):
    perms: jax.Array   # (n, n) int32; row r = permutation of round r (padded)
    alphas: jax.Array  # (n,) float32; 0 for padded rounds
    k: jax.Array       # () int32: number of real rounds
    converged: jax.Array  # () bool: all auctions converged


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def decompose_jax(D: jax.Array, *, use_kernel: bool = False) -> JaxDecomposition:
    """Exactly-k decomposition of D (Alg. 1 + greedy REFINE), on device."""
    D = D.astype(jnp.float32)
    n = D.shape[0]
    arange = jnp.arange(n)

    def cond(st):
        _, S_rem, _, _, i, _ = st
        return S_rem.any() & (i < n)

    def body(st):
        D_rem, S_rem, perms, alphas, i, conv = st
        row_deg = S_rem.sum(axis=1)
        col_deg = S_rem.sum(axis=0)
        k = jnp.maximum(row_deg.max(), col_deg.max())
        crit_r = (row_deg == k) & (k > 0)
        crit_c = (col_deg == k) & (k > 0)
        base = jnp.maximum(D_rem, 0.0)
        M = base.sum() + 1.0
        bonus = M * (crit_r[:, None].astype(jnp.float32) + crit_c[None, :])
        W = base + jnp.where(S_rem, bonus, 0.0)
        perm, ok = auction_maximize(W, use_kernel=use_kernel)
        newly = S_rem[arange, perm]
        # α = min D_rem over *newly covered* support, exactly the numpy
        # "covered_support" rule: a round that newly covers nothing gets α=0
        # (guarding on newly.any() keeps the inf mask from ever escaping).
        vals = jnp.where(newly, D_rem[arange, perm], jnp.inf)
        alpha = jnp.where(newly.any(), vals.min(), 0.0)
        D_rem = jnp.maximum(D_rem.at[arange, perm].add(-alpha), 0.0)
        S_rem = S_rem.at[arange, perm].set(False)
        perms = perms.at[i].set(perm.astype(jnp.int32))
        alphas = alphas.at[i].set(alpha)
        return D_rem, S_rem, perms, alphas, i + 1, conv & ok

    init = (
        D,
        D > 0,
        jnp.broadcast_to(arange[None, :], (n, n)).astype(jnp.int32),
        jnp.zeros((n,), jnp.float32),
        jnp.int32(0),
        jnp.bool_(True),
    )
    D_rem, S_rem, perms, alphas, k, conv = jax.lax.while_loop(cond, body, init)

    # Greedy REFINE (Alg. 2) over all rounds (padded rounds see zero residual).
    R0 = D - (
        jnp.zeros_like(D)
        .at[jnp.broadcast_to(arange[None, :], (n, n)), perms]
        .add(alphas[:, None] * (jnp.arange(n) < k)[:, None])
    )
    # Note: scatter above adds alpha_r at (row, perms[r, row]) for each round.
    R0 = jnp.maximum(R0, 0.0)

    def refine_body(r, carry):
        R, alphas = carry
        perm = perms[r]
        d = jnp.maximum(R[arange, perm].max(), 0.0)
        d = jnp.where(r < k, d, 0.0)
        alphas = alphas.at[r].add(d)
        R = R.at[arange, perm].add(-d)
        R = jnp.maximum(R, 0.0)
        return R, alphas

    _, alphas = jax.lax.fori_loop(0, n, refine_body, (R0, alphas))
    return JaxDecomposition(perms=perms, alphas=alphas, k=k, converged=conv)


@functools.partial(jax.jit, static_argnames=("s",))
def lpt_schedule_jax(dec: JaxDecomposition, s: int, delta: jax.Array):
    """Alg. 3 on device: returns (assignment (n,), loads (s,), makespan)."""
    n = dec.alphas.shape[0]
    valid = jnp.arange(n) < dec.k
    order = jnp.argsort(jnp.where(valid, -dec.alphas, jnp.inf))

    def place(loads, idx):
        a = dec.alphas[idx]
        is_real = jnp.take(valid, idx)
        h = jnp.argmin(loads)
        loads = jnp.where(is_real, loads.at[h].add(delta + a), loads)
        return loads, jnp.where(is_real, h, -1)

    loads, assignment_sorted = jax.lax.scan(place, jnp.zeros((s,), jnp.float32), order)
    assignment = jnp.full((n,), -1, jnp.int32).at[order].set(
        assignment_sorted.astype(jnp.int32)
    )
    return assignment, loads, loads.max()


def spectra_jax(D: jax.Array, s: int, delta: float, *, use_kernel: bool = False):
    """DECOMPOSE + LPT on device; returns (dec, assignment, loads, makespan)."""
    dec = decompose_jax(D, use_kernel=use_kernel)
    assignment, loads, makespan = lpt_schedule_jax(dec, s, jnp.float32(delta))
    return dec, assignment, loads, makespan


def to_decomposition(dec: JaxDecomposition) -> Decomposition:
    """Materialize on host as a numpy Decomposition (for EQUALIZE etc.)."""
    import numpy as np

    k = int(dec.k)
    perms = np.asarray(dec.perms)[:k]
    alphas = np.asarray(dec.alphas)[:k]
    return Decomposition(perms=[p.astype(np.int64) for p in perms],
                         alphas=[float(a) for a in alphas])
