"""On-device (TPU) SPECTRA DECOMPOSE + LPT SCHEDULE, fully in JAX.

Mirrors ``repro.core.decompose``/``schedule`` with dense array state inside
``lax.while_loop``/``scan`` so the controller's scheduling computation can run
on the accelerator itself and be ``vmap``-ed over batches of demand matrices
(DESIGN.md §4). The constrained MWM goes through a pluggable device matcher
(:mod:`repro.core.jaxopt.matching` — ε-scaling auction by default, selectable
via ``matcher=``); the node-coverage constraint is encoded in the weights
(M-bonus), exactly as in the numpy path.

Beyond Alg. 1+2, ``repair_rounds > 0`` enables a bounded device local-search
pass after the greedy REFINE: repeated shrink sweeps re-extract α mass that
REFINE over-provisioned (each sweep lowers every α by the minimum coverage
slack along its permutation), so one weak matching round no longer
permanently inflates the decomposition's total weight. Rounds whose α
shrinks to zero are compacted to the tail and dropped from ``k`` — they
would otherwise still cost δ in the schedule.

EQUALIZE runs on device too: the decomposition and LPT assignment produced
here feed the dense ``repro.core.schedule_ir.DeviceSchedule`` slot table, on
which ``equalize_jax`` (Alg. 4 as a ``lax.while_loop``) operates — see
``repro.core.jaxopt.e2e.spectra_jax_e2e`` for the fused single-call pipeline.
``to_decomposition`` + ``repro.core.schedule_lpt`` + ``repro.core.equalize``
remain available to materialize/rebuild a host schedule from the raw
decomposition.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .matching import get_matcher
from ..decompose import Decomposition


class JaxDecomposition(NamedTuple):
    perms: jax.Array   # (n, n) int32; row r = permutation of round r (padded)
    alphas: jax.Array  # (n,) float32; 0 for padded rounds
    k: jax.Array       # () int32: number of real rounds
    converged: jax.Array  # () bool: all matcher calls converged


def _decompose(
    D: jax.Array,
    *,
    use_kernel: bool,
    matcher: str,
    repair_rounds: int,
    carry_prices: bool,
    prices0: jax.Array | None,
) -> tuple[JaxDecomposition, jax.Array]:
    """Shared impl; returns ``(dec, final dual prices)``.

    ``carry_prices=True`` threads the matcher's column dual prices through
    the rounds (each round warm-starts from the previous round's finals)
    and seeds round 0 with ``prices0`` — the online controller's
    cross-period warm start. ``False`` reproduces the stateless behavior
    bit-for-bit (every round starts from zero prices).
    """
    match = get_matcher(matcher)
    D = D.astype(jnp.float32)
    n = D.shape[0]
    arange = jnp.arange(n)
    init_prices = (
        jnp.zeros((n,), jnp.float32)
        if prices0 is None
        else jnp.asarray(prices0, jnp.float32)
    )

    def cond(st):
        _, S_rem, _, _, i, _, _ = st
        return S_rem.any() & (i < n)

    def body(st):
        D_rem, S_rem, perms, alphas, i, conv, prices = st
        row_deg = S_rem.sum(axis=1)
        col_deg = S_rem.sum(axis=0)
        k = jnp.maximum(row_deg.max(), col_deg.max())
        crit_r = (row_deg == k) & (k > 0)
        crit_c = (col_deg == k) & (k > 0)
        base = jnp.maximum(D_rem, 0.0)
        # Dominance constant: any permutation serves at most the sum of row
        # maxima, so this M already forces the max bonus count. (Tighter
        # than sum(D)+1: auction prices scale with M, and float32 price
        # resolution — hence matcher convergence — improves as M shrinks.)
        # The bonus-level separation must dominate the matcher's n·ε
        # optimality slack, which scales with the weight magnitude (ε is
        # ulp-floored at wmax·2⁻²², wmax ≤ 3M) — hence the relative margin
        # on top of the absolute +1.
        M = (base.max(axis=1).sum() + 1.0) * (1.0 + n * 2.0**-19)
        bonus = M * (crit_r[:, None].astype(jnp.float32) + crit_c[None, :])
        W = base + jnp.where(S_rem, bonus, 0.0)
        if carry_prices:
            perm, ok, prices = match(
                W, use_kernel=use_kernel, prices0=prices, with_prices=True
            )
        else:
            perm, ok = match(W, use_kernel=use_kernel)
        newly = S_rem[arange, perm]
        # α = min D_rem over *newly covered* support, exactly the numpy
        # "covered_support" rule: a round that newly covers nothing gets α=0
        # (guarding on newly.any() keeps the inf mask from ever escaping).
        vals = jnp.where(newly, D_rem[arange, perm], jnp.inf)
        alpha = jnp.where(newly.any(), vals.min(), 0.0)
        D_rem = jnp.maximum(D_rem.at[arange, perm].add(-alpha), 0.0)
        S_rem = S_rem.at[arange, perm].set(False)
        perms = perms.at[i].set(perm.astype(jnp.int32))
        alphas = alphas.at[i].set(alpha)
        return D_rem, S_rem, perms, alphas, i + 1, conv & ok, prices

    init = (
        D,
        D > 0,
        jnp.broadcast_to(arange[None, :], (n, n)).astype(jnp.int32),
        jnp.zeros((n,), jnp.float32),
        jnp.int32(0),
        jnp.bool_(True),
        init_prices,
    )
    D_rem, S_rem, perms, alphas, k, conv, prices = jax.lax.while_loop(
        cond, body, init
    )

    cov_idx = (jnp.broadcast_to(arange[None, :], (n, n)), perms)
    round_live = (jnp.arange(n) < k)[:, None]

    def coverage(al):
        return jnp.zeros_like(D).at[cov_idx].add(al[:, None] * round_live)

    # Greedy REFINE (Alg. 2) over all rounds (padded rounds see zero residual).
    R0 = jnp.maximum(D - coverage(alphas), 0.0)

    def refine_body(r, carry):
        R, alphas = carry
        perm = perms[r]
        d = jnp.maximum(R[arange, perm].max(), 0.0)
        d = jnp.where(r < k, d, 0.0)
        alphas = alphas.at[r].add(d)
        R = R.at[arange, perm].add(-d)
        R = jnp.maximum(R, 0.0)
        return R, alphas

    _, alphas = jax.lax.fori_loop(0, n, refine_body, (R0, alphas))

    if repair_rounds:
        perms, alphas, k = _repair(
            D, perms, alphas, k, coverage, repair_rounds
        )
    dec = JaxDecomposition(perms=perms, alphas=alphas, k=k, converged=conv)
    return dec, prices


@functools.partial(
    jax.jit, static_argnames=("use_kernel", "matcher", "repair_rounds")
)
def decompose_jax(
    D: jax.Array,
    *,
    use_kernel: bool = False,
    matcher: str = "auction",
    repair_rounds: int = 0,
) -> JaxDecomposition:
    """Exactly-k decomposition of D (Alg. 1 + greedy REFINE), on device.

    ``matcher`` picks the device MWM solver from ``matching.MATCHERS``;
    ``repair_rounds`` bounds the post-REFINE local-search sweeps (0 keeps
    the paper-faithful Alg. 1+2 output bit-for-bit).
    """
    dec, _ = _decompose(
        D, use_kernel=use_kernel, matcher=matcher,
        repair_rounds=repair_rounds, carry_prices=False, prices0=None,
    )
    return dec


@functools.partial(
    jax.jit, static_argnames=("use_kernel", "matcher", "repair_rounds")
)
def decompose_jax_prices(
    D: jax.Array,
    prices0: jax.Array,
    *,
    use_kernel: bool = False,
    matcher: str = "auction",
    repair_rounds: int = 0,
) -> tuple[JaxDecomposition, jax.Array]:
    """Warm-started decomposition: seed the matcher's dual prices with
    ``prices0`` (e.g. the previous controller period's finals) and return
    ``(dec, final prices)`` so the caller can carry them forward. Requires
    a matcher that supports ``prices0``/``with_prices`` (both built-ins do).
    """
    return _decompose(
        D, use_kernel=use_kernel, matcher=matcher,
        repair_rounds=repair_rounds, carry_prices=True, prices0=prices0,
    )


def _repair(D, perms, alphas, k, coverage, repair_rounds: int):
    """Bounded local search on the refined weights (2-opt α re-extraction).

    REFINE only ever raises weights, so entries covered by several rounds
    end up over-provisioned. Each sweep walks the rounds and shrinks α_r by
    the minimum slack ``(Σ α P − D)`` along its permutation — the largest
    reduction that keeps coverage — wrapping the freed mass back into the
    makespan. Sweeps repeat (bounded by ``repair_rounds``) until a full
    pass changes nothing; rounds whose α hits zero are compacted to the
    tail and dropped from ``k`` so they stop costing δ.
    """
    n = D.shape[0]
    arange = jnp.arange(n)

    def sweep(carry):
        alphas, rounds_left, improved = carry
        slack = coverage(alphas) - D

        def one(r, c):
            slack, al = c
            perm = perms[r]
            d = jnp.minimum(slack[arange, perm].min(), al[r])
            d = jnp.where(r < k, jnp.maximum(d, 0.0), 0.0)
            al = al.at[r].add(-d)
            slack = slack.at[arange, perm].add(-d)
            return slack, al

        _, new = jax.lax.fori_loop(0, n, one, (slack, alphas))
        return new, rounds_left - 1, (new < alphas).any()

    alphas, _, _ = jax.lax.while_loop(
        lambda c: c[2] & (c[1] > 0),
        sweep,
        (alphas, jnp.int32(repair_rounds), jnp.bool_(True)),
    )
    # Compact: live rounds (α > 0) first in original order; dead rounds
    # join the padding so LPT/EQUALIZE slot accounting stays contiguous.
    live = (alphas > 0) & (jnp.arange(n) < k)
    order = jnp.argsort(~live, stable=True)
    return perms[order], jnp.where(live, alphas, 0.0)[order], live.sum()


@functools.partial(jax.jit, static_argnames=("s",))
def lpt_schedule_jax(dec: JaxDecomposition, s: int, delta: jax.Array):
    """Alg. 3 on device: returns (assignment (n,), loads (s,), makespan)."""
    n = dec.alphas.shape[0]
    valid = (jnp.arange(n) < dec.k) & (dec.alphas > 0)
    order = jnp.argsort(jnp.where(valid, -dec.alphas, jnp.inf))

    def place(loads, idx):
        a = dec.alphas[idx]
        is_real = jnp.take(valid, idx)
        h = jnp.argmin(loads)
        loads = jnp.where(is_real, loads.at[h].add(delta + a), loads)
        return loads, jnp.where(is_real, h, -1)

    loads, assignment_sorted = jax.lax.scan(place, jnp.zeros((s,), jnp.float32), order)
    assignment = jnp.full((n,), -1, jnp.int32).at[order].set(
        assignment_sorted.astype(jnp.int32)
    )
    return assignment, loads, loads.max()


def spectra_jax(
    D: jax.Array,
    s: int,
    delta: float,
    *,
    use_kernel: bool = False,
    matcher: str = "auction",
    repair_rounds: int = 0,
):
    """DECOMPOSE + LPT on device; returns (dec, assignment, loads, makespan)."""
    dec = decompose_jax(
        D, use_kernel=use_kernel, matcher=matcher, repair_rounds=repair_rounds
    )
    assignment, loads, makespan = lpt_schedule_jax(dec, s, jnp.float32(delta))
    return dec, assignment, loads, makespan


def to_decomposition(dec: JaxDecomposition) -> Decomposition:
    """Materialize on host as a numpy Decomposition (for EQUALIZE etc.).

    Zero-α rounds (possible after repair) are dropped — they carry no
    weight and would only add δ-cost configs to a host schedule.
    """
    import numpy as np

    k = int(dec.k)
    perms = np.asarray(dec.perms)[:k]
    alphas = np.asarray(dec.alphas)[:k]
    keep = alphas > 0
    return Decomposition(
        perms=[p.astype(np.int64) for p, kp in zip(perms, keep) if kp],
        alphas=[float(a) for a, kp in zip(alphas, keep) if kp],
    )
