"""Device (JAX) implementations of the SPECTRA pipeline stages.

Everything here operates on dense, fixed-shape arrays — the
``repro.core.schedule_ir.DeviceSchedule`` IR — so each stage jits and vmaps:

    matching          pluggable device MWM matchers (MATCHERS registry:
                      ε-scaling auction + forward-reverse auction)
    auction           legacy entry point for the "auction" matcher
    decompose_jax     Alg. 1 + greedy REFINE + optional repair sweeps;
                      device LPT (Alg. 3) telemetry
    equalize_jax      Alg. 4 (incl. merge-aware SPECTRA++) as lax.while_loop
    lower_bounds_jax  §IV bounds, vectorized over all 2n lines
    e2e               fused DECOMPOSE → SCHEDULE → EQUALIZE (+ LB), one call
    online_jax        stateful cross-period steps + the lax.scan rolling
                      solve (whole trace = one dispatch, switch state carry)
"""

from .auction import auction_maximize, auction_maximize_batch
from .matching import (
    MATCHERS,
    get_matcher,
    list_matchers,
    match_auction,
    match_auction_fr,
    register_matcher,
)
from .decompose_jax import (
    JaxDecomposition,
    decompose_jax,
    decompose_jax_prices,
    lpt_schedule_jax,
    spectra_jax,
    to_decomposition,
)
from .e2e import E2EResult, spectra_jax_e2e, spectra_jax_e2e_many
from .equalize_jax import equalize_ir, equalize_ir_jit, equalize_jax
from .lower_bounds_jax import lower_bound_jax, lower_bounds_many
from .online_jax import (
    OnlineDeviceState,
    OnlineStepResult,
    online_initial_state,
    online_step_jax,
    spectra_online_scan,
)

__all__ = [
    "E2EResult",
    "JaxDecomposition",
    "MATCHERS",
    "OnlineDeviceState",
    "OnlineStepResult",
    "auction_maximize",
    "auction_maximize_batch",
    "decompose_jax",
    "decompose_jax_prices",
    "get_matcher",
    "list_matchers",
    "match_auction",
    "match_auction_fr",
    "online_initial_state",
    "online_step_jax",
    "register_matcher",
    "equalize_ir",
    "equalize_ir_jit",
    "equalize_jax",
    "lower_bound_jax",
    "lower_bounds_many",
    "lpt_schedule_jax",
    "spectra_jax",
    "spectra_jax_e2e",
    "spectra_jax_e2e_many",
    "spectra_online_scan",
    "to_decomposition",
]
