"""§IV lower bounds as a jitted, vmappable JAX function.

Vectorized port of ``repro.core.lower_bounds``: all ``2n`` lines (rows then
columns) are bounded at once —

* Theorem 1 for every line:   ``(w_i + δ·max(k_i, s)) / s``
* Theorem 2 where ``k_i = s``: ``δ + min(x_1, max(x_2, (w+δ)/s, x_s+δ),
  min_m max(x_{m+1}, (w + m·δ)/s))`` with zero-padding beyond the s
  nonzeros — expressed as a dense ``(2n, s²−1)`` max/min instead of the
  host's per-line Python loop,

and Property 2 takes the max. ``lower_bound_jax`` composes into the fused
e2e pipeline (one device program attaches per-instance LBs to a whole
batch); ``lower_bounds_many`` is the standalone jitted batch entry point.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def lower_bound_jax(D: jax.Array, s: int, delta) -> jax.Array:
    """Scalar §IV lower bound for one (n, n) demand matrix (traceable)."""
    D = jnp.asarray(D, jnp.float32)
    delta = jnp.asarray(delta, jnp.float32)
    n = D.shape[0]
    lines = jnp.concatenate([D, D.T], axis=0)          # (2n, n)
    k = (lines > 0).sum(axis=1)                        # nonzeros per line
    w = lines.sum(axis=1)                              # line weight
    lb1 = (w + delta * jnp.maximum(k, s)) / s

    # Theorem 2 (lines with exactly s nonzeros). Sort descending; the zeros
    # that pad each line land at the tail, matching the host's x_j := 0 for
    # j > s. Pad columns out to s²+1 so x_{m+1} exists for every m ≤ s².
    x = -jnp.sort(-lines, axis=1)                      # (2n, n) descending
    width = max(n, s * s + 1)
    x = jnp.pad(x, ((0, 0), (0, width - n)))           # (2n, ≥s²+1)
    opt0 = x[:, 0]
    opt1 = jnp.maximum(
        jnp.maximum(x[:, 1], (w + delta) / s), x[:, s - 1] + delta
    )
    inner = jnp.minimum(opt0, opt1)
    if s >= 2:  # m ∈ [2, s²]: x_{m+1} is column index m (0-based)
        m = jnp.arange(2, s * s + 1)
        opts_m = jnp.maximum(x[:, m], (w[:, None] + m * delta) / s)
        inner = jnp.minimum(inner, opts_m.min(axis=1))
    lb2 = delta + inner

    per_line = jnp.where(k == s, jnp.maximum(lb1, lb2), lb1)
    per_line = jnp.where(k == 0, 0.0, per_line)        # empty lines bound nothing
    return per_line.max()


@functools.partial(jax.jit, static_argnames=("s",))
def lower_bounds_many(Ds: jax.Array, s: int, delta) -> jax.Array:
    """Per-instance §IV lower bounds for a stacked (B, n, n) batch, on device."""
    Ds = jnp.asarray(Ds, jnp.float32)
    return jax.vmap(lambda D: lower_bound_jax(D, s, delta))(Ds)
