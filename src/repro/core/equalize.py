"""EQUALIZE (Alg. 4): balance switch loads by controlled permutation splits.

Iteratively move a ``τ = (L_max − L_min − δ)/2`` slice of the longest
permutation on the most-loaded switch to the least-loaded switch (which pays
one extra reconfiguration δ for the new configuration), until the spread is
at most δ or the longest permutation is too short to split.

``merge_aware=True`` is a beyond-paper improvement (SPECTRA++): when the
moved permutation already exists on the target switch, its weight is merged
into the existing configuration — no extra δ — and the target load rises by
τ only (µ is computed accordingly).
"""

from __future__ import annotations

import numpy as np

from .schedule import ParallelSchedule


def equalize(
    sched: ParallelSchedule,
    *,
    merge_aware: bool = False,
    max_iters: int | None = None,
) -> ParallelSchedule:
    """Alg. 4, in place on ``sched`` (also returned for chaining)."""
    s = sched.s
    delta = sched.delta
    if s <= 1:
        return sched
    loads = sched.loads()
    if max_iters is None:
        max_iters = 64 * (sched.num_configs() + s) + 64
    for _ in range(max_iters):
        h_max = int(np.argmax(loads))
        h_min = int(np.argmin(loads))
        if loads[h_max] - loads[h_min] <= delta:
            break
        src = sched.switches[h_max]
        z = src.longest()
        if z < 0:
            break
        dst = sched.switches[h_min]
        merged = -1
        if merge_aware:
            for j, p in enumerate(dst.perms):
                if np.array_equal(p, src.perms[z]):
                    merged = j
                    break
        # Target load µ: average of the two loads including the extra δ the
        # destination pays for a brand-new configuration (none if merging).
        setup = 0.0 if merged >= 0 else delta
        mu = (loads[h_max] + loads[h_min] + setup) / 2.0
        tau = loads[h_max] - mu
        if tau <= 0 or src.alphas[z] <= tau:
            break
        src.alphas[z] -= tau
        if merged >= 0:
            dst.alphas[merged] += tau
        else:
            dst.perms.append(src.perms[z].copy())
            dst.alphas.append(tau)
        loads[h_max] -= tau
        loads[h_min] += setup + tau
    return sched
