"""EQUALIZE (Alg. 4): balance switch loads by controlled permutation splits.

Iteratively move a ``τ = (L_max − L_min − δ)/2`` slice of the longest
permutation on the most-loaded switch to the least-loaded switch (which pays
one extra reconfiguration δ for the new configuration), until the spread is
at most δ or the longest permutation is too short to split.

``merge_aware=True`` is a beyond-paper improvement (SPECTRA++): when the
moved permutation already exists on the target switch, its weight is merged
into the existing configuration — no extra δ — and the target load rises by
τ only (µ is computed accordingly).
"""

from __future__ import annotations

import numpy as np

from .schedule import ParallelSchedule


def perm_key(perm: np.ndarray) -> bytes:
    """Dtype-normalized hash key for a permutation (bytes of its int64
    array), so int32 device perms and int64 host perms with equal values
    hash alike — matching ``np.array_equal`` semantics. Shared by the
    merge-aware EQUALIZE lookup and the online subsystem's installed-state
    matching."""
    return np.ascontiguousarray(perm, dtype=np.int64).tobytes()


def equalize(
    sched: ParallelSchedule,
    *,
    merge_aware: bool = False,
    max_iters: int | None = None,
    load_offset: np.ndarray | None = None,
) -> ParallelSchedule:
    """Alg. 4, in place on ``sched`` (also returned for chaining).

    ``load_offset`` shifts each switch's *effective* load (online
    scheduling's reuse credit: a switch whose first configuration is
    already installed pays no δ for it, so its offset is −δ). Offsets are
    constant per switch — the credited configuration never leaves its
    switch (splits only shrink it) — so they simply bias the argmax/argmin
    choices and the target spread.
    """
    s = sched.s
    delta = sched.delta
    if s <= 1:
        return sched
    loads = sched.loads()
    if load_offset is not None:
        loads = loads + np.asarray(load_offset, dtype=np.float64)
    if max_iters is None:
        max_iters = 64 * (sched.num_configs() + s) + 64
    # Hash every permutation once (module-level perm_key) so the merge
    # lookup is O(1) per iteration instead of an O(configs) rescan of the
    # destination switch. setdefault keeps the first slot on duplicates,
    # matching the original first-match scan.
    tables: list[dict[bytes, int]] = []
    if merge_aware:
        for sw in sched.switches:
            table: dict[bytes, int] = {}
            for j, p in enumerate(sw.perms):
                table.setdefault(perm_key(p), j)
            tables.append(table)
    for _ in range(max_iters):
        h_max = int(np.argmax(loads))
        h_min = int(np.argmin(loads))
        if loads[h_max] - loads[h_min] <= delta:
            break
        src = sched.switches[h_max]
        z = src.longest()
        if z < 0:
            break
        dst = sched.switches[h_min]
        merged = -1
        if merge_aware:
            key = perm_key(src.perms[z])
            merged = tables[h_min].get(key, -1)
        # Target load µ: average of the two loads including the extra δ the
        # destination pays for a brand-new configuration (none if merging).
        setup = 0.0 if merged >= 0 else delta
        mu = (loads[h_max] + loads[h_min] + setup) / 2.0
        tau = loads[h_max] - mu
        if tau <= 0 or src.alphas[z] <= tau:
            break
        src.alphas[z] -= tau
        if merged >= 0:
            dst.alphas[merged] += tau
        else:
            dst.perms.append(src.perms[z].copy())
            dst.alphas.append(tau)
            if merge_aware:
                tables[h_min].setdefault(key, len(dst.perms) - 1)
        loads[h_max] -= tau
        loads[h_min] += setup + tau
    return sched
