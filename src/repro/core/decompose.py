"""DECOMPOSE (Alg. 1) + REFINE (Alg. 2): cover D with exactly k permutations.

``k = degree(D)`` (max nonzeros in any line) is both necessary and sufficient
(Property 1 / König's line-coloring theorem). Each round solves the
node-coverage-constrained MWM of :mod:`repro.core.matching`, guaranteeing the
degree of the uncovered support drops by one per round, and greedily serving
as much remaining demand as possible.

``alpha_mode``:
  * ``"covered_support"`` (default): ``α_i = min D_rem`` over the support
    entries this permutation *newly covers* (always > 0; reproduces the
    paper's worked example).
  * ``"all_matched"``: the literal Alg. 1 line 5 — min over **all** matched
    entries, which is 0 whenever the permutation crosses a zero of D_rem
    (REFINE then supplies all the weight).

REFINE:
  * ``"greedy"`` (default, Alg. 2): one pass raising each α by the max
    uncovered residual on its permutation; certifies coverage on exit.
  * ``"lp"``: the exact LP of Eq. (5) via scipy linprog (benchmark shows
    greedy ≈ LP, as the paper reports).
  * ``"signed"``: beyond-paper greedy on *signed* residuals — may also
    shrink over-provisioned weights (see improved.py; kept here so it can
    be A/B'd through the same entry point).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..obs.trace import span as _span
from .matching import mwm_node_coverage, perm_matrix


@dataclass
class Decomposition:
    """Weighted permutations covering a demand matrix."""

    perms: list[np.ndarray] = field(default_factory=list)  # each perm[i]=col
    alphas: list[float] = field(default_factory=list)

    @property
    def k(self) -> int:
        return len(self.perms)

    def total_weight(self) -> float:
        return float(sum(self.alphas))

    def coverage(self, n: int) -> np.ndarray:
        out = np.zeros((n, n), dtype=np.float64)
        rows = np.arange(n)
        for perm, a in zip(self.perms, self.alphas):
            out[rows, perm] += a
        return out

    def covers(self, D: np.ndarray, tol: float = 1e-9) -> bool:
        return bool(np.all(self.coverage(D.shape[0]) >= np.asarray(D) - tol))


def degree(D: np.ndarray) -> int:
    """Max number of nonzero elements in any row or column."""
    S = np.asarray(D) > 0
    if not S.any():
        return 0
    return int(max(S.sum(axis=1).max(), S.sum(axis=0).max()))


def refine_greedy(D: np.ndarray, alphas: list[float], perms: list[np.ndarray]) -> list[float]:
    """Alg. 2: greedily raise weights until the weighted sum covers D."""
    D = np.asarray(D, dtype=np.float64)
    n = D.shape[0]
    rows = np.arange(n)
    R = D.copy()
    for perm, a in zip(perms, alphas):
        R[rows, perm] -= a
    np.maximum(R, 0.0, out=R)  # remaining uncovered demand
    out = list(alphas)
    for i, perm in enumerate(perms):
        d = float(R[rows, perm].max())
        if d > 0.0:
            out[i] += d
            R[rows, perm] = np.maximum(0.0, R[rows, perm] - d)
    return out


def refine_signed(D: np.ndarray, alphas: list[float], perms: list[np.ndarray]) -> list[float]:
    """Beyond-paper REFINE on signed residuals: weights may also shrink.

    Safe: after processing P_i, ``max`` residual over its entries is 0, and
    later steps never push any residual above 0.
    """
    D = np.asarray(D, dtype=np.float64)
    n = D.shape[0]
    rows = np.arange(n)
    R = D.copy()
    for perm, a in zip(perms, alphas):
        R[rows, perm] -= a
    out = list(alphas)
    for i, perm in enumerate(perms):
        d = float(R[rows, perm].max())
        d = max(d, -out[i])  # weights must stay >= 0
        if d != 0.0:
            out[i] += d
            R[rows, perm] -= d
    return out


def refine_lp(D: np.ndarray, alphas: list[float], perms: list[np.ndarray]) -> list[float]:
    """Exact Eq. (5): min Σ α̂  s.t.  Σ α̂_i P_i ≥ D, α̂ ≥ 0."""
    from scipy.optimize import linprog
    from scipy.sparse import lil_matrix

    D = np.asarray(D, dtype=np.float64)
    n = D.shape[0]
    k = len(perms)
    nz = np.argwhere(D > 0)
    A = lil_matrix((len(nz), k))
    for c, (a, b) in enumerate(nz):
        for i, perm in enumerate(perms):
            if perm[a] == b:
                A[c, i] = -1.0  # -Σ α P ≤ -D
    res = linprog(
        c=np.ones(k),
        A_ub=A.tocsr(),
        b_ub=-D[nz[:, 0], nz[:, 1]],
        bounds=[(0, None)] * k,
        method="highs",
    )
    if not res.success:  # pragma: no cover - LP on feasible cover always solves
        return refine_greedy(D, alphas, perms)
    return [float(x) for x in res.x]


_REFINERS = {"greedy": refine_greedy, "lp": refine_lp, "signed": refine_signed}


def decompose(
    D: np.ndarray,
    *,
    alpha_mode: str = "covered_support",
    refine: str = "greedy",
    validate: bool = True,
) -> Decomposition:
    """Alg. 1: decompose D into exactly ``degree(D)`` weighted permutations."""
    D = np.asarray(D, dtype=np.float64)
    if D.ndim != 2 or D.shape[0] != D.shape[1]:
        raise ValueError(f"D must be square, got {D.shape}")
    if (D < 0).any():
        raise ValueError("D must be nonnegative")
    n = D.shape[0]
    rows = np.arange(n)
    S_rem = D > 0
    D_rem = D.copy()
    dec = Decomposition()
    k0 = degree(D)
    while S_rem.any():
        with _span("matcher"):
            perm = mwm_node_coverage(D_rem, S_rem, validate=validate)
        newly = S_rem[rows, perm]
        if alpha_mode == "covered_support":
            vals = D_rem[rows, perm][newly]
            alpha = float(vals.min()) if vals.size else 0.0
        elif alpha_mode == "all_matched":
            alpha = max(float(D_rem[rows, perm].min()), 0.0)
        else:
            raise ValueError(f"unknown alpha_mode {alpha_mode!r}")
        dec.perms.append(perm)
        dec.alphas.append(alpha)
        D_rem[rows, perm] -= alpha
        np.maximum(D_rem, 0.0, out=D_rem)
        S_rem[rows, perm] = False
        if len(dec.perms) > k0:  # pragma: no cover - Property 1 guarantee
            raise AssertionError("decomposition exceeded degree(D) rounds")
    dec.alphas = _REFINERS[refine](D, dec.alphas, dec.perms)
    if validate and not dec.covers(D):  # pragma: no cover - REFINE certifies
        raise AssertionError("refined decomposition does not cover D")
    return dec
