"""SPECTRA end-to-end pipeline: DECOMPOSE → SCHEDULE → EQUALIZE (§III).

``spectra(D, s, delta)`` is the paper-faithful algorithm. ``decompose_fn``
swaps the decomposition step (e.g. ECLIPSE for "SPECTRA (ECLIPSE)").
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import numpy as np

from .decompose import Decomposition, decompose
from .equalize import equalize
from .lower_bounds import lower_bound, optimality_gap
from .schedule import ParallelSchedule, schedule_lpt


@dataclass
class SpectraResult:
    schedule: ParallelSchedule
    decomposition: Decomposition
    makespan: float
    lower_bound: float
    runtime_s: float

    @property
    def optimality_gap(self) -> float:
        return optimality_gap(self.makespan, self.lower_bound)


def spectra(
    D: np.ndarray,
    s: int,
    delta: float,
    *,
    do_equalize: bool = True,
    merge_aware: bool = False,
    decompose_fn: Callable[..., Decomposition] | None = None,
    validate: bool = True,
    compute_lb: bool = True,
) -> SpectraResult:
    """Run the full SPECTRA pipeline on demand matrix D over s switches."""
    D = np.asarray(D, dtype=np.float64)
    t0 = time.perf_counter()
    if decompose_fn is None:
        dec = decompose(D)
    else:
        dec = decompose_fn(D)
    sched = schedule_lpt(dec, s, delta)
    if do_equalize:
        sched = equalize(sched, merge_aware=merge_aware)
    dt = time.perf_counter() - t0
    if validate:
        sched.validate(D)
    lb = lower_bound(D, s, delta) if compute_lb else float("nan")
    return SpectraResult(
        schedule=sched,
        decomposition=dec,
        makespan=sched.makespan(),
        lower_bound=lb,
        runtime_s=dt,
    )
