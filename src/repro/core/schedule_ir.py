"""Dense, fixed-shape schedule IR shared by the numpy and JAX paths.

``DeviceSchedule`` is the array-of-slots mirror of ``ParallelSchedule``: a
padded slot table (permutation row, weight, owning switch) whose shapes are
static, so the *whole* SPECTRA pipeline — DECOMPOSE → SCHEDULE → EQUALIZE —
can run inside one jitted, ``vmap``-able device call and only materialize
Python-object schedules on demand.

Layout (capacity R, fabric size n):

    perms  (R, n) int32   slot r serves port i → perms[r, i]; padded rows
                          hold an arbitrary permutation (identity)
    alphas (R,)   float   slot duration; 0 for free slots
    switch (R,)   int32   owning switch id, or -1 for free slots
    delta  ()     float   reconfiguration delay

Live slots are exactly ``switch >= 0`` and are packed at the front; free
slots at the tail are headroom for EQUALIZE splits (each split consumes one
slot). The number of switches ``s`` is *not* stored — it is a static shape
parameter of every consumer, exactly like ``n``.

This module is backend-neutral: the converters here are plain numpy and the
NamedTuple happily carries either numpy or JAX arrays, so
``repro.core.jaxopt`` (device kernels), ``repro.api.jax_backend`` (batched
solving), and host tooling all share one definition instead of re-deriving
padded layouts locally.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import numpy as np

from .schedule import ParallelSchedule, SwitchSchedule


class DeviceSchedule(NamedTuple):
    """Fixed-shape slot table for a parallel-OCS schedule (see module doc)."""

    perms: Any   # (R, n) int32
    alphas: Any  # (R,) float
    switch: Any  # (R,) int32; -1 = free slot
    delta: Any   # () float

    @property
    def capacity(self) -> int:
        return int(self.perms.shape[-2])

    @property
    def n(self) -> int:
        return int(self.perms.shape[-1])


def schedule_to_ir(
    sched: ParallelSchedule, n: int, *, capacity: int | None = None
) -> DeviceSchedule:
    """Flatten a host ``ParallelSchedule`` into a packed ``DeviceSchedule``.

    ``capacity`` defaults to ``num_configs + n + 64`` so the IR ships usable
    headroom for device EQUALIZE splits.
    """
    slots = [
        (np.asarray(perm), float(a), h)
        for h, sw in enumerate(sched.switches)
        for perm, a in zip(sw.perms, sw.alphas)
    ]
    if capacity is None:
        capacity = len(slots) + n + 64
    if capacity < len(slots):
        raise ValueError(f"capacity {capacity} < {len(slots)} live slots")
    perms = np.broadcast_to(np.arange(n, dtype=np.int32), (capacity, n)).copy()
    alphas = np.zeros((capacity,), dtype=np.float64)
    switch = np.full((capacity,), -1, dtype=np.int32)
    for r, (perm, a, h) in enumerate(slots):
        perms[r] = perm
        alphas[r] = a
        switch[r] = h
    return DeviceSchedule(
        perms=perms, alphas=alphas, switch=switch, delta=float(sched.delta)
    )


def ir_to_schedule(ds: DeviceSchedule, s: int) -> ParallelSchedule:
    """Materialize a host ``ParallelSchedule`` from (possibly device) arrays."""
    perms = np.asarray(ds.perms)
    alphas = np.asarray(ds.alphas, dtype=np.float64)
    switch = np.asarray(ds.switch)
    switches = [SwitchSchedule() for _ in range(s)]
    for r in np.flatnonzero(switch >= 0):
        h = int(switch[r])
        if h >= s:
            raise ValueError(f"slot {r} assigned to switch {h} but s={s}")
        switches[h].perms.append(perms[r].astype(np.int64))
        switches[h].alphas.append(float(alphas[r]))
    return ParallelSchedule(switches=switches, delta=float(ds.delta))


def ir_loads(ds: DeviceSchedule, s: int) -> np.ndarray:
    """Per-switch loads (Σα + δ·configs) computed directly on the slot table."""
    alphas = np.asarray(ds.alphas, dtype=np.float64)
    switch = np.asarray(ds.switch)
    live = switch >= 0
    loads = np.zeros((s,), dtype=np.float64)
    np.add.at(loads, switch[live], alphas[live] + float(ds.delta))
    return loads


def ir_makespan(ds: DeviceSchedule, s: int) -> float:
    return float(ir_loads(ds, s).max()) if s else 0.0


def ir_num_configs(ds: DeviceSchedule) -> int:
    return int((np.asarray(ds.switch) >= 0).sum())


def ir_coverage(ds: DeviceSchedule) -> np.ndarray:
    """Σ α_r · P_r over live slots — the Eq. 3 left-hand side."""
    perms = np.asarray(ds.perms)
    alphas = np.asarray(ds.alphas, dtype=np.float64)
    switch = np.asarray(ds.switch)
    n = perms.shape[-1]
    out = np.zeros((n, n), dtype=np.float64)
    rows = np.arange(n)
    for r in np.flatnonzero(switch >= 0):
        out[rows, perms[r]] += alphas[r]
    return out


class LazySchedule(ParallelSchedule):
    """A ``ParallelSchedule`` that materializes from a thunk on first use.

    The batched JAX backend solves whole stacks on device and returns one of
    these per instance: device results (makespan, slot counts) are available
    immediately, while the Python-object switch lists are only built when
    something actually touches them (validation, simulation, inspection).
    ``isinstance(x, ParallelSchedule)`` holds, so every existing consumer —
    ``equalize``, the event simulator, benchmarks — works unchanged.
    """

    def __init__(self, factory: Callable[[], ParallelSchedule], delta: float):
        # Deliberately skip the dataclass __init__: `switches` is a property.
        object.__setattr__(self, "_factory", factory)
        object.__setattr__(self, "_inner", None)
        object.__setattr__(self, "_delta", float(delta))

    @property
    def materialized(self) -> bool:
        return self._inner is not None

    def _force(self) -> ParallelSchedule:
        if self._inner is None:
            object.__setattr__(self, "_inner", self._factory())
        return self._inner

    @property
    def switches(self):  # type: ignore[override]
        return self._force().switches

    @property
    def delta(self) -> float:  # type: ignore[override]
        return self._delta

    def __repr__(self) -> str:
        state = repr(self._inner) if self.materialized else "unmaterialized"
        return f"LazySchedule({state})"
