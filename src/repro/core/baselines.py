"""Comparison algorithms from the paper's evaluation (§V-A).

* BASELINE — the LESS-style [9] split-then-schedule approach: split D into s
  sub-matrices maximizing sparsity under line-sum balance, then decompose
  each sub-matrix independently with our DECOMPOSE (the paper does the same
  for an apples-to-apples comparison) and take the max per-switch makespan.

* ECLIPSE [6] — state-of-the-art single-switch decomposition with
  reconfiguration delays: repeatedly pick the (permutation, duration) pair
  maximizing covered-demand-per-unit-time ``Σ min(D_rem, α·P) / (α + δ)``
  over a geometric α-grid (one unconstrained MWM per candidate α).
  "SPECTRA (ECLIPSE)" = this decomposition + our SCHEDULE + EQUALIZE.
"""

from __future__ import annotations

import numpy as np

from .decompose import Decomposition, decompose, refine_greedy
from .matching import max_weight_perfect_matching
from .schedule import ParallelSchedule, SwitchSchedule


# ---------------------------------------------------------------------------
# BASELINE: LESS-style sparsity-maximizing split into s sub-matrices.
# ---------------------------------------------------------------------------

def less_split(D: np.ndarray, s: int) -> list[np.ndarray]:
    """Split D into s sub-matrices, keeping elements whole where possible.

    Elements are placed in descending weight; each goes whole to the switch
    with the most remaining line budget (budget = max line sum of D over s,
    the balance criterion), splitting across switches only on overflow.
    Keeping big elements whole minimizes the total number of nonzeros across
    the sub-matrices — LESS's sparsity objective.
    """
    D = np.asarray(D, dtype=np.float64)
    n = D.shape[0]
    T = float(max(D.sum(axis=1).max(), D.sum(axis=0).max()))
    budget = T / s + 1e-12
    parts = [np.zeros_like(D) for _ in range(s)]
    row_load = np.zeros((s, n))
    col_load = np.zeros((s, n))
    order = np.argsort(-D, axis=None, kind="stable")
    for flat in order:
        a, b = divmod(int(flat), n)
        v = D[a, b]
        if v <= 0:
            break
        # Remaining budget per switch for this element's row and column.
        room = np.minimum(budget - row_load[:, a], budget - col_load[:, b])
        h = int(np.argmax(room))
        if room[h] >= v - 1e-12:
            placed = [(h, v)]
        else:
            # Overflow: split across switches in descending-room order.
            placed = []
            rem = v
            for h in np.argsort(-room):
                take = min(rem, max(room[h], 0.0))
                if take <= 0:
                    continue
                placed.append((int(h), float(take)))
                rem -= take
                if rem <= 1e-12:
                    break
            if rem > 1e-12:  # budgets exhausted by fp slack; dump remainder
                placed.append((int(np.argmax(room)), float(rem)))
        for h, val in placed:
            parts[h][a, b] += val
            row_load[h, a] += val
            col_load[h, b] += val
    return parts


def baseline_less(D: np.ndarray, s: int, delta: float) -> ParallelSchedule:
    """BASELINE: LESS split + per-switch DECOMPOSE; no cross-switch balance."""
    parts = less_split(D, s)
    switches = []
    for Dh in parts:
        if (Dh > 0).any():
            dec = decompose(Dh)
            switches.append(SwitchSchedule(perms=dec.perms, alphas=dec.alphas))
        else:
            switches.append(SwitchSchedule())
    return ParallelSchedule(switches=switches, delta=delta)


# ---------------------------------------------------------------------------
# ECLIPSE decomposition.
# ---------------------------------------------------------------------------

def _alpha_grid(D_rem: np.ndarray, base: float = 2.0, max_points: int = 16) -> np.ndarray:
    hi = float(D_rem.max())
    pos = D_rem[D_rem > 0]
    lo = float(pos.min())
    if hi <= 0:
        return np.array([])
    if lo >= hi:
        return np.array([hi])
    num = min(max_points, max(2, int(np.ceil(np.log(hi / lo) / np.log(base))) + 1))
    return np.geomspace(lo, hi, num=num)


def eclipse_decompose(
    D: np.ndarray,
    delta: float,
    *,
    coverage_tol: float = 1e-6,
    max_perms: int = 4096,
) -> Decomposition:
    """ECLIPSE-style greedy submodular cover with reconfiguration cost."""
    D = np.asarray(D, dtype=np.float64)
    n = D.shape[0]
    rows = np.arange(n)
    D_rem = D.copy()
    total = float(D.sum())
    dec = Decomposition()
    stall = 0
    while D_rem.sum() > coverage_tol * max(total, 1e-30) and len(dec.perms) < max_perms:
        best_score, best_alpha, best_perm = -1.0, None, None
        grid = _alpha_grid(D_rem)
        if stall >= 2:  # guard: force full service of the heaviest matching
            grid = np.array([float(D_rem.max())])
        for alpha in grid:
            W = np.minimum(D_rem, alpha)
            perm = max_weight_perfect_matching(W)
            val = float(W[rows, perm].sum())
            score = val / (alpha + delta)
            if score > best_score:
                best_score, best_alpha, best_perm = score, float(alpha), perm
        if best_perm is None:  # pragma: no cover
            break
        served = np.minimum(D_rem[rows, best_perm], best_alpha)
        progressed = float(served.sum()) > 0
        stall = 0 if progressed else stall + 1
        dec.perms.append(best_perm)
        dec.alphas.append(best_alpha)
        D_rem[rows, best_perm] -= best_alpha
        np.maximum(D_rem, 0.0, out=D_rem)
    # Top up: guarantee full coverage (the makespan objective requires it).
    if (D_rem > 0).any():
        tail = decompose(D_rem)
        dec.perms.extend(tail.perms)
        dec.alphas.extend(tail.alphas)
    dec.alphas = refine_greedy(D, dec.alphas, dec.perms)
    return dec


# ---------------------------------------------------------------------------
# ROTOR: demand-oblivious round-robin permutation sequences (RotorNet-style).
# ---------------------------------------------------------------------------

def rotor_offsets(
    n: int, s: int, *, include_identity: bool = False
) -> list[list[int]]:
    """Round-robin assignment of cyclic-shift offsets to s switches.

    The full rotor cycle is the n−1 cyclic shifts ``src → (src+k) mod n``
    for k = 1..n−1 (every ordered pair of distinct ports is connected by
    exactly one shift); switch h serves offsets ``h, h+s, h+2s, …`` of
    that sequence. ``include_identity`` prepends k = 0 — only needed when
    the demand has intra-rack (diagonal) entries, which only the identity
    configuration can serve.
    """
    if n < 2:
        raise ValueError(f"need at least two ports, got n={n}")
    if s < 1:
        raise ValueError(f"need at least one switch, got s={s}")
    offs = ([0] if include_identity else []) + list(range(1, n))
    return [offs[h::s] for h in range(s)]


def rotor_schedule(
    n: int,
    s: int,
    delta: float,
    slot: float,
    *,
    cycles: int = 1,
    include_identity: bool = False,
) -> ParallelSchedule:
    """Fixed round-robin rotor schedule: no matching solves, equal slots.

    Each switch cycles through its ``rotor_offsets`` shifts ``cycles``
    times, serving every configuration for exactly ``slot`` time units
    (paying δ before each — a rotor reconfigures blindly, it has no
    demand knowledge to reuse circuits with). Per full cycle, every
    ordered port pair gets exactly ``slot`` units of direct service, so
    the per-switch load — and the makespan, since the assignment is
    perfectly balanced up to one slot — has the closed form

        makespan = max_h |offsets_h| · cycles · (slot + δ).
    """
    if slot < 0:
        raise ValueError(f"slot must be nonnegative, got {slot}")
    if cycles < 1:
        raise ValueError(f"need at least one cycle, got {cycles}")
    base = np.arange(n)
    switches = []
    for offs in rotor_offsets(n, s, include_identity=include_identity):
        sw = SwitchSchedule()
        for _ in range(cycles):
            for k in offs:
                sw.perms.append((base + k) % n)
                sw.alphas.append(float(slot))
        switches.append(sw)
    return ParallelSchedule(switches=switches, delta=delta)
