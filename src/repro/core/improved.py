"""SPECTRA++ — beyond-paper improvements (DESIGN.md §5).

Each knob is measured against paper-faithful SPECTRA on the paper's own
workloads in ``benchmarks/improved_table.py``; the combined best-of variant
is ``spectra_pp``.

1. merge-aware EQUALIZE       (equalize.py, merge_aware=True)
2. post-LPT local search      (move/swap before any splitting)
3. signed-residual REFINE     (decompose.py, refine="signed")
4. wrap-around scheduler      (binary-search makespan T; McNaughton-style
                               wrap filling with a δ setup per segment)
"""

from __future__ import annotations

import time

import numpy as np

from .decompose import Decomposition, decompose, refine_signed  # noqa: F401
from .equalize import equalize
from .lower_bounds import lower_bound
from .schedule import ParallelSchedule, SwitchSchedule, schedule_lpt
from .spectra import SpectraResult


def local_search(sched: ParallelSchedule, max_rounds: int = 64) -> ParallelSchedule:
    """Move/swap whole permutations between switches to shrink the makespan.

    Greedy first-improvement: try moving any job off the most-loaded switch,
    then try swapping a long job on it with a shorter job elsewhere.
    """
    delta = sched.delta
    for _ in range(max_rounds):
        loads = sched.loads()
        h_max = int(np.argmax(loads))
        src = sched.switches[h_max]
        improved = False
        # Moves.
        for z in range(len(src.alphas)):
            cost = delta + src.alphas[z]
            for h, sw in enumerate(sched.switches):
                if h == h_max:
                    continue
                new_max_candidates = [loads[h] + cost, loads[h_max] - cost]
                others = [loads[g] for g in range(sched.s) if g not in (h, h_max)]
                if max(new_max_candidates + others) < loads[h_max] - 1e-15:
                    sw.perms.append(src.perms[z])
                    sw.alphas.append(src.alphas[z])
                    del src.perms[z], src.alphas[z]
                    improved = True
                    break
            if improved:
                break
        if improved:
            continue
        # Swaps.
        for z in range(len(src.alphas)):
            az = src.alphas[z]
            for h, sw in enumerate(sched.switches):
                if h == h_max:
                    continue
                for y in range(len(sw.alphas)):
                    ay = sw.alphas[y]
                    if ay >= az:
                        continue
                    d = az - ay
                    others = [loads[g] for g in range(sched.s) if g not in (h, h_max)]
                    if max([loads[h] + d, loads[h_max] - d] + others) < loads[h_max] - 1e-15:
                        src.perms[z], sw.perms[y] = sw.perms[y], src.perms[z]
                        src.alphas[z], sw.alphas[y] = ay, az
                        improved = True
                        break
                if improved:
                    break
            if improved:
                break
        if not improved:
            break
    return sched


def _wrap_fill(dec: Decomposition, s: int, delta: float, T: float) -> ParallelSchedule | None:
    """Try to fit all jobs within makespan T by wrap-around filling.

    Jobs are laid longest-first; each segment placed on a switch costs δ +
    its slice. A job is split when the current switch fills up; the
    continuation pays a fresh δ on the next switch. Returns None if > s
    switches would be needed.
    """
    order = np.argsort(-np.asarray(dec.alphas), kind="stable")
    switches = [SwitchSchedule()]
    cap = T
    for i in order:
        rem = float(dec.alphas[i])
        perm = dec.perms[i]
        while rem > 1e-15:
            room = cap - delta
            if room <= 1e-15:
                switches.append(SwitchSchedule())
                cap = T
                if len(switches) > s:
                    return None
                continue
            take = min(rem, room)
            switches[-1].perms.append(perm)
            switches[-1].alphas.append(take)
            cap -= delta + take
            rem -= take
            if rem > 1e-15:
                switches.append(SwitchSchedule())
                cap = T
                if len(switches) > s:
                    return None
    while len(switches) < s:
        switches.append(SwitchSchedule())
    return ParallelSchedule(switches=switches, delta=delta)


def schedule_wrap(dec: Decomposition, s: int, delta: float, iters: int = 40) -> ParallelSchedule:
    """Binary-search the minimum wrap-around makespan."""
    total = float(sum(dec.alphas)) + delta * dec.k
    lo = max(total / s, max(dec.alphas, default=0.0) * 0 + delta)
    hi = total + delta
    best = _wrap_fill(dec, s, delta, hi)
    assert best is not None
    for _ in range(iters):
        mid = (lo + hi) / 2.0
        cand = _wrap_fill(dec, s, delta, mid)
        if cand is not None and cand.makespan() <= mid + 1e-12:
            best, hi = cand, mid
        else:
            lo = mid
    return best


def spectra_pp(
    D: np.ndarray,
    s: int,
    delta: float,
    *,
    validate: bool = True,
    compute_lb: bool = True,
) -> SpectraResult:
    """Best-of SPECTRA++.

    One DECOMPOSE (the expensive part), two weight refinements (greedy and
    signed — same permutations, different α), three schedulers each
    (paper-faithful LPT+EQUALIZE, LPT + local search + merge-aware EQUALIZE,
    wrap-around binary search); returns the best schedule. Including the
    paper-faithful candidate guarantees SPECTRA++ ≤ SPECTRA.
    """
    D = np.asarray(D, dtype=np.float64)
    t0 = time.perf_counter()
    dec = decompose(D)  # greedy-refined (paper-faithful weights)
    dec_signed = Decomposition(dec.perms, refine_signed(D, dec.alphas, dec.perms))
    cands = [equalize(schedule_lpt(dec, s, delta))]  # paper-faithful
    for d in (dec, dec_signed):
        sched = schedule_lpt(d, s, delta)
        sched = local_search(sched)
        sched = equalize(sched, merge_aware=True)
        cands.append(sched)
        cands.append(schedule_wrap(d, s, delta))
    best = min(cands, key=lambda sc: sc.makespan())
    dt = time.perf_counter() - t0
    if validate:
        best.validate(D)
    lb = lower_bound(D, s, delta) if compute_lb else float("nan")
    return SpectraResult(
        schedule=best,
        decomposition=dec,
        makespan=best.makespan(),
        lower_bound=lb,
        runtime_s=dt,
    )
