"""Cross-period switch state and reuse-credit accounting (host side).

``SwitchState`` is what the online controller carries between controller
periods: the permutation each OCS left *installed* at the end of the
previous period, plus the previous period's decomposition (the warm-start
seed). A period's schedule whose first configuration on a switch equals
that switch's installed permutation serves it with **zero** reconfiguration
delay — the circuit is already up — which is the reuse credit the whole
online subsystem monetizes.

Serve order convention (shared with the device path in
``repro.core.jaxopt.online_jax``): each switch serves its carried
configuration first (δ-free), then the remaining configurations in slot
order, EQUALIZE splits last. ``effective_loads``/``effective_makespan``
price exactly that order; ``repro.fabric.simulator.simulate(...,
installed=...)`` replays and verifies it event by event.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.equalize import perm_key
from ..core.schedule import ParallelSchedule, SwitchSchedule
from ..core.schedule_ir import DeviceSchedule

__all__ = [
    "SwitchState", "advance_installed", "apply_reuse_order",
    "effective_loads", "effective_makespan", "online_ir_to_schedule",
    "perm_key", "reuse_marks",
]


@dataclass
class SwitchState:
    """Per-OCS installed configuration carried between controller periods."""

    installed: list[np.ndarray | None]  # per switch; None = never configured
    prev_perms: list[np.ndarray] = field(default_factory=list)
    prices: np.ndarray | None = None    # device matcher dual-price carry
    # Σα / max-line-sum of the last FRESH decomposition — the scale-free
    # quality reference gating warm-start acceptance (see controller).
    fresh_ratio: float | None = None
    # makespan / §IV-lower-bound of the last FRESH (or donated-baseline)
    # period — the outcome-level warm gate reference (see controller).
    fresh_gap: float | None = None
    # Support-pattern → (permutation set, fresh ratio): the matching cache.
    # Carried on the state so it survives the per-call controllers of the
    # spectra_online registry solver (sessions thread the whole state).
    support_cache: dict = field(default_factory=dict)

    @classmethod
    def initial(cls, s: int) -> "SwitchState":
        if s < 1:
            raise ValueError(f"need at least one switch, got s={s}")
        return cls(installed=[None] * s)

    @property
    def s(self) -> int:
        return len(self.installed)

    def installed_keys(self) -> list[bytes | None]:
        return [
            perm_key(p) if p is not None else None for p in self.installed
        ]


def reuse_marks(
    sched: ParallelSchedule, state: SwitchState
) -> np.ndarray:
    """Per-switch flags: switch h holds a configuration equal to its
    installed permutation (the first such, served δ-free)."""
    keys = state.installed_keys()
    marks = np.zeros(sched.s, dtype=bool)
    for h, sw in enumerate(sched.switches):
        if keys[h] is None:
            continue
        marks[h] = any(perm_key(p) == keys[h] for p in sw.perms)
    return marks


def effective_loads(
    sched: ParallelSchedule, marks: np.ndarray
) -> np.ndarray:
    """Switch loads under the reuse credit: −δ on every marked switch."""
    return sched.loads() - sched.delta * np.asarray(marks, dtype=np.float64)


def effective_makespan(sched: ParallelSchedule, state: SwitchState) -> float:
    marks = reuse_marks(sched, state)
    loads = effective_loads(sched, marks)
    return float(loads.max()) if len(loads) else 0.0


def apply_reuse_order(
    sched: ParallelSchedule, state: SwitchState
) -> tuple[ParallelSchedule, np.ndarray]:
    """Rebuild ``sched`` in reuse serve order: on each marked switch the
    first configuration matching the installed permutation moves to the
    front (everything else keeps its relative order). Returns the new
    schedule plus the per-switch reuse marks. The input is not mutated —
    permutation arrays are shared, lists are fresh."""
    keys = state.installed_keys()
    switches: list[SwitchSchedule] = []
    marks = np.zeros(sched.s, dtype=bool)
    for h, sw in enumerate(sched.switches):
        perms = list(sw.perms)
        alphas = [float(a) for a in sw.alphas]
        if keys[h] is not None:
            for j, p in enumerate(perms):
                if perm_key(p) == keys[h]:
                    perms.insert(0, perms.pop(j))
                    alphas.insert(0, alphas.pop(j))
                    marks[h] = True
                    break
        switches.append(SwitchSchedule(perms=perms, alphas=alphas))
    return ParallelSchedule(switches=switches, delta=sched.delta), marks


def advance_installed(
    sched: ParallelSchedule, state: SwitchState, marks: np.ndarray
) -> list[np.ndarray | None]:
    """Next period's installed permutations: the last configuration each
    switch serves. A switch serving only its carried configuration — or
    nothing at all — keeps its previous state. ``sched`` must already be in
    reuse serve order (``apply_reuse_order``)."""
    out: list[np.ndarray | None] = []
    for h, sw in enumerate(sched.switches):
        served = sw.perms[1:] if marks[h] else list(sw.perms)
        if served:
            out.append(np.asarray(served[-1]))
        else:
            out.append(state.installed[h])
    return out


def online_ir_to_schedule(
    ds: DeviceSchedule, s: int, reused: np.ndarray
) -> tuple[ParallelSchedule, np.ndarray]:
    """Materialize a device online slot table as a host schedule in reuse
    serve order. ``reused`` is the (R,) slot mask from the device step;
    marked slots move to the front of their switch's list. Returns the
    schedule plus per-switch reuse flags."""
    perms = np.asarray(ds.perms)
    alphas = np.asarray(ds.alphas, dtype=np.float64)
    switch = np.asarray(ds.switch)
    reused = np.asarray(reused, dtype=bool)
    switches = [SwitchSchedule() for _ in range(s)]
    marks = np.zeros(s, dtype=bool)
    order = np.flatnonzero(switch >= 0)
    # Reused slots first (at most one per switch), then slot-index order.
    order = np.concatenate([order[reused[order]], order[~reused[order]]])
    for r in order:
        h = int(switch[r])
        if h >= s:
            raise ValueError(f"slot {r} assigned to switch {h} but s={s}")
        switches[h].perms.append(perms[r].astype(np.int64))
        switches[h].alphas.append(float(alphas[r]))
        marks[h] = marks[h] or bool(reused[r])
    return ParallelSchedule(switches=switches, delta=float(ds.delta)), marks
