"""Online cross-period scheduling: carry switch state across a trace.

The stateless solvers re-pay the reconfiguration delay δ for every
configuration every controller period. This subsystem makes the controller
*stateful*: each OCS's installed permutation is carried between periods, a
round matching it is served first with zero δ (reuse credit), the previous
period's permutation set warm-starts the next decomposition, and — on the
JAX backend — the whole trace rolls through one ``lax.scan`` dispatch.

    from repro.scenarios import run_scenario
    rep = run_scenario("gpt", solver="spectra", online=True)
    print(rep.online_summary())          # reuse, δ avoided, makespan ratio

    from repro.online import OnlineController
    ctl = OnlineController(s=4, delta=0.01)
    for D in demands:                    # stateful host loop
        out = ctl.step(D)

Registry names (usable through ``repro.api.solve`` with the state threaded
via ``SolveOptions.extra["online"]``): ``spectra_online`` (host),
``spectra_online_jax`` (device). The device rolling solve is
``repro.core.jaxopt.online_jax.spectra_online_scan``.
"""

from .controller import OnlineController, OnlinePeriodOutcome
from .state import (
    SwitchState,
    advance_installed,
    apply_reuse_order,
    effective_loads,
    effective_makespan,
    online_ir_to_schedule,
    reuse_marks,
)

from . import solvers  # noqa: F401  (registers spectra_online[_jax])

__all__ = [
    "OnlineController",
    "OnlinePeriodOutcome",
    "SwitchState",
    "advance_installed",
    "apply_reuse_order",
    "effective_loads",
    "effective_makespan",
    "online_ir_to_schedule",
    "reuse_marks",
]
