"""The host online controller: stateful cross-period scheduling.

``OnlineController.step`` schedules one controller period *statefully*:

1. **Warm-start decomposition** — re-REFINE the previous period's
   permutation set against the new demand (one greedy pass, zero matching
   solves). If the old set still covers the new support — the common case
   for periodic AI training traffic — the expensive per-round MWM of a
   fresh DECOMPOSE is skipped entirely. The support-pattern **matching
   cache** extends this beyond strict period adjacency: decompositions are
   memoized by support pattern, so a workload cycling through a few phases
   re-uses each phase's permutation set whenever that phase comes round
   again.
2. **Reuse-then-LPT** — each switch first claims a round equal to its
   installed permutation (served first, δ-free), the rest is plain LPT on
   the credited loads.
3. **Credit-aware EQUALIZE** — Alg. 4 with a −δ load offset on switches
   holding a carried configuration.
4. **Best-of selection** — the stateless schedule (computed here, or passed
   in from a batched stateless run) with the reuse credit applied post-hoc
   is always a candidate, so the chosen effective makespan is ≤ the
   stateless makespan **by construction**.
5. **State advance** — each switch's installed permutation becomes the last
   configuration it served.

This mirrors ``repro.core.jaxopt.online_jax`` (the device ``lax.scan``
rolling solve) policy-for-policy; the device path is the production hot
path, this is the exact float64 reference and the numpy-solver path.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from ..core.decompose import Decomposition, decompose, refine_greedy
from ..core.equalize import equalize
from ..core.schedule import ParallelSchedule, SwitchSchedule
from .state import (
    SwitchState,
    advance_installed,
    apply_reuse_order,
    effective_loads,
    perm_key,
    reuse_marks,
)


@dataclass
class OnlinePeriodOutcome:
    """One stateful period: the chosen schedule plus reuse accounting."""

    schedule: ParallelSchedule     # reuse serve order (carried config first)
    reused_switches: np.ndarray    # (s,) bool — switches serving δ-free first
    makespan: float                # credit-aware (effective) makespan
    stateless_makespan: float      # the stateless reference for this period
    reuse_count: int               # switches with a carried configuration
    delta_paid: float              # δ · (configs − reuse_count)
    delta_avoided: float           # δ · reuse_count
    warm: bool                     # warm-start decomposition used
    num_configs: int
    extras: dict[str, Any] = field(default_factory=dict)

    @property
    def improvement(self) -> float:
        """stateless − online makespan (≥ 0 by construction)."""
        return self.stateless_makespan - self.makespan


def _line_sum(D: np.ndarray) -> float:
    return float(max(D.sum(axis=0).max(initial=0.0),
                     D.sum(axis=1).max(initial=0.0)))


def _warm_decomposition(
    D: np.ndarray,
    prev_perms: list[np.ndarray],
    ref_ratio: float | None,
    slack: float,
    tol: float = 1e-9,
) -> Decomposition | None:
    """Previous period's permutation set re-REFINEd onto ``D`` — or None
    when the old set no longer covers the new support OR fails the quality
    gate.

    Coverage alone does not bound quality: re-REFINE along a *stale*
    permutation set can badly over-provision when weights drift. Σα /
    max-line-sum is scale-free and ≥ 1 for any cover, so the warm set is
    accepted only when its ratio stays within ``slack`` of the last fresh
    decomposition's (``ref_ratio``) and its round count doesn't exceed
    ``degree(D)`` (a fresh decomposition's exact k).
    """
    if not prev_perms:
        return None
    alphas = refine_greedy(D, [0.0] * len(prev_perms), prev_perms)
    cov = np.zeros_like(D)
    rows = np.arange(D.shape[0])
    for perm, a in zip(prev_perms, alphas):
        cov[rows, perm] += a
    if (D - cov).max() > tol * max(float(D.max()), 1.0):
        return None
    keep = [(p, a) for p, a in zip(prev_perms, alphas) if a > 0]
    from ..core.decompose import degree

    if len(keep) > degree(D):
        return None
    if ref_ratio is not None:
        L = _line_sum(D)
        warm_ratio = sum(a for _, a in keep) / L if L > 0 else 0.0
        if warm_ratio > ref_ratio * (1.0 + slack):
            return None
    return Decomposition(
        perms=[p for p, _ in keep], alphas=[a for _, a in keep]
    )


def _reuse_then_lpt(
    dec: Decomposition, state: SwitchState, s: int, delta: float
) -> tuple[ParallelSchedule, np.ndarray]:
    """Reuse-aware Alg. 3 (see module doc). Switch lists come out in round
    order with the carried configuration first — the serve order the
    simulator replays."""
    keys = state.installed_keys()
    used: set[int] = set()
    assign: dict[int, int] = {}
    loads = np.zeros(s, dtype=np.float64)
    reused_round = [-1] * s
    for h in range(s):
        if keys[h] is None:
            continue
        for r, perm in enumerate(dec.perms):
            if r not in used and dec.alphas[r] > 0 and perm_key(perm) == keys[h]:
                used.add(r)
                assign[r] = h
                loads[h] += dec.alphas[r]
                reused_round[h] = r
                break
    remaining = [
        r for r in range(len(dec.perms)) if r not in used and dec.alphas[r] > 0
    ]
    for r in sorted(remaining, key=lambda r: (-dec.alphas[r], r)):
        h = int(np.argmin(loads))
        assign[r] = h
        loads[h] += delta + dec.alphas[r]
    switches = [SwitchSchedule() for _ in range(s)]
    marks = np.zeros(s, dtype=bool)
    for h in range(s):
        rounds = sorted(r for r, hh in assign.items() if hh == h)
        if reused_round[h] >= 0:
            rounds.remove(reused_round[h])
            rounds.insert(0, reused_round[h])
            marks[h] = True
        for r in rounds:
            switches[h].perms.append(np.asarray(dec.perms[r]))
            switches[h].alphas.append(float(dec.alphas[r]))
    return ParallelSchedule(switches=switches, delta=delta), marks


@dataclass
class OnlineController:
    """Stateful cross-period scheduler over ``s`` parallel switches.

    ``warm_start`` gates the previous-period decomposition reuse, and
    ``warm_slack`` its quality gate (warm Σα may exceed the last fresh
    decomposition's scale-free weight ratio by at most this fraction);
    ``cache_size`` bounds the support-pattern matching cache (0 disables).
    ``delta`` is the default reconfiguration delay — ``step`` takes a
    per-period override, which is how trace-aware δ schedules flow through.
    """

    s: int
    delta: float
    warm_start: bool = True
    warm_slack: float = 0.05
    merge_aware: bool = False
    do_equalize: bool = True
    cache_size: int = 8

    def __post_init__(self) -> None:
        if self.s < 1:
            raise ValueError(f"need at least one switch, got s={self.s}")
        if self.delta < 0:
            raise ValueError(f"delta must be nonnegative, got {self.delta}")
        self.state = SwitchState.initial(self.s)
        self.period = 0

    def reset(self) -> None:
        self.state = SwitchState.initial(self.s)
        self.period = 0

    # ------------------------------------------------------------------ step
    def step(
        self,
        D: np.ndarray,
        *,
        delta: float | None = None,
        stateless: ParallelSchedule | None = None,
        decomposition: Decomposition | None = None,
    ) -> OnlinePeriodOutcome:
        """Schedule one period against the carried state and advance it.

        ``stateless`` / ``decomposition`` let a caller that already ran the
        stateless solver (e.g. ``run_scenario``'s batched baseline) donate
        its schedule and decomposition; otherwise both are computed here
        (host DECOMPOSE → LPT → EQUALIZE).
        """
        D = np.asarray(D, dtype=np.float64)
        delta = self.delta if delta is None else float(delta)
        if delta < 0:
            raise ValueError(f"delta must be nonnegative, got {delta}")
        state = self.state
        carried_n = next(
            (len(p) for p in state.installed if p is not None), None
        )
        if carried_n is not None and carried_n != D.shape[0]:
            raise ValueError(
                f"demand matrix is {D.shape[0]}x{D.shape[0]} but the carried "
                f"switch state is for n={carried_n}; open a fresh controller "
                "(or reset()) to change fabric size"
            )

        # Decomposition: warm (previous period / support cache) or donated
        # or fresh.
        warm_dec = None
        if self.warm_start:
            warm_dec = _warm_decomposition(
                D, state.prev_perms, state.fresh_ratio, self.warm_slack
            )
            if warm_dec is None and self.cache_size:
                cached = state.support_cache.get(perm_key(D > 0))
                if cached is not None:
                    warm_dec = _warm_decomposition(
                        D, cached[0], cached[1], self.warm_slack
                    )
        dec = warm_dec
        if dec is None:
            dec = decomposition if decomposition is not None else decompose(D)

        def build(dec_, baseline):
            """Candidate B (reuse-then-LPT + credit-aware EQUALIZE) vs
            candidate A (the stateless baseline with the credit applied
            post-hoc — free, and when ``baseline`` is the true stateless
            schedule it pins online ≤ stateless by construction)."""
            cand, marks_b = _reuse_then_lpt(dec_, state, self.s, delta)
            if self.do_equalize:
                cand = equalize(
                    cand,
                    merge_aware=self.merge_aware,
                    load_offset=-delta * marks_b.astype(np.float64),
                )
            cand, marks_b = apply_reuse_order(cand, state)
            mk_b = float(effective_loads(cand, marks_b).max())
            if baseline is None:
                from ..core.schedule import schedule_lpt

                baseline = schedule_lpt(dec_, self.s, delta)
                if self.do_equalize:
                    baseline = equalize(
                        baseline, merge_aware=self.merge_aware
                    )
            base_mk = baseline.makespan()
            cand_a, marks_a = apply_reuse_order(baseline, state)
            mk_a = float(effective_loads(cand_a, marks_a).max())
            if mk_b <= mk_a:
                return cand, marks_b, mk_b, float(base_mk)
            return cand_a, marks_a, mk_a, float(base_mk)

        from ..core.lower_bounds import lower_bound

        lb = lower_bound(D, self.s, delta)
        chosen, marks, mk, stateless_mk = build(dec, stateless)
        # Outcome-level warm gate: without a donated true baseline the
        # "stateless" reference above came from the warm decomposition
        # itself, so a drifted warm set could silently degrade quality.
        # The last fresh period's makespan/LB gap is a scale-free outcome
        # reference: a warm period whose effective makespan exceeds
        # lb · fresh_gap · (1 + slack) is REDONE with a fresh decomposition.
        if (
            warm_dec is not None
            and stateless is None
            and lb > 0
            and state.fresh_gap is not None
            and mk > lb * state.fresh_gap * (1.0 + self.warm_slack)
        ):
            warm_dec = None
            dec = decomposition if decomposition is not None else decompose(D)
            chosen, marks, mk, stateless_mk = build(dec, None)

        reuse_count = int(marks.sum())
        num_configs = chosen.num_configs()
        outcome = OnlinePeriodOutcome(
            schedule=chosen,
            reused_switches=marks,
            makespan=mk,
            stateless_makespan=float(stateless_mk),
            reuse_count=reuse_count,
            delta_paid=delta * (num_configs - reuse_count),
            delta_avoided=delta * reuse_count,
            warm=warm_dec is not None,
            num_configs=num_configs,
            extras={"period": self.period, "delta": delta},
        )

        # Advance the carry. The warm-quality references ratchet only on
        # FRESH (or donated-baseline) periods, and only DOWNWARD (running
        # min): a warm set accepted at ref·(1+slack) must never raise the
        # bar for the next period (compounding drift), and the tightest
        # fresh quality ever observed is the honest reference — so an
        # accepted warm period is within ``warm_slack`` of fresh quality
        # whenever the current period is no easier than the easiest seen.
        fresh_ratio, fresh_gap = state.fresh_ratio, state.fresh_gap
        if warm_dec is None:
            L = _line_sum(D)
            if L > 0:
                ratio = dec.total_weight() / L
                fresh_ratio = (
                    ratio if fresh_ratio is None else min(fresh_ratio, ratio)
                )
            if lb > 0:
                gap = stateless_mk / lb
                fresh_gap = (
                    gap if fresh_gap is None else min(fresh_gap, gap)
                )
        cache = state.support_cache
        self.state = SwitchState(
            installed=advance_installed(chosen, state, marks),
            prev_perms=[np.asarray(p) for p in dec.perms],
            prices=state.prices,
            fresh_ratio=fresh_ratio,
            fresh_gap=fresh_gap,
            support_cache=cache,
        )
        if self.cache_size:
            cache[perm_key(D > 0)] = (self.state.prev_perms, fresh_ratio)
            while len(cache) > self.cache_size:
                cache.pop(next(iter(cache)))
        self.period += 1
        return outcome

    # ----------------------------------------------------------- whole trace
    def solve_trace(
        self,
        demands: np.ndarray,
        *,
        deltas: np.ndarray | None = None,
        stateless: list[ParallelSchedule] | None = None,
        decompositions: list[Decomposition] | None = None,
    ) -> list[OnlinePeriodOutcome]:
        """Run ``step`` over a (T, n, n) stack, carrying state throughout."""
        demands = np.asarray(demands, dtype=np.float64)
        T = demands.shape[0]
        if deltas is not None and len(deltas) != T:
            raise ValueError(f"need {T} per-period deltas, got {len(deltas)}")
        out = []
        for t in range(T):
            out.append(
                self.step(
                    demands[t],
                    delta=None if deltas is None else float(deltas[t]),
                    stateless=None if stateless is None else stateless[t],
                    decomposition=(
                        None if decompositions is None else decompositions[t]
                    ),
                )
            )
        return out
