"""Registry adapters: stateful solving through the uniform solver API.

``spectra_online`` (host) and ``spectra_online_jax`` (device) are registered
solvers whose cross-period state travels through ``SolveOptions.extra``:

    state = None
    for D in trace:
        opts = SolveOptions(extra={"online": state})
        report = solve(Problem(D, s, delta), solver="spectra_online",
                       options=opts)
        state = report.extras["online_state"]

``report.makespan`` is the *effective* (credit-aware) makespan — what the
fabric actually takes to serve the period given the carried configurations —
and ``extras`` carries the reuse accounting (``reuse_count``,
``delta_avoided``, ``delta_paid``, ``stateless_makespan``, ``warm``).
``repro.serve.SolverService.open_session`` wraps the state threading.

Extra knobs (both solvers): ``warm_start`` (default True), ``merge_aware``,
``equalize``, ``cache_size`` (support-pattern cache capacity: host default
8; device default 0 — the device cache lives in the carried state's shape,
so it must be chosen at session start); device also honors ``use_kernel``,
``extra_slots``, ``matcher`` (autotuned by n when unset),
``repair_rounds``, and ``warm_prices`` (carry the auction's dual prices
across periods).
"""

from __future__ import annotations

import time
from typing import Any

import numpy as np

from ..api.problem import Problem, SolveOptions, SolveReport
from ..api.registry import register_solver
from .controller import OnlineController, OnlinePeriodOutcome
from .state import SwitchState


def _report(
    *,
    solver: str,
    backend: str,
    schedule,
    problem: Problem,
    options: SolveOptions,
    runtime_s: float,
    makespan: float,
    num_configs: int,
    extras: dict[str, Any],
) -> SolveReport:
    """Online-flavored ``finish_report``: the effective makespan is NOT the
    schedule's nominal ``makespan()`` (the credit removes δs the nominal
    formula charges), so validation and reporting are decoupled here."""
    validated = False
    if options.validate:
        schedule.validate(problem.D, tol=options.tol(backend))
        validated = True
    if options.compute_lb:
        from ..core.lower_bounds import lower_bound

        lb = lower_bound(problem.D, problem.s, problem.delta)
    else:
        lb = float("nan")
    return SolveReport(
        solver=solver,
        backend=backend,
        schedule=schedule,
        makespan=float(makespan),
        lower_bound=lb,
        num_configs=int(num_configs),
        runtime_s=runtime_s,
        validated=validated,
        extras=extras,
    )


def _outcome_extras(out: OnlinePeriodOutcome) -> dict[str, Any]:
    return {
        "online": True,
        "reuse_count": out.reuse_count,
        "reused_switches": out.reused_switches,
        "delta_paid": out.delta_paid,
        "delta_avoided": out.delta_avoided,
        "stateless_makespan": out.stateless_makespan,
        "warm": out.warm,
    }


@register_solver("spectra_online")
def solve_spectra_online(problem: Problem, options: SolveOptions) -> SolveReport:
    """Host stateful solver: one controller period per call.

    ``options.extra["online"]`` is the carried ``SwitchState`` (None or
    absent → fresh controller). The §IV ``lower_bound`` stays the stateless
    bound — with enough reuse credit the effective makespan may legitimately
    dip below it (the bound charges δ for every configuration).
    """
    state = options.extra.get("online")
    if state is not None and not isinstance(state, SwitchState):
        raise TypeError(
            f"extra['online'] must be a SwitchState, got {type(state).__name__}"
        )
    ctl = OnlineController(
        s=problem.s,
        delta=problem.delta,
        warm_start=bool(options.extra.get("warm_start", True)),
        warm_slack=float(options.extra.get("warm_slack", 0.05)),
        merge_aware=bool(options.extra.get("merge_aware", False)),
        do_equalize=bool(options.extra.get("equalize", True)),
        cache_size=int(options.extra.get("cache_size", 8)),
    )
    if state is not None:
        ctl.state = state
    t0 = time.perf_counter()
    out = ctl.step(np.asarray(problem.D, dtype=np.float64))
    runtime_s = time.perf_counter() - t0
    extras = _outcome_extras(out)
    extras["online_state"] = ctl.state
    return _report(
        solver="spectra_online",
        backend="numpy",
        schedule=out.schedule,
        problem=problem,
        options=options,
        runtime_s=runtime_s,
        makespan=out.makespan,
        num_configs=out.num_configs,
        extras=extras,
    )


@register_solver("spectra_online_jax")
def solve_spectra_online_jax(
    problem: Problem, options: SolveOptions
) -> SolveReport:
    """Device stateful solver: one jitted ``online_step_jax`` per call.

    ``options.extra["online"]`` is the carried ``OnlineDeviceState`` (None
    or absent → fresh). The schedule materializes lazily in reuse serve
    order; ``extras["online_state"]`` is the new device state to thread
    into the next call.
    """
    import jax

    from ..core.jaxopt.matching import default_matcher
    from ..core.jaxopt.online_jax import (
        OnlineDeviceState,
        online_initial_state,
        online_step_jax,
    )
    from ..core.schedule_ir import LazySchedule
    from ..kernels.backend import resolve_use_kernel
    from .state import online_ir_to_schedule

    state = options.extra.get("online")
    if state is None:
        # The cache capacity is part of the state's *shape*: fixed at
        # session start, carried (and honored) by every subsequent step.
        state = online_initial_state(
            problem.n, problem.s,
            cache_size=int(options.extra.get("cache_size", 0)),
        )
    elif not isinstance(state, OnlineDeviceState):
        raise TypeError(
            "extra['online'] must be an OnlineDeviceState, got "
            f"{type(state).__name__}"
        )
    elif state.installed.shape != (problem.s, problem.n):
        raise ValueError(
            f"carried state is for (s, n)={tuple(state.installed.shape)} but "
            f"the problem is (s, n)=({problem.s}, {problem.n}); start a "
            "fresh session to change fabric size"
        )
    matcher = str(
        options.extra.get("matcher") or default_matcher(problem.n)
    )
    t0 = time.perf_counter()
    res, new_state = online_step_jax(
        state,
        np.asarray(problem.D, dtype=np.float64).astype(np.float32),
        problem.s,
        np.float32(problem.delta),
        use_kernel=resolve_use_kernel(options.extra.get("use_kernel")),
        do_equalize=bool(options.extra.get("equalize", True)),
        merge_aware=bool(options.extra.get("merge_aware", False)),
        extra_slots=int(options.extra.get("extra_slots", 64)),
        matcher=matcher,
        repair_rounds=int(options.extra.get("repair_rounds", 0)),
        warm_start=bool(options.extra.get("warm_start", True)),
        warm_prices=bool(options.extra.get("warm_prices", False)),
        warm_slack=float(options.extra.get("warm_slack", 0.05)),
    )
    jax.block_until_ready(res.makespan)
    runtime_s = time.perf_counter() - t0

    ds = jax.tree_util.tree_map(np.asarray, res.schedule)
    reused = np.asarray(res.reused)
    s = problem.s
    lazy = LazySchedule(
        lambda: online_ir_to_schedule(ds, s, reused)[0], float(ds.delta)
    )
    reuse_count = int(res.reuse_count)
    delta = float(problem.delta)
    num_configs = int((ds.switch >= 0).sum())
    extras: dict[str, Any] = {
        "online": True,
        "online_state": new_state,
        "reuse_count": reuse_count,
        "reused_slots": reused,
        "delta_paid": delta * (num_configs - reuse_count),
        "delta_avoided": delta * reuse_count,
        "stateless_makespan": float(res.stateless_makespan),
        "warm": bool(res.warm),
        "cache_hit": bool(res.cache_hit),
        "k": int(res.k),
        "converged": bool(res.converged),
        "eq_exhausted": bool(res.eq_exhausted),
        "matcher": matcher,
        "device_lb": float(res.lb),
    }
    return _report(
        solver="spectra_online_jax",
        backend="jax",
        schedule=lazy,
        problem=problem,
        options=options,
        runtime_s=runtime_s,
        makespan=float(res.makespan),
        num_configs=num_configs,
        extras=extras,
    )
