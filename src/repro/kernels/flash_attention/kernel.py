"""Pallas TPU flash attention: GQA, causal and/or sliding-window masks.

Online-softmax accumulation over key/value tiles. Grid layout
``(batch·q_heads, q_blocks, kv_blocks)`` with the KV dimension innermost;
running (m, l, acc) state lives in VMEM scratch across KV tiles and is
normalized on the last tile. GQA is expressed purely through the K/V
BlockSpec index maps (query head h reads KV head ``h // group``), so no
repeated-KV materialization ever happens. Block shapes are MXU-aligned
(q/kv tiles are multiples of 128 on the sequence dims, head dim padded to
a multiple of 128 by the ops wrapper).

Fully-masked KV tiles (beyond the causal frontier or outside the sliding
window) are computed-but-masked; on real hardware they would be pruned with
a custom grid index map — noted in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: int | None, sk_total: int, sq_total: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (bk, d)
    v = v_ref[0]  # (bk, d)
    bq, _ = q.shape
    bk, _ = k.shape

    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale  # (bq, bk)

    # Mask: absolute positions, queries aligned to the end of the KV stream.
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (
        sk_total - sq_total
    )
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    keep = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        keep &= k_pos <= q_pos
    if window is not None:
        keep &= k_pos > q_pos - window
    s = jnp.where(keep, s, NEG)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    # Guard fully-masked rows (m_new == NEG): exp(NEG - NEG) would be 1.
    safe_m = jnp.where(m_new <= NEG / 2, 0.0, m_new)
    p = jnp.exp(jnp.where(keep, s - safe_m[:, None], NEG))
    corr = jnp.exp(jnp.where(m_prev <= NEG / 2, NEG, m_prev - safe_m))
    l_ref[...] = l_prev * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v.astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "block_q", "block_kv", "group", "interpret", "scale",
    ),
)
def flash_attention_pallas(
    q: jax.Array,  # (BH, Sq, D)  — batch·q_heads folded
    k: jax.Array,  # (BHkv, Sk, D)
    v: jax.Array,
    *,
    group: int,  # q heads per kv head
    scale: float,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
):
    BH, Sq, D = q.shape
    BHkv, Sk, _ = k.shape
    assert BH == BHkv * group
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sk)
    if Sq % block_q or Sk % block_kv:
        raise ValueError("sequence lengths must divide block sizes")
    grid = (BH, Sq // block_q, Sk // block_kv)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, sk_total=Sk, sq_total=Sq,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_kv, D), lambda h, qi, ki, g=group: (h // g, ki, 0)),
            pl.BlockSpec((1, block_kv, D), lambda h, qi, ki, g=group: (h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
