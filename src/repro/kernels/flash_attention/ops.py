"""Public attention op: Pallas forward + reference-recompute backward.

``mha(q, k, v)`` accepts (B, Hq, S, D) / (B, Hkv, S, D). The forward pass
uses the Pallas flash kernel (interpret mode off-TPU); the backward pass
recomputes through the pure-jnp oracle under ``jax.vjp`` (standard
flash-recompute pattern — no attention matrix is ever materialized in the
forward). ``impl="reference"`` selects the oracle end to end (used for the
training path of small smoke models and as the numerically exact fallback).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import mha_chunked, mha_ref


def _pallas_fwd(q, k, v, causal, window, scale, interpret):
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    # Fold batch/head; pad head dim to a lane-aligned multiple of 128.
    dpad = (-D) % 128
    qf = jnp.pad(q.reshape(B * Hq, Sq, D), ((0, 0), (0, 0), (0, dpad)))
    kf = jnp.pad(k.reshape(B * Hkv, Sk, D), ((0, 0), (0, 0), (0, dpad)))
    vf = jnp.pad(v.reshape(B * Hkv, Sk, D), ((0, 0), (0, 0), (0, dpad)))
    # Pick the largest aligned block sizes that divide the sequence lengths.
    bq = next(b for b in (128, 64, 32, 16, 8, 4, 2, 1) if Sq % b == 0)
    bk = next(b for b in (128, 64, 32, 16, 8, 4, 2, 1) if Sk % b == 0)
    out = flash_attention_pallas(
        qf, kf, vf,
        group=group, scale=scale, causal=causal, window=window,
        block_q=bq, block_kv=bk, interpret=interpret,
    )
    return out[..., :D].reshape(B, Hq, Sq, D)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def _mha_hybrid(q, k, v, causal, window, scale, interpret):
    return _pallas_fwd(q, k, v, causal, window, scale, interpret)


def _mha_hybrid_fwd(q, k, v, causal, window, scale, interpret):
    return _pallas_fwd(q, k, v, causal, window, scale, interpret), (q, k, v)


def _mha_hybrid_bwd(causal, window, scale, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: mha_ref(q_, k_, v_, causal=causal, window=window, scale=scale),
        q, k, v,
    )
    return vjp(g)


_mha_hybrid.defvjp(_mha_hybrid_fwd, _mha_hybrid_bwd)


def mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    impl: str = "pallas",
    interpret: bool | None = None,
    chunk_unroll: bool = False,
) -> jax.Array:
    """Grouped-query attention. q: (B,Hq,Sq,D); k/v: (B,Hkv,Sk,D)."""
    if scale is None:
        scale = float(q.shape[-1]) ** -0.5
    if impl == "reference":
        return mha_ref(q, k, v, causal=causal, window=window, scale=scale)
    if impl == "chunked":
        return mha_chunked(q, k, v, causal=causal, window=window, scale=scale,
                           unroll=chunk_unroll)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _mha_hybrid(q, k, v, causal, window, scale, bool(interpret))
