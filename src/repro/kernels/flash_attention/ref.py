"""Pure-jnp oracle for GQA attention (causal / sliding-window / full)."""

from __future__ import annotations

import jax.numpy as jnp

NEG = -1e30


def _mask(sq: int, sk: int, causal: bool, window: int | None):
    """(sq, sk) boolean keep-mask; query i attends key j.

    Positions are aligned at the end: query i corresponds to absolute
    position (sk - sq + i), the standard decode/prefill alignment.
    """
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)
    k_pos = jnp.arange(sk)[None, :]
    keep = jnp.ones((sq, sk), bool)
    if causal:
        keep &= k_pos <= q_pos
    if window is not None:
        keep &= k_pos > q_pos - window
    return keep


def mha_chunked(
    q, k, v, *, causal: bool = True, window: int | None = None, scale=None,
    block_q: int = 512, unroll: bool = False,
):
    """Flash-style pure-jnp attention: lax.scan over query blocks.

    Differentiable, O(S·block_q) score memory, HLO size independent of
    sequence length — this is the training / dry-run lowering path (the
    Pallas kernel is the TPU-runtime path). With a sliding ``window``, each
    query block only reads its (window + block_q)-wide KV slice, keeping the
    compiled FLOPs faithful to the local-attention cost.
    """
    import jax
    import jax.numpy as jnp

    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    if unroll:
        # Cost-calibration: fewer unrolled bodies. Full attention: identical
        # total FLOPs (each body scores its block against the full Sk).
        # Windowed: the kv slice grows to (window + bq), overcounting local
        # layers by ≤ (window+2048)/(window+512) — bounded and noted in
        # EXPERIMENTS.md §Dry-run method notes.
        block_q = max(block_q, 2048)
    bq = min(block_q, Sq)
    while Sq % bq:
        bq //= 2
    nb = Sq // bq
    offset = Sk - Sq  # queries aligned to the end of the KV stream
    qg = q.reshape(B, Hkv, group, Sq, D)

    windowed = window is not None and (window + bq) < Sk

    def blk(carry, i):
        qs = i * bq
        qb = jax.lax.dynamic_slice_in_dim(qg, qs, bq, axis=3)
        q_pos = qs + jnp.arange(bq)[:, None] + offset
        if windowed:
            # KV slice [qs+offset-window+1, qs+offset+bq] (clipped).
            ks_lo = jnp.clip(qs + offset - window + 1, 0, Sk - (window + bq))
            kb = jax.lax.dynamic_slice_in_dim(k, ks_lo, window + bq, axis=2)
            vb = jax.lax.dynamic_slice_in_dim(v, ks_lo, window + bq, axis=2)
            k_pos = ks_lo + jnp.arange(window + bq)[None, :]
        else:
            kb, vb = k, v
            k_pos = jnp.arange(Sk)[None, :]
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk",
            qb.astype(jnp.float32), kb.astype(jnp.float32),
        ) * scale
        keep = jnp.ones(s.shape[-2:], bool)
        if causal:
            keep &= k_pos <= q_pos
        if window is not None:
            keep &= k_pos > q_pos - window
        s = jnp.where(keep[None, None, None], s, NEG)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
        return carry, o.astype(q.dtype)

    if unroll:  # dry-run cost calibration: loop bodies must appear per-trip
        blocks = jnp.stack([blk((), i)[1] for i in range(nb)])
    else:
        _, blocks = jax.lax.scan(blk, (), jnp.arange(nb))
    # blocks: (nb, B, Hkv, group, bq, D) → (B, Hq, Sq, D)
    out = jnp.moveaxis(blocks, 0, 3).reshape(B, Hkv, group, Sq, D)
    return out.reshape(B, Hq, Sq, D)


def mha_ref(q, k, v, *, causal: bool = True, window: int | None = None, scale=None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D), Hq % Hkv == 0 → (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s * scale
    keep = _mask(Sq, Sk, causal, window)
    s = jnp.where(keep[None, None], s, NEG)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
