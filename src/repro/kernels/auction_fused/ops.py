"""Dispatch wrapper for the fused auction: pads, tiles, picks kernel vs ref.

``fused_auction`` is the one entry point the matcher registry calls. It
pads the benefit matrix to lane-aligned 128-multiples (NEG columns, with
padded rows pre-assigned to padded columns — see kernel.py's padding
contract), chooses the column tile width (whole matrix below 256, 128-wide
lane tiles at and above so VMEM temporaries stay bounded), and runs the
Pallas kernel — compiled on TPU, interpret mode elsewhere — or, with
``use_kernel=False``, the exactly-matching jnp reference at the original
(unpadded) shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..backend import on_tpu
from .kernel import NEG, fused_auction_pallas
from .ref import fused_auction_ref

# Lane-aligned tile width; also the padding quantum. Below this the whole
# (padded) matrix is one tile.
_LANE = 128


@functools.partial(
    jax.jit,
    static_argnames=(
        "max_iters", "use_kernel", "block_cols", "interpret", "with_iters"
    ),
)
def fused_auction(
    W: jax.Array,
    prices0: jax.Array,
    eps_schedule: jax.Array,
    *,
    max_iters: int,
    use_kernel: bool = True,
    block_cols: int | None = None,
    interpret: bool | None = None,
    with_iters: bool = False,
):
    """Run the fused ε-scaling auction; returns ``(r2c, c2r, prices)`` at
    the caller's (unpadded) n. ``interpret=None`` → auto (off on TPU).

    ``with_iters=True`` appends the total bidding-round count. The Pallas
    kernel keeps its loop counter on-chip and doesn't export it, so the
    kernel path reports ``-1`` ("not tracked"); the jnp reference reports
    the exact count — that is the path warm-start round assertions use.
    """
    if not use_kernel:
        return fused_auction_ref(
            W, prices0, eps_schedule, max_iters=max_iters,
            with_iters=with_iters,
        )
    if interpret is None:
        interpret = not on_tpu()
    n = W.shape[0]
    n_pad = max(_LANE, -(-n // _LANE) * _LANE)
    pad = n_pad - n
    Wp = jnp.pad(
        W.astype(jnp.float32), ((0, pad), (0, pad)), constant_values=NEG
    )
    p0 = jnp.pad(jnp.asarray(prices0, jnp.float32), (0, pad))
    idx = jnp.arange(n_pad, dtype=jnp.int32)
    init_assign = jnp.where(idx < n, -1, idx)
    if block_cols is None:
        block_cols = _LANE if n_pad >= 256 else n_pad
    r2c, c2r, prices = fused_auction_pallas(
        Wp,
        p0,
        init_assign,
        jnp.asarray(eps_schedule, jnp.float32),
        block_cols=block_cols,
        max_iters=max_iters,
        interpret=bool(interpret),
    )
    if with_iters:
        return r2c[:n], c2r[:n], prices[:n], jnp.int32(-1)
    return r2c[:n], c2r[:n], prices[:n]
