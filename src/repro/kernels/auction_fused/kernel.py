"""Pallas TPU kernel: the entire ε-scaling auction fused into one call.

The legacy matcher path (``core.jaxopt.matching.match_auction``) runs the
bidding loop as a ``lax.while_loop`` *around* a Pallas top-2 reduction, so
every round round-trips through XLA and re-materializes whole-matrix
intermediates. This kernel owns the loop instead:

* **grid = (num_phases,)** — the ε-scaling phase axis. Column dual prices
  live in VMEM scratch and persist across grid steps (seeded from the
  warm-start input on phase 0); each phase restarts the assignment maps and
  bids until complete, exactly the ε-scaling restart semantics of the
  registry matchers.
* **in-kernel bidding rounds** — bid → price-update → assignment-flip runs
  inside a ``lax.while_loop`` *within* the kernel, so rounds never leave
  VMEM and never re-dispatch.
* **blocked/tiled** — both the per-row top-2 bid reduction and the
  per-column winner selection iterate over lane-aligned ``block_cols``-wide
  tiles (the row dimension is processed whole; padding keeps it sublane-
  aligned), bounding peak VMEM temporaries at (n_pad × block_cols) so the
  n ∈ {256, 512, 1024} regime fits comfortably beside the resident benefit
  matrix (4 MB at n=1024 f32).

Round semantics (shared bit-for-bit with ``ref.fused_auction_ref`` — the
interpret-mode parity tests assert exact equality):

1. every unassigned row computes its top-2 values ``(v1, v2)`` of
   ``W − prices`` and bids ``inc = v1 − v2 + ε`` on its favorite column
   (ties → lowest column index, merged first-tile-wins across tiles);
2. each column takes the highest bid (ties → lowest row index), kicks its
   previous owner, and raises its price by the winning increment — the
   increment formulation avoids gathers: every bidder on column j shares
   ``prices[j]``, so comparing increments IS comparing absolute bids;
3. row→column assignments are rebuilt from the column→row map (a row bids
   only while unassigned, so the map stays injective).

Padding contract (see ``ops.fused_auction``): padded columns carry ``NEG``
weight so no real row ever bids on them; padded rows arrive pre-assigned to
padded columns so the termination test ``(row2col < 0).any()`` only watches
real rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
NEG_HALF = NEG / 2


def _fused_auction_kernel(
    eps_ref,      # (1,) f32 — this phase's ε
    W_ref,        # (n_pad, n_pad) f32 — benefit matrix (NEG-padded)
    p0_ref,       # (n_pad,) f32 — warm-start column prices
    init_ref,     # (n_pad,) i32 — phase-start assignment (-1 real, identity pad)
    r2c_ref,      # out (n_pad,) i32
    c2r_ref,      # out (n_pad,) i32
    price_ref,    # out (n_pad,) f32
    price_scr,    # VMEM (n_pad,) f32 — prices carried across ε phases
    *,
    n_pad: int,
    block_cols: int,
    max_iters: int,
):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _seed_prices():
        price_scr[...] = p0_ref[...]

    eps = eps_ref[0]
    nt = n_pad // block_cols
    rows2d = jax.lax.broadcasted_iota(jnp.int32, (n_pad, block_cols), 0)
    cols2d = jax.lax.broadcasted_iota(jnp.int32, (n_pad, block_cols), 1)

    def cond(carry):
        r2c, _, _, it = carry
        return (r2c < 0).any() & (it < max_iters)

    def body(carry):
        r2c, c2r, prices, it = carry

        # ---- bid: per-row top-2 of W − prices, blocked over column tiles.
        v1 = jnp.full((n_pad,), NEG, jnp.float32)
        v2 = jnp.full((n_pad,), NEG, jnp.float32)
        j1 = jnp.zeros((n_pad,), jnp.int32)
        for ct in range(nt):
            lo = ct * block_cols
            tile = W_ref[:, lo:lo + block_cols] - prices[lo:lo + block_cols][None, :]
            t1 = tile.max(axis=1)
            jloc = jnp.argmax(tile, axis=1).astype(jnp.int32)
            t2 = jnp.where(cols2d == jloc[:, None], NEG, tile).max(axis=1)
            take = t1 > v1  # strict: earlier tile wins ties = global argmax
            v2 = jnp.where(take, jnp.maximum(t2, v1), jnp.maximum(v2, t1))
            v1 = jnp.where(take, t1, v1)
            j1 = jnp.where(take, jloc + lo, j1)
        inc = jnp.where(r2c < 0, v1 - v2 + eps, NEG)

        # ---- price-update + assignment-flip, blocked over column tiles.
        new_prices = prices
        new_c2r = c2r
        r2c_acc = jnp.full((n_pad,), n_pad, jnp.int32)
        for ct in range(nt):
            lo = ct * block_cols
            cols_g = cols2d + lo
            contrib = jnp.where(j1[:, None] == cols_g, inc[:, None], NEG)
            best = contrib.max(axis=0)                       # (bc,)
            cand = (contrib >= best[None, :]) & (contrib > NEG_HALF)
            winner = jnp.where(cand, rows2d, n_pad).min(axis=0)
            has = winner < n_pad
            c2r_t = jnp.where(has, winner, new_c2r[lo:lo + block_cols])
            p_t = jnp.where(
                has,
                new_prices[lo:lo + block_cols] + best,
                new_prices[lo:lo + block_cols],
            )
            new_c2r = jax.lax.dynamic_update_slice(new_c2r, c2r_t, (lo,))
            new_prices = jax.lax.dynamic_update_slice(new_prices, p_t, (lo,))
            # Row i owns global column lo+j iff c2r_t[j] == i (injective map).
            owned = c2r_t[None, :] == rows2d
            r2c_acc = jnp.minimum(
                r2c_acc, jnp.where(owned, cols_g, n_pad).min(axis=1)
            )
        new_r2c = jnp.where(r2c_acc < n_pad, r2c_acc, -1)
        return new_r2c, new_c2r, new_prices, it + 1

    init = init_ref[...]
    r2c, c2r, prices, _ = jax.lax.while_loop(
        cond, body, (init, init, price_scr[...], jnp.int32(0))
    )
    price_scr[...] = prices
    r2c_ref[...] = r2c
    c2r_ref[...] = c2r
    price_ref[...] = prices


@functools.partial(
    jax.jit, static_argnames=("block_cols", "max_iters", "interpret")
)
def fused_auction_pallas(
    W: jax.Array,             # (n_pad, n_pad), NEG-padded, n_pad % 128 == 0
    prices0: jax.Array,       # (n_pad,)
    init_assign: jax.Array,   # (n_pad,) i32
    eps_schedule: jax.Array,  # (num_phases,)
    *,
    block_cols: int,
    max_iters: int,
    interpret: bool = False,
):
    n_pad = W.shape[0]
    if n_pad % block_cols:
        raise ValueError(
            f"padded size {n_pad} not divisible by block_cols {block_cols}"
        )
    num_phases = eps_schedule.shape[0]
    kernel = functools.partial(
        _fused_auction_kernel,
        n_pad=n_pad,
        block_cols=block_cols,
        max_iters=max_iters,
    )
    return pl.pallas_call(
        kernel,
        grid=(num_phases,),
        in_specs=[
            pl.BlockSpec((1,), lambda p: (p,)),
            pl.BlockSpec((n_pad, n_pad), lambda p: (0, 0)),
            pl.BlockSpec((n_pad,), lambda p: (0,)),
            pl.BlockSpec((n_pad,), lambda p: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((n_pad,), lambda p: (0,)),
            pl.BlockSpec((n_pad,), lambda p: (0,)),
            pl.BlockSpec((n_pad,), lambda p: (0,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n_pad,), W.dtype),
        ),
        scratch_shapes=[pltpu.VMEM((n_pad,), W.dtype)],
        interpret=interpret,
    )(eps_schedule, W, prices0, init_assign)
