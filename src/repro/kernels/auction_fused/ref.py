"""Pure-jnp oracle for the fused auction kernel — and the fast host path.

Implements exactly the round semantics documented in ``kernel.py`` (same
bid/increment formulas, same first-index tie-breaks, same float evaluation
order), so interpret-mode kernel runs compare *bit-exactly* against it.

It is also the performant matcher on non-TPU backends: where the legacy
``match_auction`` round materializes a dense (n, n) scatter matrix to find
each column's best bid (three O(n²) passes per round beyond the top-2
reduction), this round uses O(n) segment scatters — ``.at[j1].max`` for the
winning increment, ``.at[...].min`` for the winning row — so each round
costs one O(n²) pass (the unavoidable ``W − prices`` top-2) plus O(n)
bookkeeping. That is where the measured ≥1.5× per-dispatch speedup at
n ≥ 256 comes from on CPU hosts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG = -1e30
NEG_HALF = NEG / 2


def _round(W, r2c, c2r, prices, eps, rows, cols):
    """One Jacobi bidding round; see kernel.py for the shared semantics."""
    n = W.shape[0]
    V = W - prices[None, :]
    j1 = jnp.argmax(V, axis=1).astype(jnp.int32)
    v1 = jnp.take_along_axis(V, j1[:, None].astype(jnp.int32), axis=1)[:, 0]
    v2 = jnp.where(cols[None, :] == j1[:, None], NEG, V).max(axis=1)
    inc = jnp.where(r2c < 0, v1 - v2 + eps, NEG)
    # Columns take the best increment (all bidders on j share prices[j], so
    # comparing increments is comparing absolute bids); winner = lowest row.
    col_inc = jnp.full((n,), NEG, W.dtype).at[j1].max(inc)
    cand = (inc > NEG_HALF) & (inc >= col_inc[j1])
    winner = (
        jnp.full((n,), n, jnp.int32)
        .at[jnp.where(cand, j1, n)]
        .min(rows, mode="drop")
    )
    has = winner < n
    c2r = jnp.where(has, winner, c2r)
    prices = jnp.where(has, prices + col_inc, prices)
    # Rebuild row→col from the (injective) col→row map.
    r2c = (
        jnp.full((n,), -1, jnp.int32)
        .at[jnp.where(c2r >= 0, c2r, n)]
        .set(cols, mode="drop")
    )
    return r2c, c2r, prices


@functools.partial(jax.jit, static_argnames=("max_iters", "with_iters"))
def fused_auction_ref(
    W: jax.Array,
    prices0: jax.Array,
    eps_schedule: jax.Array,
    *,
    max_iters: int,
    with_iters: bool = False,
):
    """ε-scaling auction over ``eps_schedule``; returns (r2c, c2r, prices).

    Each phase restarts the assignment maps from scratch but keeps the
    learned prices — identical to the kernel's per-phase grid steps.
    ``with_iters=True`` appends the total bidding-round count summed over
    phases — the convergence-cost observable warm-started prices reduce.
    """
    W = W.astype(jnp.float32)
    n = W.shape[0]
    rows = jnp.arange(n, dtype=jnp.int32)
    cols = rows

    def phase(state, eps):
        _, _, prices = state

        def cond(c):
            r2c, _, _, it = c
            return (r2c < 0).any() & (it < max_iters)

        def body(c):
            r2c, c2r, prices, it = c
            r2c, c2r, prices = _round(W, r2c, c2r, prices, eps, rows, cols)
            return r2c, c2r, prices, it + 1

        r2c, c2r, prices, it = jax.lax.while_loop(
            cond,
            body,
            (
                jnp.full((n,), -1, jnp.int32),
                jnp.full((n,), -1, jnp.int32),
                prices,
                jnp.int32(0),
            ),
        )
        return (r2c, c2r, prices), it

    state = (
        jnp.full((n,), -1, jnp.int32),
        jnp.full((n,), -1, jnp.int32),
        jnp.asarray(prices0, jnp.float32),
    )
    (r2c, c2r, prices), phase_iters = jax.lax.scan(phase, state, eps_schedule)
    if with_iters:
        return r2c, c2r, prices, phase_iters.sum()
    return r2c, c2r, prices
