"""Fused ε-scaling auction: the whole matcher hot loop in one Pallas kernel.

``kernel.py`` owns bid → price-update → assignment-flip across ε-phase grid
steps with prices in VMEM scratch; ``ref.py`` is the exactly-matching jnp
oracle (and the fast host-backend path); ``ops.py`` pads/dispatches.
"""

from .ops import fused_auction
from .ref import fused_auction_ref

__all__ = ["fused_auction", "fused_auction_ref"]
