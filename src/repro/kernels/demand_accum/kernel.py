"""Pallas TPU kernel: demand-matrix accumulation as one-hot MXU matmuls.

GPU-style scatter-add of (src, dst, bytes) traffic events is atomics-hostile
on TPU. The TPU-native recast (DESIGN.md §4):

    D += onehot(src)ᵀ @ (onehot(dst) ⊙ w)

per token block — a (n × bt) @ (bt × n) systolic matmul with an f32 VMEM
accumulator that lives across the token-block grid dimension. ``n`` is the
rack count (≤ a few hundred), so the (n, n) accumulator sits comfortably in
VMEM; block sizes are MXU-aligned (multiples of 128 on the lane dim).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _accum_kernel(src_ref, dst_ref, w_ref, out_ref, acc_ref):
    ti = pl.program_id(0)
    nt = pl.num_programs(0)

    @pl.when(ti == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    src = src_ref[...]  # (bt,) int32
    dst = dst_ref[...]
    w = w_ref[...].astype(jnp.float32)
    n = acc_ref.shape[0]
    bt = src.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bt, n), 1)
    onehot_src = (rows == src[:, None]).astype(jnp.float32)  # (bt, n)
    onehot_dst_w = jnp.where(rows == dst[:, None], w[:, None], 0.0)  # (bt, n)
    acc_ref[...] += jax.lax.dot_general(
        onehot_src,
        onehot_dst_w,
        (((0,), (0,)), ((), ())),  # contract over the token dim → (n, n)
        preferred_element_type=jnp.float32,
    )

    @pl.when(ti == nt - 1)
    def _done():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("n", "block_tokens", "interpret"))
def demand_accum_pallas(
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    *,
    n: int,
    block_tokens: int = 512,
    interpret: bool = False,
):
    (T,) = src.shape
    block_tokens = min(block_tokens, T)
    if T % block_tokens:
        raise ValueError(f"T={T} not divisible by block_tokens={block_tokens}")
    grid = (T // block_tokens,)
    return pl.pallas_call(
        _accum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_tokens,), lambda t: (t,)),
            pl.BlockSpec((block_tokens,), lambda t: (t,)),
            pl.BlockSpec((block_tokens,), lambda t: (t,)),
        ],
        out_specs=pl.BlockSpec((n, n), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(src, dst, w)
