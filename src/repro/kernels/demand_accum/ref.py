"""Pure-jnp oracle for demand-matrix accumulation from traffic events."""

from __future__ import annotations

import jax.numpy as jnp


def demand_accum_ref(src, dst, w, n: int):
    """D[n, n] with D[src[t], dst[t]] += w[t] (scatter-add)."""
    D = jnp.zeros((n, n), jnp.float32)
    return D.at[src, dst].add(w.astype(jnp.float32))
