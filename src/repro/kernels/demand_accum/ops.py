"""Jit'd wrapper: pads the event stream and dispatches to the kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import demand_accum_pallas


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def demand_accum(src, dst, w, *, n: int, interpret: bool | None = None):
    """Accumulate (src, dst, w) events into an (n, n) demand matrix.

    Padding events get w = 0 so they contribute nothing.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    T = src.shape[0]
    bt = 512 if T >= 512 else max(8, T)
    pad = (-T) % bt
    src = jnp.pad(src.astype(jnp.int32), (0, pad))
    dst = jnp.pad(dst.astype(jnp.int32), (0, pad))
    w = jnp.pad(w.astype(jnp.float32), (0, pad))
    return demand_accum_pallas(
        src, dst, w, n=n, block_tokens=bt, interpret=bool(interpret)
    )
