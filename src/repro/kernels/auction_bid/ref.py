"""Pure-jnp oracle for the auction bid top-2 reduction."""

from __future__ import annotations

import jax.numpy as jnp

NEG = -1e30


def masked_row_top2_ref(W, prices):
    """Per-row top-2 of V = W − prices.

    Returns (v1, v2, j1): best value, second-best value (over the remaining
    columns), and the argmax column per row. For n == 1, v2 = NEG.
    """
    V = W - prices[None, :]
    j1 = jnp.argmax(V, axis=1)
    v1 = jnp.take_along_axis(V, j1[:, None], axis=1)[:, 0]
    V2 = jnp.where(
        jnp.arange(V.shape[1])[None, :] == j1[:, None], NEG, V
    )
    v2 = V2.max(axis=1)
    return v1, v2, j1.astype(jnp.int32)
