"""Jit'd wrapper for the auction bid kernel (pads to hardware-aligned tiles)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..backend import on_tpu
from .kernel import NEG, masked_row_top2_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_row_top2(W: jax.Array, prices: jax.Array, *, interpret: bool | None = None):
    """Per-row (v1, v2, j1) of V = W − p. Pads rows to 8, cols to 128."""
    if interpret is None:
        interpret = not on_tpu()
    n, m = W.shape
    rpad = (-n) % 8
    cpad = (-m) % 128
    Wp = jnp.pad(W, ((0, rpad), (0, cpad)), constant_values=NEG)
    pp = jnp.pad(prices, (0, cpad), constant_values=0.0)
    br = min(128, n + rpad)
    bc = min(512, m + cpad)
    # block sizes must divide padded dims: fall back to full extent otherwise
    if (n + rpad) % br:
        br = n + rpad
    if (m + cpad) % bc:
        bc = m + cpad
    v1, v2, j1 = masked_row_top2_pallas(
        Wp, pp, block_rows=br, block_cols=bc, interpret=bool(interpret)
    )
    return v1[:n], v2[:n], j1[:n]
