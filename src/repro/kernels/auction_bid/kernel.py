"""Pallas TPU kernel: per-row top-2 reduction of V = W − p (auction bids).

Bandwidth-bound VPU reduction. The benefit matrix is tiled
(block_rows × block_cols) into VMEM; running (v1, v2, j1) merge state lives
in VMEM scratch across the column-tile grid dimension, finalized on the last
column tile. Column tiles are lane-aligned (multiples of 128); row tiles are
sublane-aligned (multiples of 8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _bid_kernel(W_ref, p_ref, v1_ref, v2_ref, j1_ref, s1_ref, s2_ref, sj_ref):
    ci = pl.program_id(1)
    ncols = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        s1_ref[...] = jnp.full_like(s1_ref, NEG)
        s2_ref[...] = jnp.full_like(s2_ref, NEG)
        sj_ref[...] = jnp.zeros_like(sj_ref)

    tile = W_ref[...] - p_ref[...]  # (br, bc)
    br, bc = tile.shape
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (br, bc), 1)
    t1 = tile.max(axis=1)
    j_loc = tile.argmax(axis=1).astype(jnp.int32)
    masked = jnp.where(col_ids == j_loc[:, None], NEG, tile)
    t2 = masked.max(axis=1)
    j_glob = j_loc + ci * bc

    v1 = s1_ref[...]
    v2 = s2_ref[...]
    j1 = sj_ref[...]
    take_new = t1 > v1
    new_v1 = jnp.where(take_new, t1, v1)
    new_v2 = jnp.where(take_new, jnp.maximum(t2, v1), jnp.maximum(v2, t1))
    new_j1 = jnp.where(take_new, j_glob, j1)
    s1_ref[...] = new_v1
    s2_ref[...] = new_v2
    sj_ref[...] = new_j1

    @pl.when(ci == ncols - 1)
    def _finalize():
        v1_ref[...] = s1_ref[...]
        v2_ref[...] = s2_ref[...]
        j1_ref[...] = sj_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def masked_row_top2_pallas(
    W: jax.Array,
    prices: jax.Array,
    *,
    block_rows: int = 128,
    block_cols: int = 128,
    interpret: bool = False,
):
    n, m = W.shape
    block_rows = min(block_rows, n)
    block_cols = min(block_cols, m)
    if n % block_rows or m % block_cols:
        raise ValueError(f"shape {(n, m)} not divisible by blocks "
                         f"{(block_rows, block_cols)}")
    grid = (n // block_rows, m // block_cols)
    out_shapes = (
        jax.ShapeDtypeStruct((n,), W.dtype),
        jax.ShapeDtypeStruct((n,), W.dtype),
        jax.ShapeDtypeStruct((n,), jnp.int32),
    )
    return pl.pallas_call(
        _bid_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_cols), lambda r, c: (r, c)),
            pl.BlockSpec((block_cols,), lambda r, c: (c,)),
        ],
        out_specs=(
            pl.BlockSpec((block_rows,), lambda r, c: (r,)),
            pl.BlockSpec((block_rows,), lambda r, c: (r,)),
            pl.BlockSpec((block_rows,), lambda r, c: (r,)),
        ),
        scratch_shapes=[
            pltpu.VMEM((block_rows,), W.dtype),
            pltpu.VMEM((block_rows,), W.dtype),
            pltpu.VMEM((block_rows,), jnp.int32),
        ],
        out_shape=out_shapes,
        interpret=interpret,
    )(W, prices)
