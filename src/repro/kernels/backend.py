"""Kernel backend detection: when does the Pallas path turn on?

``use_kernel`` is threaded through every device entry point (matchers,
``decompose_jax``, the fused e2e call, ``SolveOptions.extra``), but it used
to default to ``False`` everywhere — nothing ever turned the Pallas path on
outside hand-written tests. API boundaries now pass ``None`` through
``resolve_use_kernel``, which supplies the backend-aware default:

* on TPU → ``True``: the compiled Pallas kernels are the production path;
* elsewhere → ``False`` (the pure-jnp reference math), unless the
  ``REPRO_USE_KERNEL`` environment variable is set truthy, which forces the
  kernels on — they then run in Pallas *interpret* mode (each kernel's
  ``ops`` wrapper resolves ``interpret=None`` to ``not on_tpu()``). That is
  the CPU CI parity lane: the same kernel code path, executed by the
  interpreter instead of Mosaic.

An explicit ``use_kernel=True/False`` (per call or via
``SolveOptions.extra["use_kernel"]``) always wins over detection.
"""

from __future__ import annotations

import functools
import os

__all__ = ["on_tpu", "default_use_kernel", "resolve_use_kernel"]


@functools.lru_cache(maxsize=None)
def on_tpu() -> bool:
    """True when the default JAX backend is a TPU (cached per process)."""
    import jax

    return jax.default_backend() == "tpu"


def default_use_kernel() -> bool:
    """Backend-aware default for ``use_kernel``.

    ``REPRO_USE_KERNEL`` overrides detection both ways (``1``/``true`` →
    kernels on, ``0``/``false`` → off); it is re-read on every call so test
    harnesses can flip it per test.
    """
    env = os.environ.get("REPRO_USE_KERNEL")
    if env is not None:
        return env.strip().lower() not in ("", "0", "false", "no")
    return on_tpu()


def resolve_use_kernel(value: bool | None = None) -> bool:
    """``None`` → backend detection; anything else → ``bool(value)``."""
    return default_use_kernel() if value is None else bool(value)
