"""Pallas TPU kernel: Mamba-2 SSD chunked scan — intra-chunk pass.

State-space duality: within a chunk of length L the recurrence is a masked
(decay-weighted) attention-like matmul pair, all MXU work:

    y_intra = ((C @ Bᵀ) ⊙ decay_mask) @ xd        decay[t,u] = exp(la_t − la_u)
    state_c = (B ⊙ exp(la_L − la))ᵀ @ xd           (N, P) carry-out
    gate_c  = exp(la_L)                            chunk decay

The cross-chunk recurrence H_in(c+1) = gate_c·H_in(c) + state_c is a tiny
associative scan done in the ops wrapper; the O(S·L·(N+P)) heavy lifting is
in this kernel. Grid: (BH, n_chunks); every tile is VMEM-resident. All exps
are of non-positive numbers (decays ≤ 1) — numerically safe.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(xd_ref, la_ref, b_ref, c_ref, y_ref, st_ref, g_ref):
    xd = xd_ref[0].astype(jnp.float32)  # (L, P)
    loga = la_ref[0].astype(jnp.float32)  # (L,)
    B = b_ref[0].astype(jnp.float32)  # (L, N)
    C = c_ref[0].astype(jnp.float32)  # (L, N)
    L = xd.shape[0]

    la = jnp.cumsum(loga)  # inclusive cumulative log-decay
    la_total = la[-1]
    # Pairwise decay matrix with causal (u ≤ t) mask.
    diff = la[:, None] - la[None, :]
    t_ge_u = (
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    )
    decay = jnp.where(t_ge_u, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * decay  # (L, L)
    y_ref[0] = jax.lax.dot_general(
        scores, xd, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)
    to_end = jnp.exp(la_total - la)  # (L,)
    st_ref[0] = jax.lax.dot_general(
        B * to_end[:, None], xd, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(st_ref.dtype)  # (N, P)
    g_ref[0, 0] = jnp.exp(la_total)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_pallas(
    xd: jax.Array,   # (BH, S, P)
    loga: jax.Array,  # (BH, S)
    B: jax.Array,    # (BH, S, N)
    C: jax.Array,    # (BH, S, N)
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    BH, S, P = xd.shape
    N = B.shape[-1]
    if S % chunk:
        raise ValueError(f"S={S} not divisible by chunk={chunk}")
    nc = S // chunk
    grid = (BH, nc)
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, P), lambda b, c: (b * nc + c, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, c)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((BH, S, P), jnp.float32),
            jax.ShapeDtypeStruct((BH * nc, N, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc), jnp.float32),
        ),
        interpret=interpret,
    )(xd, loga, B, C)
