"""Public SSD op: Pallas intra-chunk kernel + tiny cross-chunk scan.

Forward: Pallas per-chunk pass → ``lax.associative_scan`` over the (gate,
state) pairs (the cross-chunk recurrence) → inter-chunk correction
``y += (C ⊙ exp(la)) @ H_in``. Backward: reference-recompute via custom_vjp
(same pattern as flash_attention). ``ssd_decode_step`` is the O(1) serving
update.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_chunk_pallas
from .ref import ssd_decode_step_ref, ssd_ref


def _pick_chunk(S: int) -> int:
    for c in (128, 64, 32, 16, 8, 4, 2, 1):
        if S % c == 0:
            return c
    return 1


def _chunk_jnp(xd, loga, B, C, chunk):
    """Vectorized pure-jnp version of the Pallas chunk kernel (same math).

    Used for training and dry-run lowering: compact HLO at any (BH, S),
    whereas the interpret-mode Pallas path would unroll the grid on CPU.
    """
    BH, S, P = xd.shape
    N = B.shape[-1]
    nc = S // chunk
    xd_c = xd.reshape(BH, nc, chunk, P).astype(jnp.float32)
    la = jnp.cumsum(loga.reshape(BH, nc, chunk).astype(jnp.float32), axis=-1)
    B_c = B.reshape(BH, nc, chunk, N).astype(jnp.float32)
    C_c = C.reshape(BH, nc, chunk, N).astype(jnp.float32)
    la_tot = la[..., -1]
    diff = la[..., :, None] - la[..., None, :]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    scores = jnp.einsum("bcln,bcmn->bclm", C_c, B_c) * decay
    y_intra = jnp.einsum("bclm,bcmp->bclp", scores, xd_c).reshape(BH, S, P)
    to_end = jnp.exp(la_tot[..., None] - la)
    states = jnp.einsum("bcln,bclp->bcnp", B_c * to_end[..., None], xd_c)
    gates = jnp.exp(la_tot)
    return y_intra, states, gates


def _chunk_jnp_scanned(xd, loga, B, C, chunk, unroll=False):
    """Memory-lean variant: lax.scan over the chunk axis.

    The vectorized ``_chunk_jnp`` materializes all nc (L×L) decay/score
    tiles at once — O(S·L) f32 per (batch·head), which at mamba2-2.7b
    train_4k is ~170 GB/chip (the dry-run's memory-dominant term). Scanning
    over chunks keeps one tile live at a time — the jnp analogue of the
    Pallas kernel's VMEM blocking. ``unroll=True`` python-loops the chunks
    for dry-run cost calibration.
    """
    BH, S, P = xd.shape
    N = B.shape[-1]
    nc = S // chunk
    xs = (
        jnp.moveaxis(xd.reshape(BH, nc, chunk, P), 1, 0),
        jnp.moveaxis(loga.reshape(BH, nc, chunk), 1, 0),
        jnp.moveaxis(B.reshape(BH, nc, chunk, N), 1, 0),
        jnp.moveaxis(C.reshape(BH, nc, chunk, N), 1, 0),
    )

    def body(carry, inp):
        xd_c, la_c, B_c, C_c = inp
        y_c, st_c, g_c = _chunk_jnp(xd_c, la_c, B_c, C_c, chunk)
        return carry, (y_c, st_c[:, 0], g_c[:, 0])

    if unroll:
        outs = [body((), jax.tree.map(lambda a: a[i], xs))[1]
                for i in range(nc)]
        ys = jax.tree.map(lambda *a: jnp.stack(a), *outs)
    else:
        _, ys = jax.lax.scan(body, (), xs)
    y_intra = jnp.moveaxis(ys[0], 0, 1).reshape(BH, S, P)
    states = jnp.moveaxis(ys[1], 0, 1)  # (BH, nc, N, P)
    gates = jnp.moveaxis(ys[2], 0, 1)  # (BH, nc)
    return y_intra, states, gates


def _ssd_fwd_impl(xd, loga, B, C, h0, interpret, use_pallas=True,
                  scanned=False, unroll=False):
    BH, S, P = xd.shape
    N = B.shape[-1]
    chunk = _pick_chunk(S)
    nc = S // chunk
    if use_pallas:
        y_intra, states, gates = ssd_chunk_pallas(
            xd, loga, B, C, chunk=chunk, interpret=interpret
        )
        states = states.reshape(BH, nc, N, P)
    elif scanned:
        y_intra, states, gates = _chunk_jnp_scanned(
            xd, loga, B, C, chunk, unroll=unroll
        )
    else:
        y_intra, states, gates = _chunk_jnp(xd, loga, B, C, chunk)
    # Cross-chunk recurrence: H_out(c) = gate_c · H_in(c) + state_c.
    pair_g = jnp.concatenate([jnp.ones((BH, 1)), gates[:, :-1]], axis=1)
    pair_s = jnp.concatenate(
        [h0[:, None].astype(jnp.float32), states[:, :-1]], axis=1
    )

    def combine(a, b):
        g1, s1 = a
        g2, s2 = b
        return g1 * g2, s1 * g2[..., None, None] + s2

    g_in, h_in = jax.lax.associative_scan(combine, (pair_g, pair_s), axis=1)
    # h_in[c] = state entering chunk c (includes h0 propagated).
    la = jnp.cumsum(loga.reshape(BH, nc, chunk), axis=-1)
    Cc = C.reshape(BH, nc, chunk, N)
    y_inter = jnp.einsum(
        "bcln,bcnp->bclp", Cc * jnp.exp(la)[..., None], h_in
    ).reshape(BH, S, P)
    y = y_intra + y_inter
    hT = h_in[:, -1] * gates[:, -1][..., None, None] + states[:, -1]
    return y.astype(xd.dtype), hT


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ssd_hybrid(xd, loga, B, C, h0, interpret):
    return _ssd_fwd_impl(xd, loga, B, C, h0, interpret)


def _ssd_hybrid_fwd(xd, loga, B, C, h0, interpret):
    return _ssd_fwd_impl(xd, loga, B, C, h0, interpret), (xd, loga, B, C, h0)


def ssd_chunked(xd, loga, B, C, h0, scanned=False, unroll=False):
    """Differentiable pure-jnp chunked SSD (training / dry-run path)."""
    return _ssd_fwd_impl(xd, loga, B, C, h0, False, use_pallas=False,
                         scanned=scanned, unroll=unroll)


def _ssd_hybrid_bwd(interpret, res, g):
    xd, loga, B, C, h0 = res
    _, vjp = jax.vjp(lambda *a: ssd_ref(*a), xd, loga, B, C, h0)
    return vjp(g)


_ssd_hybrid.defvjp(_ssd_hybrid_fwd, _ssd_hybrid_bwd)


def ssd_scan(
    xd: jax.Array,
    loga: jax.Array,
    B: jax.Array,
    C: jax.Array,
    h0: jax.Array | None = None,
    *,
    impl: str = "pallas",
    interpret: bool | None = None,
    chunk_unroll: bool = False,
):
    """SSD sequence transform. Returns (y (BH,S,P), final state (BH,N,P))."""
    BH, S, P = xd.shape
    N = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((BH, N, P), jnp.float32)
    if impl == "reference":
        return ssd_ref(xd, loga, B, C, h0)
    if impl == "chunked":
        return ssd_chunked(xd, loga, B, C, h0, unroll=chunk_unroll)
    if impl == "chunked_scan":
        return ssd_chunked(xd, loga, B, C, h0, scanned=True,
                           unroll=chunk_unroll)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _ssd_hybrid(xd, loga, B, C, h0, bool(interpret))


def ssd_decode_step(h, xd, loga, B, C):
    """One-token state update (BH,N,P),(BH,P),(BH,),(BH,N),(BH,N)."""
    return ssd_decode_step_ref(h, xd, loga, B, C)
