"""Pure-jnp oracle for the Mamba-2 SSD recurrence (exact sequential scan).

Per (batch·head): H_t = a_t · H_{t-1} + B_tᵀ ⊗ xd_t,  y_t = C_t @ H_t
with decay a_t = exp(loga_t) ∈ (0, 1], state H ∈ (N, P).
Shapes: xd (BH, S, P), loga (BH, S), B/C (BH, S, N) → y (BH, S, P).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(xd, loga, B, C, h0=None):
    BH, S, P = xd.shape
    N = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((BH, N, P), jnp.float32)

    def step(h, inp):
        xd_t, loga_t, b_t, c_t = inp
        h = jnp.exp(loga_t)[:, None, None] * h + jnp.einsum(
            "bn,bp->bnp", b_t.astype(jnp.float32), xd_t.astype(jnp.float32)
        )
        y = jnp.einsum("bn,bnp->bp", c_t.astype(jnp.float32), h)
        return h, y

    xs = (
        jnp.moveaxis(xd, 1, 0),
        jnp.moveaxis(loga, 1, 0),
        jnp.moveaxis(B, 1, 0),
        jnp.moveaxis(C, 1, 0),
    )
    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(xd.dtype), hT


def ssd_decode_step_ref(h, xd, loga, B, C):
    """Single-token recurrence update (serving path)."""
    h = jnp.exp(loga)[:, None, None] * h + jnp.einsum(
        "bn,bp->bnp", B.astype(jnp.float32), xd.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bnp->bp", C.astype(jnp.float32), h)
    return h, y.astype(xd.dtype)
