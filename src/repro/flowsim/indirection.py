"""2-hop Valiant load balancing: what a window carries besides its own pair.

When circuit (a → b) has leftover capacity after serving relay and direct
traffic, VLB spends it on hop-1 detours: bytes queued at ``a`` for *other*
destinations ride to ``b`` now and are forwarded from ``b``'s indirect
buffer when a later (b → dst) window comes up. Because each window's
leftover is at most one slot's worth, injection self-spreads across
intermediates as the rotor sequence cycles — the classic RotorNet/Opus
behavior — without any demand knowledge beyond the local queue depths.

The policy here is deterministic: destinations are offered in order of
descending local queue depth (ties by index), so heavy flows detour first.
"""

from __future__ import annotations

import numpy as np

from .buffers import FabricBuffers

__all__ = ["vlb_injections"]


def vlb_injections(
    buffers: FabricBuffers,
    a: int,
    b: int,
    capacity: float,
    tol: float = 1e-12,
) -> list[tuple[int, float]]:
    """Hop-1 plan for window (a → b): [(dst, units to park at b), ...].

    Respects ``b``'s free buffer space (finite ``buffer_limit`` throttles
    admission) and never detours traffic already destined ``b`` (that is
    direct) nor ``a``'s intra-rack demand. Callers stage the returned
    amounts via ``buffers.stage_arrival`` so they only become forwardable
    at the window boundary.
    """
    if capacity <= tol:
        return []
    space = buffers.free_space(b)
    if space <= tol:
        return []
    row = buffers.direct[a]
    order = np.argsort(-row, kind="stable")
    plan: list[tuple[int, float]] = []
    budget = min(capacity, space)
    for d in order:
        d = int(d)
        if d == b or d == a:
            continue
        queued = float(row[d])
        if queued <= tol:
            break  # descending order: nothing left worth detouring
        x = min(queued, budget)
        plan.append((d, x))
        budget -= x
        if budget <= tol:
            break
    return plan
