"""Flow-level fabric simulation: FCT/CCT distributions over scheduled circuits.

    from repro.flowsim import simulate_flows, FlowSimOptions

    rep = solve(Problem(D, s=4, delta=0.01), solver="spectra")
    fs = simulate_flows(rep, D)
    print(fs.fct_stats.p99, fs.cct, fs.conserved)

The measurement tier above ``repro.fabric.simulator``: instead of checking
matrix coverage and a single finish time, the discrete-event engine in
``events`` replays per-(src, dst) *flows* through the scheduled circuit
windows — NIC virtual-output queues, finite indirect buffers, optional
2-hop Valiant load balancing — and reports the flow-completion-time
distribution (p50/p90/p99/mean/max), coordinated completion time,
per-switch utilization, δ overhead, and bytes conservation.

Circuit timing comes from ``repro.fabric.timeline`` — the same source of
truth the matrix-level simulator asserts against — so the two tiers can
never disagree about when a circuit is up. Demand-oblivious baselines
(``rotor``, ``rotor_vlb`` in the solver registry) and SPECTRA schedules
all flow through the same ``FlowSimReport``; ``run_scenario(...,
flowsim=True)`` attaches one per controller period.
"""

from .buffers import FabricBuffers
from .events import simulate_flows
from .flows import Flow, FlowTable, flows_from_demand
from .indirection import vlb_injections
from .report import FlowSimOptions, FlowSimReport, FlowStats

__all__ = [
    "FabricBuffers", "Flow", "FlowSimOptions", "FlowSimReport", "FlowStats",
    "FlowTable", "flows_from_demand", "simulate_flows", "vlb_injections",
]
