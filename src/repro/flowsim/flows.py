"""Flows: one per (src, dst) demand entry, with delivery accounting.

A flow is the unit the FCT distribution is over — all of D[src, dst],
regardless of how many circuit windows (or VLB detours) carry pieces of
it. ``FlowTable`` owns the per-flow delivered counters and stamps the
completion time the instant the last unit reaches the destination.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["Flow", "FlowTable", "flows_from_demand"]


@dataclass
class Flow:
    src: int
    dst: int
    size: float
    delivered: float = 0.0
    indirected: float = 0.0          # units that arrived via a VLB detour
    fct: float = float("inf")        # completion time; inf until complete
    release: float = 0.0             # instant the bytes become sendable

    @property
    def remaining(self) -> float:
        return self.size - self.delivered

    @property
    def complete(self) -> bool:
        return np.isfinite(self.fct)


def flows_from_demand(D: np.ndarray, tol: float = 1e-9) -> list[Flow]:
    """One flow per strictly-positive demand entry (diagonal included —
    intra-rack demand is rare but the matrix-level simulator serves it via
    identity configurations, and the flow view must agree)."""
    D = np.asarray(D, dtype=np.float64)
    srcs, dsts = np.nonzero(D > tol)
    return [Flow(src=int(a), dst=int(b), size=float(D[a, b])) for a, b in zip(srcs, dsts)]


class FlowTable:
    """Index + delivery bookkeeping over the flow list."""

    def __init__(self, flows: list[Flow], tol: float = 1e-9):
        self.flows = flows
        self.tol = tol
        self._by_pair = {(f.src, f.dst): f for f in flows}

    def get(self, src: int, dst: int) -> Flow | None:
        return self._by_pair.get((src, dst))

    def deliver(
        self, src: int, dst: int, amount: float, time: float, *,
        indirect: bool = False,
    ) -> None:
        """Credit ``amount`` units arriving at ``dst`` at ``time``.

        ``time`` is the instant the *last* of the amount lands (the engine
        serves queues sequentially within a window, so it knows exactly
        when each chunk finishes). Completion is stamped when delivered
        reaches the flow size within tolerance.
        """
        if amount <= 0:
            return
        f = self._by_pair[(src, dst)]
        f.delivered += amount
        if indirect:
            f.indirected += amount
        if not f.complete and f.delivered >= f.size - self.tol:
            f.fct = time

    def fct_array(self) -> np.ndarray:
        return np.array([f.fct for f in self.flows], dtype=np.float64)

    def arrays(self) -> dict[str, np.ndarray]:
        return {
            "fct": self.fct_array(),
            "flow_src": np.array([f.src for f in self.flows], dtype=np.int64),
            "flow_dst": np.array([f.dst for f in self.flows], dtype=np.int64),
            "flow_size": np.array([f.size for f in self.flows]),
            "delivered": np.array([f.delivered for f in self.flows]),
        }
