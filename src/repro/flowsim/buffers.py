"""Host-side queues: direct VOQs at sources, finite indirect buffers.

Two tiers of queueing, matching the RotorNet host model:

* **direct** — the virtual output queues at each source NIC: remaining
  demand D[src, dst] waiting at src for a (src → dst) circuit (or, under
  VLB, for a hop-1 detour).
* **indirect** — bytes a host holds *for someone else*: hop-1 traffic
  parked at intermediate ``m`` until an ``(m → dst)`` circuit comes up.
  Capped at ``buffer_limit`` units per node; a full buffer throttles
  hop-1 admission, which is how finite host memory pushes back on VLB.

Causality: hop-1 arrivals within a circuit window are *staged* and only
become forwardable when the engine commits them at the window boundary —
store-and-forward at slot granularity, so a byte can never ride two
circuits in the same instant.
"""

from __future__ import annotations

import math

import numpy as np

__all__ = ["FabricBuffers"]


class FabricBuffers:
    def __init__(self, D: np.ndarray, *, buffer_limit: float = math.inf):
        D = np.asarray(D, dtype=np.float64)
        self.n = D.shape[0]
        self.direct = D.copy()              # (n, n) remaining at source
        self.buffer_limit = float(buffer_limit)
        # indirect[m][dst] -> {src: units} in arrival order (FIFO drain).
        self.indirect: list[dict[int, dict[int, float]]] = [
            {} for _ in range(self.n)
        ]
        self.occupancy = np.zeros(self.n, dtype=np.float64)  # Σ indirect at m
        self._staged: list[tuple[int, int, int, float]] = []  # (m, src, dst, x)

    # -- direct tier --------------------------------------------------------

    def take_direct(self, src: int, dst: int, amount: float) -> float:
        """Remove up to ``amount`` units from the (src, dst) VOQ."""
        x = min(float(self.direct[src, dst]), amount)
        if x <= 0:
            return 0.0
        self.direct[src, dst] -= x
        return x

    # -- indirect tier ------------------------------------------------------

    def free_space(self, m: int) -> float:
        """Admissible hop-1 units at node ``m`` (staged arrivals count
        against the cap immediately, so concurrent windows can't jointly
        overcommit the buffer)."""
        return max(self.buffer_limit - float(self.occupancy[m]), 0.0)

    def stage_arrival(self, m: int, src: int, dst: int, amount: float) -> None:
        """Park hop-1 units at ``m``; forwardable only after ``commit``."""
        if amount <= 0:
            return
        self._staged.append((m, src, dst, amount))
        self.occupancy[m] += amount

    def commit(self) -> None:
        """Window boundary: staged arrivals become forwardable."""
        for m, src, dst, x in self._staged:
            per_dst = self.indirect[m].setdefault(dst, {})
            per_dst[src] = per_dst.get(src, 0.0) + x
        self._staged.clear()

    def relay_queue(self, m: int, dst: int) -> dict[int, float]:
        """Forwardable units at ``m`` destined ``dst``, by origin (FIFO)."""
        return self.indirect[m].get(dst, {})

    def take_relay(self, m: int, dst: int, src: int, amount: float) -> float:
        """Remove up to ``amount`` relay units (m, src→dst) for delivery."""
        queue = self.indirect[m].get(dst)
        if not queue or src not in queue:
            return 0.0
        x = min(queue[src], amount)
        if x <= 0:
            return 0.0
        queue[src] -= x
        self.occupancy[m] -= x
        if queue[src] <= 1e-15:
            del queue[src]
        if not queue:
            del self.indirect[m][dst]
        return x

    # -- accounting ---------------------------------------------------------

    def buffered_total(self) -> float:
        """Units parked (or staged) anywhere in the fabric's buffers."""
        return float(self.occupancy.sum())

    def direct_total(self) -> float:
        return float(self.direct.sum())
