"""The discrete-event flow replay: circuits × queues → per-flow FCTs.

Event model
-----------
``fabric.timeline.build_timeline`` turns the schedule into absolute
circuit serve windows (δ reconfiguration gaps between them — the same
timing the matrix-level simulator asserts against). Window boundaries are
the event times: between two consecutive boundaries the set of up
circuits is fixed, so the engine walks intervals in time order and lets
each active circuit spend its capacity ``(t1 − t0) · line_rate``
sequentially on, in priority order:

1. **relay** — indirect bytes parked at the source by an earlier VLB
   hop-1, destined to this window's output (RotorNet's "old indirect
   first", which guarantees buffers drain);
2. **direct** — the window's own (src → dst) VOQ;
3. **hop-1 injection** (VLB only) — leftover capacity detours other
   destinations' bytes to this output's buffer, throttled by its free
   space; arrivals commit at the window boundary (store-and-forward), so
   no byte rides two circuits in one instant.

Circuits are processed in deterministic (switch, slot) order and debit
shared queues immediately, so two windows can never serve the same byte.
With ``options.arrival="uniform"`` each flow is released at a uniform
time inside the period instead of at t=0; a circuit serves a flow only
from ``max(window position, release)``, forfeiting the capacity before
it, and VLB may not detour bytes that have not been released yet.
Completion times are stamped mid-window at the exact chunk end — the
engine knows when each byte lands because service within a window is
sequential.
"""

from __future__ import annotations

import numpy as np

from ..fabric.timeline import Timeline, build_timeline
from .buffers import FabricBuffers
from .flows import FlowTable, flows_from_demand
from .indirection import vlb_injections
from .report import FlowSimOptions, FlowSimReport, FlowStats

__all__ = ["simulate_flows"]

_EPS = 1e-15


def _resolve_indirection(sched, options: FlowSimOptions) -> str:
    """``"auto"`` → whatever the solver's report requests (default off)."""
    if options.indirection != "auto":
        return options.indirection
    extras = getattr(sched, "extras", None) or {}
    return "vlb" if extras.get("indirection") == "vlb" else "none"


def _port_windows_ok(tl: Timeline, tol: float) -> bool:
    """No switch may have two serve windows up at one instant."""
    for h in range(tl.s):
        ws = sorted(
            (w for w in tl.windows if w.switch == h), key=lambda w: w.start
        )
        for prev, nxt in zip(ws, ws[1:]):
            if nxt.start < prev.end - tol:
                return False
    return True


def simulate_flows(
    sched,
    D: np.ndarray,
    *,
    options: FlowSimOptions | None = None,
    installed=None,
) -> FlowSimReport:
    """Flow-level replay of ``sched`` (or anything carrying ``.schedule``)
    against demand ``D``; see the module doc for the event model.

    ``installed`` is the online controller's carried per-switch
    configuration (δ-free first slot), identical to the matrix simulator's
    parameter. The report's ``finish_time`` is the shared timeline's
    finish — by construction the same number ``fabric.simulator.simulate``
    asserts equals the schedule's claimed makespan.
    """
    options = options or FlowSimOptions()
    vlb = _resolve_indirection(sched, options) == "vlb"
    tol = options.resolve_tol(sched)
    D = np.asarray(D, dtype=np.float64)
    tl = build_timeline(sched, installed=installed, tol=tol)
    n = D.shape[0]
    for w in tl.windows:
        if len(w.perm) != n:
            raise AssertionError("configuration is not a permutation")

    flows = FlowTable(flows_from_demand(D, tol=_EPS), tol=tol)
    staggered = options.arrival == "uniform"
    if staggered:
        # Releases are drawn per flow in the FlowTable's (row-major) order,
        # so a fixed seed reproduces the same arrival pattern exactly.
        rng = np.random.default_rng(options.arrival_seed)
        horizon = options.arrival_span * tl.finish
        for f in flows.flows:
            f.release = float(rng.uniform(0.0, horizon)) if horizon > 0 else 0.0
    buffers = FabricBuffers(D, buffer_limit=options.buffer_limit)
    rate = options.line_rate
    busy = np.zeros(tl.s, dtype=np.float64)
    port_ok = _port_windows_ok(tl, tol)

    # Interval decomposition: windows never straddle a boundary, so a
    # window is active on [idx(start), idx(end)) of the boundary grid.
    bounds = sorted({w.start for w in tl.windows} | {w.end for w in tl.windows})
    index = {t: i for i, t in enumerate(bounds)}
    active: list[list] = [[] for _ in range(max(len(bounds) - 1, 0))]
    for w in sorted(tl.windows, key=lambda w: (w.switch, w.slot)):
        for i in range(index[w.start], index[w.end]):
            active[i].append(w)

    for i, circuits in enumerate(active):
        t0, t1 = bounds[i], bounds[i + 1]
        span = t1 - t0
        if span <= 0 or not circuits:
            continue
        for w in circuits:
            h = w.switch
            # A window holds n simultaneous circuits — one per (src,
            # perm[src]) port pair — each serving independently at line
            # rate, so every pair gets its own capacity budget.
            for src in range(n):
                dst = int(w.perm[src])
                cap = span * rate
                used = 0.0
                # 1. relay: forward bytes parked here for this output.
                queue = buffers.relay_queue(src, dst)
                for origin in list(queue):
                    if cap - used <= _EPS:
                        break
                    x = buffers.take_relay(src, dst, origin, cap - used)
                    if x <= 0:
                        continue
                    used += x
                    t_land = min(t0 + used / rate, t1)
                    flows.deliver(origin, dst, x, t_land, indirect=True)
                # 2. direct: this circuit's own VOQ.
                if cap - used > _EPS:
                    if not staggered:
                        x = buffers.take_direct(src, dst, cap - used)
                        if x > 0:
                            used += x
                            t_land = min(t0 + used / rate, t1)
                            flows.deliver(src, dst, x, t_land)
                    else:
                        # Service can't start before the flow's release;
                        # window capacity before it is forfeited.
                        f = flows.get(src, dst)
                        rel = f.release if f is not None else 0.0
                        start = max(t0 + used / rate, rel)
                        budget = min(cap - used, (t1 - start) * rate)
                        if budget > _EPS:
                            x = buffers.take_direct(src, dst, budget)
                            if x > 0:
                                used += x
                                t_land = min(start + x / rate, t1)
                                flows.deliver(src, dst, x, t_land)
                # 3. VLB hop-1: detour other destinations with the leftover.
                if vlb and cap - used > _EPS:
                    for d, want in vlb_injections(
                        buffers, src, dst, cap - used
                    ):
                        if staggered:
                            fd = flows.get(src, d)
                            # Unreleased bytes can't be detoured either.
                            if fd is not None and fd.release > t0:
                                continue
                        x = buffers.take_direct(src, d, want)
                        if x <= 0:
                            continue
                        buffers.stage_arrival(dst, src, d, x)
                        used += x
                busy[h] += used / rate
        buffers.commit()  # staged hop-1 arrivals become forwardable

    fct = flows.fct_array()
    arrays = flows.arrays()
    residual = buffers.direct_total() + buffers.buffered_total()
    num_flows = len(flows.flows)
    conserved = bool(np.isfinite(fct).all()) and residual <= tol * max(
        1, num_flows
    )
    finish = tl.finish
    if finish > 0:
        # A switch exposes n port-pairs at once, so its busy time is the
        # summed per-pair transfer time out of n · finish available.
        utilization = busy / (n * finish)
        delta_fraction = tl.delta_time() / finish
        delta_overhead = float(tl.delta_time().sum() / (tl.s * finish))
    else:
        utilization = np.zeros(tl.s)
        delta_fraction = np.zeros(tl.s)
        delta_overhead = 0.0
    return FlowSimReport(
        finish_time=finish,
        fct=fct,
        flow_src=arrays["flow_src"],
        flow_dst=arrays["flow_dst"],
        flow_size=arrays["flow_size"],
        delivered=arrays["delivered"],
        fct_stats=FlowStats.from_sample(fct),
        cct=float(fct.max()) if num_flows else 0.0,
        utilization=utilization,
        delta_fraction=delta_fraction,
        delta_overhead=delta_overhead,
        conserved=conserved,
        residual=float(residual),
        port_ok=port_ok,
        indirected=float(sum(f.indirected for f in flows.flows)),
        options=options,
        extras={
            "vlb": vlb,
            "windows": len(tl.windows),
            "intervals": len(active),
            **(
                {
                    "arrival": options.arrival,
                    "releases": np.array(
                        [f.release for f in flows.flows], dtype=np.float64
                    ),
                }
                if staggered
                else {}
            ),
        },
    )
