"""FlowSimOptions / FlowStats / FlowSimReport — the flow-level result shape.

Every flow-level replay — SPECTRA schedules, rotor round-robin, rotor+VLB —
returns one ``FlowSimReport``: per-flow completion times (FCT), their
distribution (p50/p90/p99/mean/max, linear-interpolated ``np.percentile``),
the coordinated completion time (CCT = last flow's FCT), per-switch
utilization and δ-overhead, and the bytes-conservation verdict that is the
real validation for indirection-dependent schedules (whose matrix-level
Eq. 3 coverage is undefined).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Mapping

import numpy as np

__all__ = ["FlowSimOptions", "FlowStats", "FlowSimReport"]

_INDIRECTION = ("auto", "none", "vlb")
_ARRIVAL = ("start", "uniform")


@dataclass(frozen=True)
class FlowSimOptions:
    """Knobs of the flow-level replay.

    * ``line_rate`` — service rate of one circuit, demand units per time
      unit. 1.0 is the normalized fabric (one unit of demand takes one
      unit of time on one link), matching the matrix-level simulator.
    * ``buffer_limit`` — per-node cap on *indirect* (VLB hop-1) bytes a
      host can hold for later forwarding, in demand units. ``inf`` models
      unbounded host memory; finite values throttle hop-1 injection (a
      full buffer admits nothing until hop-2 drains it).
    * ``indirection`` — ``"none"`` replays circuits directly; ``"vlb"``
      enables 2-hop Valiant load balancing (leftover window capacity
      carries traffic to an intermediate that forwards it across a later
      window); ``"auto"`` (default) enables VLB exactly when the solver's
      report asks for it (``SolveReport.extras["indirection"] == "vlb"``,
      e.g. the ``rotor_vlb`` baseline).
    * ``tol`` — completion/conservation tolerance in demand units.
      ``None`` (default) resolves per schedule backend exactly like the
      matrix simulator's verdict tolerance: 1e-9 for float64 host
      schedules, 1e-4 for float32 device (``"jax"``) schedules, whose
      alphas legitimately undershoot demand at single-precision scale.
    * ``arrival`` — when each flow's bytes become sendable. ``"start"``
      (default) is the classic all-at-t=0 replay the schedule was solved
      for; ``"uniform"`` releases each flow at an independent uniform
      time in ``[0, arrival_span · finish]`` — the demand estimate a real
      controller schedules is collected *during* the period, so bytes
      trickle in while circuits are already up. Capacity a circuit sees
      before its flow's release is lost (no retroactive service), so a
      schedule that is exact at ``line_rate=1`` generally needs headroom
      to complete under staggered arrivals.
    * ``arrival_span`` — fraction of the timeline finish over which
      uniform releases spread (default 0.5).
    * ``arrival_seed`` — RNG seed for the release draw (deterministic
      replays).
    """

    line_rate: float = 1.0
    buffer_limit: float = math.inf
    indirection: str = "auto"
    tol: float | None = None
    arrival: str = "start"
    arrival_span: float = 0.5
    arrival_seed: int = 0

    def __post_init__(self) -> None:
        if self.line_rate <= 0:
            raise ValueError(f"line_rate must be positive, got {self.line_rate}")
        if self.tol is not None and self.tol <= 0:
            raise ValueError(f"tol must be positive, got {self.tol}")
        if self.buffer_limit < 0:
            raise ValueError(
                f"buffer_limit must be nonnegative, got {self.buffer_limit}"
            )
        if self.indirection not in _INDIRECTION:
            raise ValueError(
                f"indirection must be one of {_INDIRECTION}, "
                f"got {self.indirection!r}"
            )
        if self.arrival not in _ARRIVAL:
            raise ValueError(
                f"arrival must be one of {_ARRIVAL}, got {self.arrival!r}"
            )
        if self.arrival_span < 0:
            raise ValueError(
                f"arrival_span must be nonnegative, got {self.arrival_span}"
            )

    @classmethod
    def from_params(cls, params: Mapping[str, Any] | None) -> "FlowSimOptions":
        """Build from a scenario's ``flowsim_params`` mapping."""
        return cls(**dict(params or {}))

    def resolve_tol(self, sched: Any) -> float:
        """The effective tolerance against this schedule (see ``tol``)."""
        if self.tol is not None:
            return self.tol
        return 1e-4 if getattr(sched, "backend", None) == "jax" else 1e-9


@dataclass(frozen=True)
class FlowStats:
    """Distribution summary of one completion-time sample (NaN when empty)."""

    p50: float
    p90: float
    p99: float
    mean: float
    max: float
    count: int

    @classmethod
    def from_sample(cls, sample: np.ndarray) -> "FlowStats":
        sample = np.asarray(sample, dtype=np.float64)
        sample = sample[np.isfinite(sample)]
        if len(sample) == 0:
            nan = float("nan")
            return cls(p50=nan, p90=nan, p99=nan, mean=nan, max=nan, count=0)
        p50, p90, p99 = np.percentile(sample, [50, 90, 99])
        return cls(
            p50=float(p50), p90=float(p90), p99=float(p99),
            mean=float(sample.mean()), max=float(sample.max()),
            count=int(len(sample)),
        )


@dataclass
class FlowSimReport:
    """One flow-level replay of one schedule against one demand matrix."""

    finish_time: float           # Timeline.finish — circuit replay makespan
    fct: np.ndarray              # (F,) per-flow completion time; inf = stuck
    flow_src: np.ndarray         # (F,) source port per flow
    flow_dst: np.ndarray         # (F,) destination port per flow
    flow_size: np.ndarray        # (F,) demand units per flow
    delivered: np.ndarray        # (F,) units delivered to the destination
    fct_stats: FlowStats         # FCT distribution over *completed* flows
    cct: float                   # last completion (inf if any flow is stuck)
    utilization: np.ndarray      # (s,) serve-busy time / finish per switch
    delta_fraction: np.ndarray   # (s,) reconfiguration time / finish
    delta_overhead: float        # aggregate δ share of total switch-time
    conserved: bool              # every flow delivered in full (± tol)
    residual: float              # total undelivered units (incl. buffered)
    port_ok: bool                # no switch served two circuits at once
    indirected: float            # units delivered via a 2-hop VLB detour
    options: FlowSimOptions = field(default_factory=FlowSimOptions)
    extras: dict[str, Any] = field(default_factory=dict)

    @property
    def num_flows(self) -> int:
        return int(len(self.fct))

    @property
    def completed(self) -> int:
        return int(np.isfinite(self.fct).sum())

    @property
    def demand_total(self) -> float:
        return float(self.flow_size.sum())

    @property
    def delivered_total(self) -> float:
        return float(self.delivered.sum())

    @property
    def indirect_fraction(self) -> float:
        """Share of delivered units that took the 2-hop detour."""
        total = self.delivered_total
        return self.indirected / total if total > 0 else 0.0

    def summary(self) -> dict[str, Any]:
        """Flat row (what benchmarks and the smoke lane print)."""
        return {
            "flows": self.num_flows,
            "completed": self.completed,
            "fct_p50": self.fct_stats.p50,
            "fct_p90": self.fct_stats.p90,
            "fct_p99": self.fct_stats.p99,
            "fct_mean": self.fct_stats.mean,
            "fct_max": self.fct_stats.max,
            "cct": self.cct,
            "finish": self.finish_time,
            "util_mean": (
                float(self.utilization.mean()) if len(self.utilization) else 0.0
            ),
            "delta_overhead": self.delta_overhead,
            # Switch-time attribution shares (see repro.obs.timeline_table):
            # serve is util_mean, δ is delta_share, the rest of the horizon
            # is idle — the three sum to 1 per switch by construction.
            "delta_share": (
                float(self.delta_fraction.mean())
                if len(self.delta_fraction)
                else 0.0
            ),
            "idle_share": (
                float(
                    np.clip(
                        1.0 - self.utilization - self.delta_fraction, 0.0, 1.0
                    ).mean()
                )
                if len(self.utilization)
                else 0.0
            ),
            "indirect_frac": self.indirect_fraction,
            "conserved": self.conserved,
            "residual": self.residual,
        }
