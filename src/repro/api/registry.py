"""String-addressable solver registry: ``solve(problem, solver="spectra")``.

Built-in solvers (see README for the table):

    spectra          paper-faithful DECOMPOSE → LPT → EQUALIZE
    spectra_no_eq    same, without the EQUALIZE step (Fig. 7 ablation)
    spectra_pp       beyond-paper best-of ensemble (SPECTRA++)
    spectra_eclipse  ECLIPSE decomposition + our SCHEDULE/EQUALIZE
    baseline_less    LESS-style split-then-schedule comparison baseline
    spectra_jax      fused on-device DECOMPOSE+LPT+EQUALIZE (JAX)

A solver is any callable ``(Problem, SolveOptions) -> SolveReport``;
``Pipeline`` instances qualify. Register your own with ``register_solver``.
"""

from __future__ import annotations

import time
from typing import Callable, Iterable

import numpy as np

from ..core.baselines import baseline_less as _baseline_less
from ..core.improved import spectra_pp as _spectra_pp
from .pipeline import Pipeline
from .problem import Problem, SolveOptions, SolveReport, finish_report

SolverFn = Callable[[Problem, SolveOptions], SolveReport]

_SOLVERS: dict[str, SolverFn] = {}


def register_solver(
    name: str, fn: SolverFn | None = None, *, overwrite: bool = False
):
    """Register a solver under ``name``; usable as a decorator."""

    def _register(f: SolverFn) -> SolverFn:
        if name in _SOLVERS and not overwrite:
            raise ValueError(f"solver {name!r} already registered")
        _SOLVERS[name] = f
        return f

    return _register if fn is None else _register(fn)


def get_solver(name: str) -> SolverFn:
    if name not in _SOLVERS and name.startswith("spectra_online"):
        # The online subsystem registers its stateful solvers on import;
        # importing it here (not at module load) avoids the api ↔ online
        # circular dependency.
        from .. import online  # noqa: F401
    if name not in _SOLVERS:
        raise KeyError(f"unknown solver {name!r}; available: {list_solvers()}")
    return _SOLVERS[name]


def list_solvers() -> list[str]:
    return sorted(_SOLVERS)


def solve(
    problem: Problem,
    *,
    solver: str = "spectra",
    options: SolveOptions | None = None,
) -> SolveReport:
    """Run one registered solver on one problem; uniform SolveReport out."""
    fn = get_solver(solver)
    report = fn(problem, options or SolveOptions())
    report.solver = solver
    return report


# ---------------------------------------------------------------------------
# Built-in registrations.
# ---------------------------------------------------------------------------

def _pipeline_solver(name: str, pipeline: Pipeline) -> None:
    register_solver(
        name, lambda problem, options, _p=pipeline: _p(problem, options)
    )


_pipeline_solver("spectra", Pipeline())
_pipeline_solver("spectra_no_eq", Pipeline(equalize="none"))
_pipeline_solver("spectra_eclipse", Pipeline(decompose="eclipse"))


@register_solver("spectra_pp")
def _solve_spectra_pp(problem: Problem, options: SolveOptions) -> SolveReport:
    # Validation/LB go through finish_report so SolveOptions (validate_tol,
    # compute_lb) behave exactly as on every other solver.
    res = _spectra_pp(
        problem.D, problem.s, problem.delta, validate=False, compute_lb=False
    )
    return finish_report(
        solver="spectra_pp",
        backend="numpy",
        schedule=res.schedule,
        problem=problem,
        options=options,
        runtime_s=res.runtime_s,
        decomposition=res.decomposition,
    )


@register_solver("baseline_less")
def _solve_baseline_less(problem: Problem, options: SolveOptions) -> SolveReport:
    D = np.asarray(problem.D, dtype=np.float64)
    t0 = time.perf_counter()
    sched = _baseline_less(D, problem.s, problem.delta)
    runtime = time.perf_counter() - t0
    return finish_report(
        solver="baseline_less",
        backend="numpy",
        schedule=sched,
        problem=problem,
        options=options,
        runtime_s=runtime,
    )


def _register_jax_solver() -> None:
    try:
        from .jax_backend import solve_spectra_jax
    except Exception:  # pragma: no cover - jax missing: numpy API still works
        return
    register_solver("spectra_jax", solve_spectra_jax)


_register_jax_solver()


def solve_all(
    problem: Problem,
    *,
    solvers: Iterable[str] | None = None,
    options: SolveOptions | None = None,
) -> dict[str, SolveReport]:
    """Run several solvers on the same problem (benchmark convenience)."""
    return {
        name: solve(problem, solver=name, options=options)
        for name in (solvers or list_solvers())
    }
