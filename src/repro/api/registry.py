"""String-addressable solver registry: ``solve(problem, solver="spectra")``.

Built-in solvers (see README for the table):

    spectra          paper-faithful DECOMPOSE → LPT → EQUALIZE
    spectra_no_eq    same, without the EQUALIZE step (Fig. 7 ablation)
    spectra_pp       beyond-paper best-of ensemble (SPECTRA++)
    spectra_eclipse  ECLIPSE decomposition + our SCHEDULE/EQUALIZE
    baseline_less    LESS-style split-then-schedule comparison baseline
    spectra_jax      fused on-device DECOMPOSE+LPT+EQUALIZE (JAX)
    rotor            demand-oblivious round-robin rotor (no matching solves)
    rotor_vlb        rotor sized for 2-hop Valiant load balancing (flowsim)

A solver is any callable ``(Problem, SolveOptions) -> SolveReport``;
``Pipeline`` instances qualify. Register your own with ``register_solver``.
"""

from __future__ import annotations

import time
from typing import Callable, Iterable

import numpy as np

from ..core.baselines import baseline_less as _baseline_less
from ..core.improved import spectra_pp as _spectra_pp
from .pipeline import Pipeline
from .problem import Problem, SolveOptions, SolveReport, finish_report

SolverFn = Callable[[Problem, SolveOptions], SolveReport]

_SOLVERS: dict[str, SolverFn] = {}


def register_solver(
    name: str, fn: SolverFn | None = None, *, overwrite: bool = False
):
    """Register a solver under ``name``; usable as a decorator."""

    def _register(f: SolverFn) -> SolverFn:
        if name in _SOLVERS and not overwrite:
            raise ValueError(f"solver {name!r} already registered")
        _SOLVERS[name] = f
        return f

    return _register if fn is None else _register(fn)


def get_solver(name: str) -> SolverFn:
    if name not in _SOLVERS and name.startswith("spectra_online"):
        # The online subsystem registers its stateful solvers on import;
        # importing it here (not at module load) avoids the api ↔ online
        # circular dependency.
        from .. import online  # noqa: F401
    if name not in _SOLVERS:
        raise KeyError(f"unknown solver {name!r}; available: {list_solvers()}")
    return _SOLVERS[name]


def list_solvers() -> list[str]:
    return sorted(_SOLVERS)


def solve(
    problem: Problem,
    *,
    solver: str = "spectra",
    options: SolveOptions | None = None,
) -> SolveReport:
    """Run one registered solver on one problem; uniform SolveReport out."""
    fn = get_solver(solver)
    report = fn(problem, options or SolveOptions())
    report.solver = solver
    return report


# ---------------------------------------------------------------------------
# Built-in registrations.
# ---------------------------------------------------------------------------

def _pipeline_solver(name: str, pipeline: Pipeline) -> None:
    register_solver(
        name, lambda problem, options, _p=pipeline: _p(problem, options)
    )


_pipeline_solver("spectra", Pipeline())
_pipeline_solver("spectra_no_eq", Pipeline(equalize="none"))
_pipeline_solver("spectra_eclipse", Pipeline(decompose="eclipse"))


@register_solver("spectra_pp")
def _solve_spectra_pp(problem: Problem, options: SolveOptions) -> SolveReport:
    # Validation/LB go through finish_report so SolveOptions (validate_tol,
    # compute_lb) behave exactly as on every other solver.
    res = _spectra_pp(
        problem.D, problem.s, problem.delta, validate=False, compute_lb=False
    )
    return finish_report(
        solver="spectra_pp",
        backend="numpy",
        schedule=res.schedule,
        problem=problem,
        options=options,
        runtime_s=res.runtime_s,
        decomposition=res.decomposition,
    )


@register_solver("baseline_less")
def _solve_baseline_less(problem: Problem, options: SolveOptions) -> SolveReport:
    D = np.asarray(problem.D, dtype=np.float64)
    t0 = time.perf_counter()
    sched = _baseline_less(D, problem.s, problem.delta)
    runtime = time.perf_counter() - t0
    return finish_report(
        solver="baseline_less",
        backend="numpy",
        schedule=sched,
        problem=problem,
        options=options,
        runtime_s=runtime,
    )


# ---------------------------------------------------------------------------
# Demand-oblivious rotor baselines (RotorNet/Opus lineage): fixed round-robin
# permutation sequences, no matching solves. The counterpoint SPECTRA is
# measured against at the flow level (repro.flowsim).
# ---------------------------------------------------------------------------

def _rotor_common(problem: Problem) -> tuple[np.ndarray, float, float, bool]:
    D = np.asarray(problem.D, dtype=np.float64)
    peak = float(D.max(initial=0.0))
    diag_max = float(np.diag(D).max(initial=0.0)) if D.size else 0.0
    return D, peak, diag_max, diag_max > 0


@register_solver("rotor")
def _solve_rotor(problem: Problem, options: SolveOptions) -> SolveReport:
    """Pure rotor: uniform slots sized so *direct* service covers D.

    Demand-obliviousness is structural — the permutation sequence is the
    fixed round-robin cycle — but a covering schedule needs one scalar of
    demand knowledge: the slot length, sized to the worst matrix entry
    (``slot · cycles = max D``). That scalar is exactly why rotors price
    skewed traffic so badly: every port pair pays for the heaviest one.
    ``options.extra["rotor_cycles"]`` (default 1) trades slot granularity
    for extra δ rounds.
    """
    from ..core.baselines import rotor_schedule
    from ..core.schedule import ParallelSchedule, SwitchSchedule

    D, peak, _, has_diag = _rotor_common(problem)
    cycles = int(options.extra.get("rotor_cycles", 1))
    t0 = time.perf_counter()
    if peak <= 0:  # nothing to serve: no circuits, no reconfigurations
        sched = ParallelSchedule(
            switches=[SwitchSchedule() for _ in range(problem.s)],
            delta=problem.delta,
        )
        slot = 0.0
    else:
        slot = peak / cycles
        sched = rotor_schedule(
            problem.n, problem.s, problem.delta, slot,
            cycles=cycles, include_identity=has_diag,
        )
    runtime = time.perf_counter() - t0
    return finish_report(
        solver="rotor",
        backend="numpy",
        schedule=sched,
        problem=problem,
        options=options,
        runtime_s=runtime,
        extras={"rotor": {"slot": slot, "cycles": cycles}},
    )


@register_solver("rotor_vlb")
def _solve_rotor_vlb(problem: Problem, options: SolveOptions) -> SolveReport:
    """Rotor + 2-hop VLB: slots sized for *indirected* traffic, not peaks.

    Valiant load balancing uniformizes any admissible matrix: per rotor
    cycle, the fluid load on every port pair is at most
    ``S = (max row sum + max col sum) / (n − 1)`` — a function of line
    sums, not of the worst entry — so the slots are sized to ``S`` (over
    ``rotor_cycles``, default 2) plus ``rotor_safety_cycles`` (default 3)
    extra cycles for store-and-forward latency: hop-1 bytes parked at an
    intermediate can only leave on a *later* window. (The fluid bound is
    exact only in the limit; at paper scale the last straggler bytes can
    land a window after two safety cycles end, hence three.)

    The returned schedule does NOT cover D in the matrix sense (Eq. 3) —
    by design: direct slots are far smaller than skewed entries. Coverage
    validation is skipped (``validated=False``) and correctness is
    instead the flow-level conservation check:
    ``repro.flowsim.simulate_flows`` (which auto-enables VLB via
    ``extras["indirection"]``) must deliver every byte.
    """
    import dataclasses

    from ..core.baselines import rotor_schedule
    from ..core.schedule import ParallelSchedule, SwitchSchedule

    D, peak, diag_max, has_diag = _rotor_common(problem)
    base_cycles = int(options.extra.get("rotor_cycles", 2))
    safety = int(options.extra.get("rotor_safety_cycles", 3))
    cycles = base_cycles + safety
    t0 = time.perf_counter()
    if peak <= 0:
        sched = ParallelSchedule(
            switches=[SwitchSchedule() for _ in range(problem.s)],
            delta=problem.delta,
        )
        slot = 0.0
    else:
        n = problem.n
        fluid = (
            float(D.sum(axis=1).max()) + float(D.sum(axis=0).max())
        ) / max(n - 1, 1)
        # Diagonal demand can't be indirected — only the identity shift
        # serves it, so direct slots must cover it over all cycles.
        slot = max(fluid / base_cycles, diag_max / cycles)
        sched = rotor_schedule(
            n, problem.s, problem.delta, slot,
            cycles=cycles, include_identity=has_diag,
        )
    runtime = time.perf_counter() - t0
    report = finish_report(
        solver="rotor_vlb",
        backend="numpy",
        schedule=sched,
        problem=problem,
        options=options if not options.validate
        else dataclasses.replace(options, validate=False),
        runtime_s=runtime,
        extras={
            "indirection": "vlb",
            "rotor": {"slot": slot, "cycles": cycles,
                      "base_cycles": base_cycles, "safety_cycles": safety},
            "warnings": [
                "schedule covers demand only under 2-hop VLB indirection; "
                "validate with repro.flowsim conservation, not Eq. 3"
            ],
        },
    )
    return report


def _register_jax_solver() -> None:
    try:
        from .jax_backend import solve_spectra_jax
    except Exception:  # pragma: no cover - jax missing: numpy API still works
        return
    register_solver("spectra_jax", solve_spectra_jax)


_register_jax_solver()


def solve_all(
    problem: Problem,
    *,
    solvers: Iterable[str] | None = None,
    options: SolveOptions | None = None,
) -> dict[str, SolveReport]:
    """Run several solvers on the same problem (benchmark convenience)."""
    return {
        name: solve(problem, solver=name, options=options)
        for name in (solvers or list_solvers())
    }
