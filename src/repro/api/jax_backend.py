"""JAX backend for the unified solver API.

On-device DECOMPOSE (+ device LPT for telemetry) with the ε-scaling auction,
then host-side SCHEDULE + EQUALIZE to materialize a concrete
``ParallelSchedule`` — the same split as ``repro.core.jaxopt``: the k MWM
solves dominate and run on the accelerator, the O(k·s) list surgery stays on
the host.

``decompose_many`` is the vmapped entry point used by ``solve_many``: one
device call decomposes a whole stack of demand matrices.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.equalize import equalize
from ..core.jaxopt.decompose_jax import (
    JaxDecomposition,
    decompose_jax,
    lpt_schedule_jax,
    to_decomposition,
)
from ..core.schedule import ParallelSchedule, schedule_lpt
from .problem import Problem, SolveOptions, SolveReport, finish_report


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _decompose_many_jit(Ds: jax.Array, *, use_kernel: bool = False) -> JaxDecomposition:
    return jax.vmap(lambda D: decompose_jax(D, use_kernel=use_kernel))(Ds)


def decompose_many(Ds, *, use_kernel: bool = False) -> JaxDecomposition:
    """Batched on-device decomposition of stacked (B, n, n) demand matrices."""
    Ds = jnp.asarray(Ds, jnp.float32)
    if Ds.ndim != 3 or Ds.shape[1] != Ds.shape[2]:
        raise ValueError(f"expected stacked square matrices (B, n, n), got {Ds.shape}")
    return _decompose_many_jit(Ds, use_kernel=use_kernel)


def _index_batch(dec: JaxDecomposition, b: int) -> JaxDecomposition:
    return JaxDecomposition(
        perms=dec.perms[b], alphas=dec.alphas[b], k=dec.k[b], converged=dec.converged[b]
    )


def _finish_on_host(
    dec: JaxDecomposition,
    problem: Problem,
    options: SolveOptions,
    runtime_s: float,
    *,
    do_equalize: bool = True,
) -> SolveReport:
    host = to_decomposition(dec)
    sched: ParallelSchedule = schedule_lpt(host, problem.s, problem.delta)
    if do_equalize:
        sched = equalize(sched)
    return finish_report(
        solver="spectra_jax",
        backend="jax",
        schedule=sched,
        problem=problem,
        options=options,
        runtime_s=runtime_s,
        decomposition=host,
        extras={"k": int(dec.k), "converged": bool(dec.converged)},
    )


def solve_spectra_jax(problem: Problem, options: SolveOptions) -> SolveReport:
    """Registry entry: one instance, on-device decompose, host equalize."""
    use_kernel = bool(options.extra.get("use_kernel", False))
    do_equalize = bool(options.extra.get("equalize", True))
    D = jnp.asarray(np.asarray(problem.D), jnp.float32)
    t0 = time.perf_counter()
    dec = decompose_jax(D, use_kernel=use_kernel)
    _, _, device_makespan = lpt_schedule_jax(
        dec, problem.s, jnp.float32(problem.delta)
    )
    jax.block_until_ready(device_makespan)
    report = _finish_on_host(
        dec, problem, options, time.perf_counter() - t0, do_equalize=do_equalize
    )
    report.extras["device_lpt_makespan"] = float(device_makespan)
    return report


def solve_many_jax(
    Ds: np.ndarray,
    s: int,
    delta: float,
    options: SolveOptions,
) -> list[SolveReport]:
    """Batched path for ``solve_many``: one vmapped device call for the whole
    stack, then per-instance host SCHEDULE + EQUALIZE + validation."""
    use_kernel = bool(options.extra.get("use_kernel", False))
    do_equalize = bool(options.extra.get("equalize", True))
    # Only the device input is float32; reports validate/lower-bound against
    # the caller's matrices, exactly like the single-instance path.
    mats = np.asarray(Ds, dtype=np.float64)
    t0 = time.perf_counter()
    decs = decompose_many(mats.astype(np.float32), use_kernel=use_kernel)
    jax.block_until_ready(decs.alphas)
    device_s = time.perf_counter() - t0
    B = mats.shape[0]
    reports = []
    for b in range(B):
        problem = Problem(mats[b], s, delta)
        rep = _finish_on_host(
            _index_batch(decs, b),
            problem,
            options,
            device_s / B,
            do_equalize=do_equalize,
        )
        rep.extras.update(batched=True, batch_size=B)
        reports.append(rep)
    return reports
