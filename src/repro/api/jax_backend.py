"""JAX backend for the unified solver API: the whole pipeline on device.

DECOMPOSE (ε-scaling auction), SCHEDULE (device LPT), and EQUALIZE
(``lax.while_loop`` over the dense ``DeviceSchedule`` IR) are fused into one
jitted call — ``repro.core.jaxopt.spectra_jax_e2e`` — and ``solve_many``
drains a whole stack of demand matrices through its ``vmap`` in a single
device call. Reports come back with device-computed makespans and *lazy*
host schedules: the Python-object ``ParallelSchedule`` is only materialized
when something touches it (validation, simulation, inspection), so the hot
path never loops over instances on the host.

``SolveOptions.extra`` knobs: ``use_kernel`` (Pallas kernels; unset →
backend detection via ``kernels.backend.resolve_use_kernel``: on by default
on TPU, off elsewhere unless ``REPRO_USE_KERNEL`` forces interpret mode),
``equalize`` (default True), ``merge_aware`` (SPECTRA++ merge-aware device
EQUALIZE), ``extra_slots`` (EQUALIZE split headroom, default 64),
``matcher`` (device MWM solver name from ``core.jaxopt.matching.MATCHERS``;
unset → autotuned per shape bucket by ``matching.default_matcher``:
``auction`` at n ≤ 32, ``auction_fr`` to 128, ``auction_fused`` above),
``repair_rounds`` (post-REFINE device local-search sweeps, default 0 =
paper-faithful Alg. 1+2).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.decompose import Decomposition
from ..core.equalize import equalize
from ..core.jaxopt.e2e import E2EResult, spectra_jax_e2e, spectra_jax_e2e_many
from ..core.schedule_ir import DeviceSchedule, LazySchedule, ir_to_schedule
from ..kernels.backend import resolve_use_kernel
from ..obs.trace import get_tracer
from .problem import Problem, SolveOptions, SolveReport, finish_report


def _e2e_kwargs(options: SolveOptions, n: int) -> dict:
    from ..core.jaxopt.matching import default_matcher

    return dict(
        use_kernel=resolve_use_kernel(options.extra.get("use_kernel")),
        do_equalize=bool(options.extra.get("equalize", True)),
        merge_aware=bool(options.extra.get("merge_aware", False)),
        extra_slots=int(options.extra.get("extra_slots", 64)),
        # Autotuned per shape bucket unless the caller pins one: every
        # instance in a fused dispatch shares n, so the bucket IS the
        # autotuning granularity.
        matcher=str(options.extra.get("matcher") or default_matcher(n)),
        repair_rounds=int(options.extra.get("repair_rounds", 0)),
    )


class _LazyDecomposition(Decomposition):
    """A ``Decomposition`` whose Python lists build on first access.

    Keeps the batched hot path free of per-round list construction: the
    report carries the per-instance arrays (one vectorized copy), and the
    O(k) object materialization happens only if a consumer actually reads
    ``perms``/``alphas``.
    """

    def __init__(self, perms_arr: np.ndarray, alphas_arr: np.ndarray):
        self._perms_arr = perms_arr
        self._alphas_arr = alphas_arr
        self._inner: Decomposition | None = None

    def _force(self) -> Decomposition:
        if self._inner is None:
            self._inner = Decomposition(
                perms=[p.astype(np.int64) for p in self._perms_arr],
                alphas=[float(a) for a in self._alphas_arr],
            )
        return self._inner

    @property
    def perms(self):  # type: ignore[override]
        return self._force().perms

    @property
    def alphas(self):  # type: ignore[override]
        return self._force().alphas


class _HostBatch:
    """One device→host transfer for a whole fused batch, shared by B reports."""

    def __init__(
        self,
        res: E2EResult,
        deltas: np.ndarray,
        *,
        merge_aware: bool = False,
        matcher: str = "auction",
        repair_rounds: int = 0,
        use_kernel: bool = False,
        **_ignored,
    ):
        sched = res.schedule
        self.merge_aware = merge_aware
        self.matcher = matcher
        self.repair_rounds = repair_rounds
        self.use_kernel = use_kernel
        self.perms = np.asarray(sched.perms)
        self.alphas = np.asarray(sched.alphas, dtype=np.float64)
        self.switch = np.asarray(sched.switch)
        self.makespans = np.asarray(res.makespan, dtype=np.float64)
        self.lpt_makespans = np.asarray(res.lpt_makespan, dtype=np.float64)
        self.dec_perms = np.asarray(res.dec.perms)
        self.dec_alphas = np.asarray(res.dec.alphas, dtype=np.float64)
        self.k = np.asarray(res.dec.k)
        self.converged = np.asarray(res.dec.converged)
        self.eq_exhausted = np.asarray(res.eq_exhausted)
        self.lbs = np.asarray(res.lb, dtype=np.float64)
        # Per-instance δ (trace-aware sweeps batch mixed δs in one dispatch).
        B = self.makespans.shape[0]
        self.deltas = np.broadcast_to(
            np.asarray(deltas, dtype=np.float64), (B,)
        )

    def decomposition(self, b: int) -> Decomposition:
        """Host Decomposition of instance b (pre-EQUALIZE weights), as the
        pre-fusion backend attached to every report — lazily materialized,
        with per-instance array copies so it doesn't pin the batch."""
        k = int(self.k[b])
        return _LazyDecomposition(
            self.dec_perms[b][:k].copy(), self.dec_alphas[b][:k].copy()
        )

    def schedule_thunk(self, b: int, s: int):
        # Copy the per-instance slices so a report that outlives the flush
        # pins O(R·n) of its own data, not the whole batch's arrays.
        perms = self.perms[b].copy()
        alphas = self.alphas[b].copy()
        switch = self.switch[b].copy()
        delta = float(self.deltas[b])
        exhausted = bool(self.eq_exhausted[b])
        merge_aware = self.merge_aware

        def build():
            ds = DeviceSchedule(
                perms=perms, alphas=alphas, switch=switch, delta=delta
            )
            sched = ir_to_schedule(ds, s)
            if exhausted:
                # Device EQUALIZE ran out of split headroom; host EQUALIZE
                # picks up exactly where it stopped, restoring host parity.
                sched = equalize(sched, merge_aware=merge_aware)
            return sched

        return build

    def report(
        self,
        b: int,
        problem: Problem,
        options: SolveOptions,
        runtime_s: float,
        *,
        extras: dict | None = None,
        device_lb: bool = True,
    ) -> SolveReport:
        lazy = LazySchedule(self.schedule_thunk(b, problem.s), float(self.deltas[b]))
        device_makespan = float(self.makespans[b])
        exhausted = bool(self.eq_exhausted[b])
        converged = bool(self.converged[b])
        # Warning-bearing surface: device-side degradations that would
        # otherwise hide in telemetry booleans. Consumers can gate on
        # ``extras["warnings"]`` without knowing each flag.
        warnings: list[str] = []
        if not converged:
            warnings.append(
                f"device matcher {self.matcher!r} exhausted its iteration "
                "budget (JaxDecomposition.converged=False); the matching — "
                "and the decomposition built on it — may be suboptimal"
            )
        if exhausted:
            warnings.append(
                "device EQUALIZE ran out of split headroom (raise "
                "options.extra['extra_slots']); host EQUALIZE finished the "
                "schedule at materialization"
            )
        all_extras = {
            "k": int(self.k[b]),
            "converged": converged,
            "matcher": self.matcher,
            "use_kernel": self.use_kernel,
            "repair_rounds": self.repair_rounds,
            "device_makespan": device_makespan,
            "device_lpt_makespan": float(self.lpt_makespans[b]),
            # True when device EQUALIZE ran out of split headroom before the
            # ≤δ spread (raise options.extra["extra_slots"]); the schedule
            # thunk finishes with host EQUALIZE, so metrics come from it.
            "eq_exhausted": exhausted,
            "warnings": warnings,
        }
        all_extras.update(extras or {})
        return finish_report(
            solver="spectra_jax",
            backend="jax",
            schedule=lazy,
            problem=problem,
            options=options,
            runtime_s=runtime_s,
            decomposition=self.decomposition(b),
            # Exhausted instances materialize eagerly so makespan/configs
            # reflect the host-finished schedule, not the truncated one.
            makespan=None if exhausted else device_makespan,
            num_configs=(
                None if exhausted else int((self.switch[b] >= 0).sum())
            ),
            # Batched path: §IV bound computed inside the fused device call
            # (float32) — no per-instance host loop. Single-instance solves
            # keep the exact float64 host bound (device_lb=False): one cheap
            # O(n²) pass with nothing to amortize.
            lower_bound=float(self.lbs[b]) if device_lb else None,
            extras=all_extras,
        )


def solve_spectra_jax(problem: Problem, options: SolveOptions) -> SolveReport:
    """Registry entry: one instance, full DECOMPOSE→SCHEDULE→EQUALIZE on device."""
    D = jnp.asarray(np.asarray(problem.D), jnp.float32)
    kwargs = _e2e_kwargs(options, problem.n)
    t0 = time.perf_counter()
    res = spectra_jax_e2e(D, problem.s, jnp.float32(problem.delta), **kwargs)
    jax.block_until_ready(res.makespan)
    runtime_s = time.perf_counter() - t0
    batch = _HostBatch(
        jax.tree_util.tree_map(lambda x: x[None], res),
        np.array([problem.delta]),
        **kwargs,
    )
    return batch.report(0, problem, options, runtime_s, device_lb=False)


class PendingBatch:
    """A dispatched-but-uncollected fused batch: the async serving handle.

    ``dispatch_many_jax`` returns immediately after enqueueing the fused
    device call — JAX dispatches asynchronously, so the solve runs on the
    XLA worker threads while the host does other work (e.g. installing the
    *previous* period's schedules — the double-buffered serving loop in
    ``repro.serve.server``). ``collect()`` performs the only
    synchronization: the ``np.asarray`` conversions inside ``_HostBatch``
    block on each buffer as it is read — there is no
    ``jax.block_until_ready`` barrier anywhere on this path.
    """

    def __init__(self, res: E2EResult, mats, s, deltas, options, kwargs, t0):
        self._res = res
        self._mats = mats
        self._s = s
        self._deltas = deltas
        self._options = options
        self._kwargs = kwargs
        self._t0 = t0
        self._reports: list[SolveReport] | None = None

    def __len__(self) -> int:
        return int(self._mats.shape[0])

    @property
    def ready(self) -> bool:
        """Non-blocking readiness probe of the device computation."""
        try:
            return bool(self._res.makespan.is_ready())
        except AttributeError:  # non-jax array (already concrete)
            return True

    def collect(self) -> list[SolveReport]:
        """Wait for the device results and build the per-ticket reports.

        Idempotent — repeated calls return the same report list. Runtime
        accounting spans dispatch → collection (the wall-clock the device
        work occupied, whether or not the host overlapped it)."""
        if self._reports is None:
            tracer = get_tracer()
            with tracer.span(
                "jax.collect",
                {"B": len(self)} if tracer.enabled else None,
            ):
                batch = _HostBatch(self._res, self._deltas, **self._kwargs)
                device_s = time.perf_counter() - self._t0
                B = len(self)
                self._reports = [
                    batch.report(
                        b,
                        Problem(self._mats[b], self._s, float(self._deltas[b])),
                        self._options,
                        device_s / B,
                        extras={"batched": True, "batch_size": B, "fused": True},
                    )
                    for b in range(B)
                ]
        return self._reports


def dispatch_many_jax(
    Ds: np.ndarray,
    s: int,
    delta,
    options: SolveOptions,
) -> PendingBatch:
    """Enqueue one fused batched solve and return without waiting.

    The returned ``PendingBatch`` owns the in-flight device arrays;
    ``collect()`` synchronizes. See ``solve_many_jax`` for the batching
    semantics — this is the same dispatch with the barrier split off."""
    # Only the device input is float32; reports validate against the
    # caller's matrices, exactly like the single-instance path.
    mats = np.asarray(Ds, dtype=np.float64)
    B = mats.shape[0]
    deltas = np.broadcast_to(np.asarray(delta, dtype=np.float64), (B,))
    kwargs = _e2e_kwargs(options, int(mats.shape[-1]))
    tracer = get_tracer()
    t0 = time.perf_counter()
    with tracer.span(
        "jax.dispatch",
        {"B": B, "n": int(mats.shape[-1]), "s": int(s)}
        if tracer.enabled
        else None,
    ):
        res = spectra_jax_e2e_many(
            mats.astype(np.float32), s, deltas.astype(np.float32), **kwargs
        )
        if tracer.enabled and tracer.device_sync:
            # Opt-in: land device time inside the span that launched it
            # (serializes the async pipeline — tracing-only behavior).
            jax.block_until_ready(res.makespan)
    return PendingBatch(res, mats, s, deltas, options, kwargs, t0)


def solve_many_jax(
    Ds: np.ndarray,
    s: int,
    delta,
    options: SolveOptions,
) -> list[SolveReport]:
    """Batched path for ``solve_many``: DECOMPOSE, SCHEDULE, *and* EQUALIZE
    for the whole stack in one vmapped device call; per-instance host
    schedules materialize lazily (on validation/access), never eagerly.
    §IV lower bounds come from the same fused call (float32, parity ≤1e-7
    rel) instead of a per-instance host loop. ``delta`` is a scalar or a
    per-instance (B,) vector (trace-aware δ sweeps) — the fused call vmaps
    over it either way. Synchronous dispatch + collect; async callers use
    ``dispatch_many_jax`` and collect when they need the results."""
    return dispatch_many_jax(Ds, s, delta, options).collect()
