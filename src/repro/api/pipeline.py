"""Composable DECOMPOSE → SCHEDULE → EQUALIZE pipelines (declarative stages).

A ``Pipeline`` names its three stages instead of closing over functions, so
variants like "SPECTRA (ECLIPSE)" or the wrap-around scheduler are data::

    Pipeline()                                  # paper-faithful SPECTRA
    Pipeline(equalize="none")                   # SPECTRA w/o EQUALIZE
    Pipeline(decompose="eclipse")               # SPECTRA (ECLIPSE)
    Pipeline(schedule="wrap", equalize="none")  # wrap-around scheduler

Each stage is looked up in a registry (``DECOMPOSERS`` / ``SCHEDULERS`` /
``EQUALIZERS``); ``register_stage`` adds new ones without touching this
module.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

from ..core.baselines import eclipse_decompose
from ..core.decompose import Decomposition, decompose
from ..core.equalize import equalize
from ..core.improved import local_search, schedule_wrap
from ..core.schedule import ParallelSchedule, schedule_lpt
from ..obs.trace import get_tracer
from .problem import Problem, SolveOptions, SolveReport, finish_report

# Stage signatures. Every stage sees the Problem so stage functions can use
# s / delta without closures (ECLIPSE's decomposition needs delta, say).
DecomposeFn = Callable[..., Decomposition]        # (problem, **kw) -> dec
ScheduleFn = Callable[..., ParallelSchedule]      # (dec, problem, **kw) -> sched
EqualizeFn = Callable[..., ParallelSchedule]      # (sched, problem, **kw) -> sched

def _decompose_jax_stage(
    problem,
    *,
    matcher: str = "auction",
    repair_rounds: int = 0,
    use_kernel: bool | None = None,
    **kw,
):
    # Imported lazily so the numpy stage tables never pay for (or require)
    # jax; the device decomposition materializes to a host Decomposition.
    import jax.numpy as jnp
    import numpy as np

    from ..core.jaxopt.decompose_jax import decompose_jax, to_decomposition
    from ..kernels.backend import resolve_use_kernel

    dec = decompose_jax(
        jnp.asarray(np.asarray(problem.D), jnp.float32),
        matcher=matcher,
        repair_rounds=repair_rounds,
        use_kernel=resolve_use_kernel(use_kernel),
        **kw,
    )
    return to_decomposition(dec)


DECOMPOSERS: dict[str, DecomposeFn] = {
    "spectra": lambda problem, **kw: decompose(problem.D, **kw),
    "eclipse": lambda problem, **kw: eclipse_decompose(problem.D, problem.delta, **kw),
    # Device decompositions (materialized to host for the numpy stages):
    # jax_auction is the paper-faithful Alg. 1+2 on the device matcher;
    # jax_refined adds the bounded post-REFINE local-search sweeps.
    "jax_auction": _decompose_jax_stage,
    "jax_refined": lambda problem, **kw: _decompose_jax_stage(
        problem, **{"repair_rounds": 2, **kw}
    ),
}

SCHEDULERS: dict[str, ScheduleFn] = {
    "lpt": lambda dec, problem, **kw: schedule_lpt(dec, problem.s, problem.delta),
    "lpt_local_search": lambda dec, problem, **kw: local_search(
        schedule_lpt(dec, problem.s, problem.delta), **kw
    ),
    "wrap": lambda dec, problem, **kw: schedule_wrap(
        dec, problem.s, problem.delta, **kw
    ),
}

def _equalize_jax_stage(sched, problem, *, merge_aware: bool = False, **kw):
    # Imported lazily so the numpy stage tables never pay for (or require)
    # jax; the device EQUALIZE round-trips through the DeviceSchedule IR.
    from ..core.jaxopt.equalize_jax import equalize_jax

    return equalize_jax(sched, problem.n, merge_aware=merge_aware, **kw)


EQUALIZERS: dict[str, EqualizeFn] = {
    "none": lambda sched, problem, **kw: sched,
    "standard": lambda sched, problem, **kw: equalize(sched, **kw),
    "merge_aware": lambda sched, problem, **kw: equalize(
        sched, merge_aware=True, **kw
    ),
    "jax": _equalize_jax_stage,
    "jax_merge_aware": lambda sched, problem, **kw: _equalize_jax_stage(
        sched, problem, merge_aware=True, **kw
    ),
}

_STAGE_TABLES = {
    "decompose": DECOMPOSERS,
    "schedule": SCHEDULERS,
    "equalize": EQUALIZERS,
}


def register_stage(kind: str, name: str, fn: Callable, *, overwrite: bool = False) -> None:
    """Add a named stage implementation (kind ∈ decompose/schedule/equalize)."""
    try:
        table = _STAGE_TABLES[kind]
    except KeyError:
        raise ValueError(
            f"unknown stage kind {kind!r}; expected one of {sorted(_STAGE_TABLES)}"
        ) from None
    if name in table and not overwrite:
        raise ValueError(f"{kind} stage {name!r} already registered")
    table[name] = fn


def _lookup(kind: str, name: str) -> Callable:
    table = _STAGE_TABLES[kind]
    if name not in table:
        raise KeyError(
            f"unknown {kind} stage {name!r}; available: {sorted(table)}"
        )
    return table[name]


@dataclass(frozen=True)
class Pipeline:
    """Declarative three-stage solver; callable as ``pipeline(problem, options)``."""

    decompose: str = "spectra"
    schedule: str = "lpt"
    equalize: str = "standard"
    decompose_kwargs: Mapping[str, Any] = field(default_factory=dict)
    schedule_kwargs: Mapping[str, Any] = field(default_factory=dict)
    equalize_kwargs: Mapping[str, Any] = field(default_factory=dict)

    def describe(self) -> str:
        return f"{self.decompose} → {self.schedule} → {self.equalize}"

    @property
    def backend(self) -> str:
        """"jax" when any stage runs on device (names the float32 tolerance)."""
        stages = (self.decompose, self.schedule, self.equalize)
        return "jax" if any(name.startswith("jax") for name in stages) else "numpy"

    def __call__(
        self,
        problem: Problem,
        options: SolveOptions = SolveOptions(),
        *,
        solver_name: str | None = None,
    ) -> SolveReport:
        dec_fn = _lookup("decompose", self.decompose)
        sched_fn = _lookup("schedule", self.schedule)
        eq_fn = _lookup("equalize", self.equalize)
        tracer = get_tracer()
        t0 = time.perf_counter()
        with tracer.span(
            "decompose", {"impl": self.decompose} if tracer.enabled else None
        ):
            dec = dec_fn(problem, **dict(self.decompose_kwargs))
        with tracer.span(
            "schedule", {"impl": self.schedule} if tracer.enabled else None
        ):
            sched = sched_fn(dec, problem, **dict(self.schedule_kwargs))
        with tracer.span(
            "equalize", {"impl": self.equalize} if tracer.enabled else None
        ):
            sched = eq_fn(sched, problem, **dict(self.equalize_kwargs))
        runtime = time.perf_counter() - t0
        return finish_report(
            solver=solver_name or self.describe(),
            backend=self.backend,
            schedule=sched,
            problem=problem,
            options=options,
            runtime_s=runtime,
            decomposition=dec,
            extras={"pipeline": self.describe()},
        )
