"""Problem / SolveOptions / SolveReport — one input and one output shape.

Every solver in the registry (``repro.api.registry``) maps a
``(Problem, SolveOptions)`` pair to a ``SolveReport``, regardless of
which backend (numpy host path or on-device JAX path) produced it.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Mapping

import numpy as np

from ..core.decompose import Decomposition
from ..core.schedule import ParallelSchedule


@dataclass(frozen=True)
class Problem:
    """One parallel-OCS scheduling instance: demand D over s switches, delay δ."""

    D: np.ndarray  # (n, n) nonnegative demand matrix
    s: int         # number of parallel switches
    delta: float   # reconfiguration delay, in demand-time units

    def __post_init__(self) -> None:
        D = np.asarray(self.D)
        if D.ndim != 2 or D.shape[0] != D.shape[1]:
            raise ValueError(f"D must be a square matrix, got shape {D.shape}")
        if self.s < 1:
            raise ValueError(f"need at least one switch, got s={self.s}")
        if self.delta < 0:
            raise ValueError(f"delta must be nonnegative, got {self.delta}")
        object.__setattr__(self, "D", D)

    @property
    def n(self) -> int:
        return int(self.D.shape[0])


@dataclass(frozen=True)
class SolveOptions:
    """Cross-solver knobs. Solver-specific extras go in ``extra``."""

    validate: bool = True          # check Eq. 3 coverage on the result
    validate_tol: float | None = None  # None → backend default (1e-9 / 1e-4)
    compute_lb: bool = True        # attach the §IV lower bound
    extra: Mapping[str, Any] = field(default_factory=dict)  # per-solver kwargs

    def tol(self, backend: str) -> float:
        if self.validate_tol is not None:
            return self.validate_tol
        return 1e-4 if backend == "jax" else 1e-9


@dataclass
class SolveReport:
    """Uniform result of any registered solver."""

    solver: str                    # registry name that produced this
    backend: str                   # "numpy" or "jax"
    schedule: ParallelSchedule
    makespan: float
    lower_bound: float             # NaN when compute_lb=False
    num_configs: int
    runtime_s: float
    validated: bool                # True iff Eq. 3 coverage was checked
    decomposition: Decomposition | None = None
    extras: dict[str, Any] = field(default_factory=dict)

    @property
    def optimality_gap(self) -> float:
        """makespan / lower_bound; 1.0 for the degenerate 0/0 (empty demand)."""
        from ..core.lower_bounds import optimality_gap

        return optimality_gap(self.makespan, self.lower_bound)


def finish_report(
    *,
    solver: str,
    backend: str,
    schedule: ParallelSchedule,
    problem: Problem,
    options: SolveOptions,
    runtime_s: float,
    decomposition: Decomposition | None = None,
    extras: dict[str, Any] | None = None,
    makespan: float | None = None,
    num_configs: int | None = None,
    lower_bound: float | None = None,
) -> SolveReport:
    """Validate + lower-bound a finished schedule into a SolveReport.

    ``makespan``/``num_configs``/``lower_bound`` may be supplied by backends
    that already computed them (e.g. on device, against a lazily-materialized
    schedule — the JAX backend attaches per-instance §IV bounds from the
    fused batched call); when omitted they are derived on the host —
    makespan from ``schedule``, which is also what happens whenever
    validation runs, so the reported makespan always agrees exactly with the
    schedule the validator (and simulator) saw.
    """
    from ..core.lower_bounds import lower_bound as _host_lower_bound

    validated = False
    if options.validate:
        schedule.validate(problem.D, tol=options.tol(backend))
        validated = True
    if makespan is None or validated:
        makespan = schedule.makespan()
    if num_configs is None:
        num_configs = schedule.num_configs()
    if not options.compute_lb:
        lb = float("nan")
    elif lower_bound is not None:
        lb = float(lower_bound)
    else:
        lb = _host_lower_bound(problem.D, problem.s, problem.delta)
    return SolveReport(
        solver=solver,
        backend=backend,
        schedule=schedule,
        makespan=makespan,
        lower_bound=lb,
        num_configs=num_configs,
        runtime_s=runtime_s,
        validated=validated,
        decomposition=decomposition,
        extras=extras or {},
    )
