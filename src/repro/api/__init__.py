"""Unified solver API: one input shape, one output shape, every algorithm.

    from repro.api import Problem, solve, solve_many

    report = solve(Problem(D, s=4, delta=0.01), solver="spectra")
    reports = solve_many(Ds, s=4, delta=0.01, solver="spectra_jax")

See ``registry`` for the built-in solver names, ``pipeline`` for the
declarative stage system, and ``batch`` for batched/multiprocess solving.
"""

from .batch import solve_many
from .pipeline import (
    DECOMPOSERS,
    EQUALIZERS,
    SCHEDULERS,
    Pipeline,
    register_stage,
)
from .problem import Problem, SolveOptions, SolveReport
from .registry import (
    get_solver,
    list_solvers,
    register_solver,
    solve,
    solve_all,
)

__all__ = [
    "DECOMPOSERS", "EQUALIZERS", "SCHEDULERS",
    "Pipeline", "Problem", "SolveOptions", "SolveReport",
    "get_solver", "list_solvers", "register_solver", "register_stage",
    "solve", "solve_all", "solve_many",
]
