"""Batched solving: many demand matrices through one entry point.

``solve_many`` is how a production controller consumes the API: every
controller period it holds one demand matrix per pod/job and wants them all
scheduled at once. On the JAX backend (``solver="spectra_jax"``) instances
are grouped into shape buckets and the whole pipeline — DECOMPOSE,
SCHEDULE, *and* EQUALIZE — runs for each bucket in a single vmapped device
call over the dense schedule IR (ragged-n batching: mixed matrix sizes cost
one dispatch per distinct shape), with per-instance ``ParallelSchedule``
objects materializing lazily on access; on the numpy backends it falls back
to a per-instance loop, optionally fanned out over worker processes.
"""

from __future__ import annotations

import numpy as np

from ..obs.trace import get_tracer
from .problem import Problem, SolveOptions, SolveReport
from .registry import solve


def _as_stack(Ds) -> list[np.ndarray]:
    """Normalize to a list of square matrices."""
    if isinstance(Ds, np.ndarray) and Ds.ndim == 3:
        return [Ds[b] for b in range(Ds.shape[0])]
    return [np.asarray(D) for D in Ds]


def shape_buckets(mats: list[np.ndarray]) -> dict[tuple[int, ...], list[int]]:
    """Group instance indices by matrix shape, preserving submission order."""
    buckets: dict[tuple[int, ...], list[int]] = {}
    for i, D in enumerate(mats):
        buckets.setdefault(D.shape, []).append(i)
    return buckets


def _solve_one(args) -> SolveReport:
    D, s, delta, solver, options = args
    return solve(Problem(D, s, delta), solver=solver, options=options)


def _as_deltas(delta, B: int) -> np.ndarray:
    """Normalize δ (scalar or per-instance sequence) to a (B,) vector."""
    arr = np.asarray(delta, dtype=np.float64)
    if arr.ndim == 0:
        return np.full((B,), float(arr))
    if arr.shape != (B,):
        raise ValueError(
            f"per-instance delta must have length {B}, got shape {arr.shape}"
        )
    return arr


def solve_many(
    Ds,
    s: int,
    delta,
    *,
    solver: str = "spectra",
    options: SolveOptions | None = None,
    processes: int | None = None,
) -> list[SolveReport]:
    """Solve a batch of demand matrices; one SolveReport per instance.

    Ds may be a stacked ``(B, n, n)`` array or a sequence of square
    matrices — the shapes need not match. ``delta`` is one δ for the whole
    batch or a length-B per-instance vector (trace-aware δ sweeps: a trace
    whose reconfiguration delay varies per period still batches into the
    same dispatches). ``solver="spectra_jax"`` groups the instances into
    **shape buckets** (ragged-n batching): each bucket runs the fused
    DECOMPOSE→SCHEDULE→EQUALIZE device call once for all its instances
    (host schedules materialize lazily), and results come back in
    submission order regardless of bucketing — so a mixed n ∈ {32, 64, 100}
    submission costs one device dispatch per distinct shape, not per
    instance. The device matcher is autotuned per bucket
    (``core.jaxopt.matching.default_matcher``) unless
    ``options.extra["matcher"]`` pins one. Every other solver loops, across
    ``processes`` workers when given. Worker processes start via
    forkserver/spawn once jax is loaded, so scripts using ``processes``
    need the standard ``if __name__ == "__main__":`` guard.
    """
    options = options or SolveOptions()
    mats = _as_stack(Ds)
    if not mats:
        return []
    deltas = _as_deltas(delta, len(mats))
    tracer = get_tracer()
    if solver == "spectra_jax":
        try:
            from .jax_backend import solve_many_jax
        except Exception:  # pragma: no cover - jax missing
            pass
        else:
            buckets = shape_buckets(mats)
            with tracer.span(
                "solve_many",
                {"B": len(mats), "solver": solver, "buckets": len(buckets)}
                if tracer.enabled
                else None,
            ):
                out: list[SolveReport | None] = [None] * len(mats)
                for shape, idxs in buckets.items():
                    with tracer.span(
                        "bucket",
                        {"shape": list(shape), "count": len(idxs)}
                        if tracer.enabled
                        else None,
                    ):
                        reports = solve_many_jax(
                            np.stack([mats[i] for i in idxs]),
                            s,
                            deltas[idxs],
                            options,
                        )
                    for i, rep in zip(idxs, reports):
                        out[i] = rep
                return out  # type: ignore[return-value]
    work = [(D, s, float(d), solver, options) for D, d in zip(mats, deltas)]
    loop_span = tracer.span(
        "solve_many",
        {"B": len(work), "solver": solver} if tracer.enabled else None,
    )
    if processes and processes > 1 and len(work) > 1:
        import multiprocessing as mp
        import sys

        # Forking a process with live XLA threads can deadlock (JAX warns on
        # os.fork()), and importing repro.api pulls jax in — so fork only
        # when jax never loaded; otherwise use forkserver (workers fork from
        # a clean server process), falling back to spawn.
        methods = mp.get_all_start_methods()
        if "jax" not in sys.modules and "fork" in methods:
            method = "fork"
        elif "forkserver" in methods:
            method = "forkserver"
        else:
            method = "spawn"
        with loop_span, mp.get_context(method).Pool(
            min(processes, len(work))
        ) as pool:
            return pool.map(_solve_one, work)
    with loop_span:
        return [_solve_one(w) for w in work]
