"""pjit-able train_step / serve_step builders for every (arch × shape) cell."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeCfg
from ..models.lm import LM
from ..models.registry import build_model, cache_specs, input_specs
from ..train.optimizer import AdamW, global_norm, warmup_stable_decay
from .sharding import (
    batch_shardings,
    cache_shardings,
    default_act_pspec,
    param_shardings,
)


def make_model_for_cell(cfg: ModelConfig, mesh: Mesh | None, *,
                        remat: bool = True, sp: bool = True,
                        unroll: bool = False,
                        ssd_impl: str = "chunked") -> LM:
    """Model wired for distributed lowering (chunked impls, remat, SP)."""
    act = default_act_pspec(mesh) if (mesh is not None and sp) else None
    return build_model(
        cfg, attn_impl="chunked", ssd_impl=ssd_impl, remat=remat,
        act_pspec=act, unroll=unroll,
    )


def make_optimizer(total_steps: int = 10_000, peak_lr: float = 3e-4) -> AdamW:
    return AdamW(schedule=warmup_stable_decay(peak_lr, total_steps))


def make_train_step(model: LM, optimizer: AdamW):
    """(params, opt_state, batch) → (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True
        )(params)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        out_metrics = {
            "loss": loss,
            "grad_norm": global_norm(grads),
            "lr": optimizer.schedule(new_opt["step"]),
        }
        if "expert_load" in metrics:
            out_metrics["expert_load"] = metrics["expert_load"]
        return new_params, new_opt, out_metrics

    return train_step


def make_serve_step(model: LM):
    """(params, cache, tokens(B,1)) → (next_tokens, cache)."""

    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return next_tok, cache

    return serve_step


def lower_cell(
    cfg: ModelConfig,
    shape: ShapeCfg,
    mesh: Mesh,
    *,
    remat: bool = True,
    sp: bool = True,
    donate: bool = True,
    unroll: bool = False,
    shard_mode: str = "tp_fsdp",
    ssd_impl: str = "chunked",
):
    """Lower (not compile) the cell's step function on the mesh.

    train/prefill → train_step over abstract params/opt-state/batch;
    decode       → serve_step over abstract params/cache/token.
    Returns (lowered, meta dict).
    """
    model = make_model_for_cell(cfg, mesh, remat=remat, sp=sp, unroll=unroll,
                                ssd_impl=ssd_impl)
    specs_in = input_specs(cfg, shape)

    with mesh:
        params_shape = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0))
        )
        # "zero1": params replicated over data (no per-layer FSDP
        # gathers); optimizer moments still sharded over data (ZeRO-1).
        p_mode = "tp_only" if shard_mode == "zero1" else shard_mode
        o_mode = "tp_fsdp" if shard_mode == "zero1" else shard_mode
        p_shard = param_shardings(params_shape, mesh, mode=p_mode)
        b_shard = batch_shardings(specs_in, mesh)

        import math

        n_params = sum(
            math.prod(a.shape) for a in jax.tree.leaves(params_shape)
        )
        if shape.kind == "train":
            optimizer = make_optimizer()
            opt_shape = jax.eval_shape(lambda: optimizer.init(params_shape))
            o_shard = param_shardings(opt_shape, mesh, mode=o_mode)
            o_shard["step"] = NamedSharding(mesh, P())
            step = make_train_step(model, optimizer)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(params_shape, opt_shape, specs_in)
            return lowered, {"kind": "train", "n_params": n_params}

        if shape.kind == "prefill":
            # Inference-prefill: pure forward, logits sharded over
            # (batch, ·, vocab-TP when divisible); no optimizer/backward.
            import numpy as np

            from .sharding import batch_axes

            baxes = batch_axes(mesh)
            bsz = int(np.prod([mesh.shape[a] for a in baxes]))
            tp = mesh.shape["model"]
            logits_shard = NamedSharding(
                mesh,
                P(
                    baxes if shape.global_batch % bsz == 0 else None,
                    None,
                    "model" if cfg.vocab_size % tp == 0 else None,
                ),
            )

            def prefill_step(params, batch):
                out = model.apply(params, batch)
                return out["logits"]

            jitted = jax.jit(
                prefill_step,
                in_shardings=(p_shard, b_shard),
                out_shardings=logits_shard,
            )
            lowered = jitted.lower(params_shape, specs_in)
            return lowered, {"kind": "prefill", "n_params": n_params}

        # decode
        c_specs = cache_specs(cfg, shape)
        c_shard = cache_shardings(c_specs, mesh)
        step = make_serve_step(model)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, c_shard, b_shard["tokens"]),
            out_shardings=(None, c_shard),
            donate_argnums=(1,) if donate else (),
        )
        lowered = jitted.lower(params_shape, c_specs, specs_in["tokens"])
        return lowered, {"kind": "decode", "n_params": n_params}
