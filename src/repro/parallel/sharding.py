"""Sharding rules: TP + FSDP for params/optimizer states, DP/SP for data.

Strategy (DESIGN.md §8):
  * 2-D weights (d_model, flat_out) → P(fsdp_axis, tp_axis): tensor
    parallelism over the flattened output dim (always mesh-divisible by
    construction), ZeRO/FSDP over the d_model dim.
  * transposed weights (flat_in, d_model) → P(tp_axis, fsdp_axis).
  * expert weights (E, d, f) → P(tp_axis, fsdp_axis, None): expert
    parallelism over the model axis.
  * embed (V, D) → P(tp_axis, fsdp_axis) (vocab-parallel).
  * 1-D params → replicated.
  * every rule is divisibility-checked against the mesh; non-divisible dims
    fall back to replication (never a compile error).

Optimizer states share their param's spec ("ZeRO-3-alike": params, grads
and Adam moments all sharded the same way). Batch dims shard over
("pod", "data"); decode caches shard heads over model when divisible, else
sequence over model (flash-decode-style).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Param-name → (spec template, trailing ndim) — leading stack dims get None.
_TP, _FSDP = "model", "data"
_RULES: dict[str, tuple] = {
    "embed": (_TP, _FSDP),
    "lm_head": (_FSDP, _TP),
    "wq": (_FSDP, _TP),
    "wk": (_FSDP, _TP),
    "wv": (_FSDP, _TP),
    "wo": (_TP, _FSDP),
    "wi_gate": (_FSDP, _TP),
    "wi_up": (_FSDP, _TP),
    "wdown": (_TP, _FSDP),
    "router": (_FSDP, None),
    "we_gate": (_TP, _FSDP, None),
    "we_up": (_TP, _FSDP, None),
    "we_down": (_TP, None, _FSDP),
    "ws_gate": (_FSDP, _TP),
    "ws_up": (_FSDP, _TP),
    "ws_down": (_TP, _FSDP),
    "w_xz": (_FSDP, _TP),
    "w_bc": (_FSDP, _TP),
    "w_dt": (_FSDP, _TP),
    "conv_w": (None, _TP),
    "w_out": (_TP, _FSDP),
}


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fit(spec: tuple, shape: tuple, mesh: Mesh) -> P:
    """Divisibility-checked spec: non-divisible dims are replicated."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is not None and ax in mesh.shape and dim % _axis_size(mesh, ax) == 0:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


def param_pspec(path: tuple, leaf, mesh: Mesh, mode: str = "tp_fsdp") -> P:
    name = None
    for entry in reversed(path):
        key = getattr(entry, "key", getattr(entry, "idx", None))
        if isinstance(key, str):
            name = key
            break
    nd = leaf.ndim
    rule = _RULES.get(name)
    if rule is None or nd < len(rule):
        return P()  # replicate (norms, biases, scalars)
    if mode == "tp_only":  # replicate along data (no FSDP) — perf knob
        rule = tuple(None if ax == _FSDP else ax for ax in rule)
    elif mode != "tp_fsdp":
        raise ValueError(f"unknown sharding mode {mode!r}")
    lead = nd - len(rule)
    return _fit((None,) * lead + tuple(rule), leaf.shape, mesh)


def param_shardings(params: Any, mesh: Mesh, mode: str = "tp_fsdp") -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(path, leaf, mesh, mode)),
        params,
    )


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_shardings(batch: Any, mesh: Mesh) -> Any:
    axes = batch_axes(mesh)
    bsz = int(np.prod([mesh.shape[a] for a in axes]))

    def spec(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        lead = axes if leaf.shape[0] % bsz == 0 else None
        return NamedSharding(mesh, P(lead, *([None] * (leaf.ndim - 1))))

    return jax.tree.map(spec, batch)


def cache_shardings(cache: Any, mesh: Mesh) -> Any:
    """Decode caches: batch→(pod,data); heads→model if divisible, else
    sequence→model (distributed flash-decode); SSD state dims likewise."""
    axes = batch_axes(mesh)
    bsz = int(np.prod([mesh.shape[a] for a in axes]))
    tp = _axis_size(mesh, _TP)

    def spec(path, leaf):
        name = None
        for entry in reversed(path):
            key = getattr(entry, "key", None)
            if isinstance(key, str):
                name = key
                break
        shape = leaf.shape
        nd = leaf.ndim
        if name in ("k", "v") and nd >= 4:
            # (..., B, Hkv, S, dh) possibly with leading stack dims
            lead = [None] * (nd - 4)
            B, H, S, dh = shape[-4:]
            bax = axes if B % bsz == 0 and bsz > 1 else None
            if H % tp == 0:
                return P(*lead, bax, _TP, None, None)
            if S % tp == 0:
                return P(*lead, bax, None, _TP, None)
            return P(*lead, bax, None, None, None)
        if name == "ssm" and nd >= 3:
            lead = [None] * (nd - 3)
            BH, N, Pp = shape[-3:]
            first = _TP if BH % tp == 0 else None
            return P(*lead, first, None, None)
        if name == "conv" and nd >= 3:
            lead = [None] * (nd - 3)
            B, K, C = shape[-3:]
            bax = axes if B % bsz == 0 and bsz > 1 else None
            cax = _TP if C % tp == 0 else None
            return P(*lead, bax, None, cax)
        if name == "enc_out" and nd == 3:
            B, S, D = shape
            bax = axes if B % bsz == 0 and bsz > 1 else None
            return P(bax, None, None)
        return P()

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec(path, leaf)), cache
    )


def default_act_pspec(mesh: Mesh) -> tuple:
    """Activation constraint between blocks: batch over (pod, data),
    sequence over model (Megatron-style sequence parallelism)."""
    return (batch_axes(mesh), _TP, None)
