"""Serving launcher: batched greedy decode on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --batch 4 --prompt-len 16 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..configs.registry import get_arch
    from ..models.registry import build_model
    from ..serve.engine import DecodeEngine

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    eng = DecodeEngine(
        model, params, max_len=args.prompt_len + args.new_tokens + 8
    )
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)
    ).astype(np.int32)
    t0 = time.perf_counter()
    res = eng.generate(prompts, args.new_tokens, temperature=args.temperature)
    dt = time.perf_counter() - t0
    toks = args.batch * args.new_tokens
    print(f"arch={cfg.name} batch={args.batch} generated={toks} tokens "
          f"in {dt:.2f}s → {toks / dt:.1f} tok/s (CPU, reduced config)")
    print("first row:", res.tokens[0].tolist())


if __name__ == "__main__":
    main()
