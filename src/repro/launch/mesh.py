"""Production mesh factory (required by the multi-pod dry-run spec).

A function — never a module-level constant — so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(devices: int | None = None, model: int = 2):
    """Small mesh for CPU multi-device tests (data × model)."""
    n = devices or len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
