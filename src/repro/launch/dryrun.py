import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the very first lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each live cell (40 minus the noted long_500k skips — see DESIGN.md §6):
  * build the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  * jit the train_step (train/prefill) or serve_step (decode) with full
    param/optimizer/cache shardings,
  * ``.lower().compile()`` — any sharding mismatch, compile-OOM or
    unsupported collective fails the cell,
  * record memory_analysis / cost_analysis / collective schedule / roofline
    terms to benchmarks/out/dryrun/<cell>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  ... dryrun --arch qwen3-moe-30b-a3b --shape train_4k         # one cell
  ... dryrun --multi-pod / --single-pod                        # mesh select
  ... dryrun --force                                           # recompute
"""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "out" / "dryrun"


def with_depth(cfg, n_units: int):
    """Same-structure config with n_units scan repeats (remainders kept)."""
    import dataclasses

    if cfg.pattern_local:
        period = cfg.pattern_local + cfg.pattern_global
        rem = cfg.num_layers % period
        return dataclasses.replace(cfg, num_layers=n_units * period + rem)
    if cfg.attn_every:
        rem = cfg.num_layers % cfg.attn_every
        return dataclasses.replace(cfg, num_layers=n_units * cfg.attn_every + rem)
    if cfg.family == "audio":
        return dataclasses.replace(
            cfg, num_layers=n_units, encoder_layers=n_units
        )
    return dataclasses.replace(cfg, num_layers=n_units)


def scan_units(cfg) -> int:
    """Trip count of the layer scan(s) in the full config."""
    if cfg.pattern_local:
        return cfg.num_layers // (cfg.pattern_local + cfg.pattern_global)
    if cfg.attn_every:
        return cfg.num_layers // cfg.attn_every
    return cfg.num_layers


def _cell_metrics(compiled) -> dict:
    """Per-chip flops / bytes / collective wire bytes of one executable."""
    from ..analysis.hlo import parse_collectives

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byte_keys = [k for k in cost if k.startswith("bytes accessed")]
    hlo_bytes = max(float(cost[k]) for k in byte_keys) if byte_keys else 0.0
    stats = parse_collectives(compiled.as_text())
    return {
        "flops": flops,
        "bytes": hlo_bytes,
        "wire": stats.total_wire_bytes,
        "collectives": stats.as_dict(),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             remat: bool = True, sp: bool = True, donate: bool = True,
             calibrate: bool = True, shard_mode: str = "tp_fsdp",
             ssd_impl: str = "chunked", cfg_patch: dict | None = None) -> dict:
    import dataclasses

    import jax

    from ..analysis.roofline import TPU_V5E, Roofline, model_flops
    from ..configs.registry import get_arch, get_shape
    from ..models.registry import build_model
    from ..parallel.steps import lower_cell
    from .mesh import make_production_mesh

    cfg = get_arch(arch)
    if cfg_patch:
        if "moe" in cfg_patch and cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, **cfg_patch.pop("moe"))
            )
        if cfg_patch:
            cfg = dataclasses.replace(cfg, **cfg_patch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    lowered, meta = lower_cell(cfg, shape, mesh, remat=remat, sp=sp,
                               donate=donate, shard_mode=shard_mode,
                               ssd_impl=ssd_impl)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # pragma: no cover - backend-dependent
        mem["error"] = str(e)

    metrics = _cell_metrics(compiled)
    calib = {"applied": False}
    if calibrate:
        # XLA's HloCostAnalysis counts while(scan) bodies ONCE — calibrate
        # per-layer costs from unrolled depth-1/-2 variants, extrapolate.
        units = scan_units(cfg)
        m = {}
        for n_units in (1, 2):
            c_small = with_depth(cfg, n_units)
            low_s, _ = lower_cell(c_small, shape, mesh, remat=remat, sp=sp,
                                  donate=donate, unroll=True,
                                  shard_mode=shard_mode, ssd_impl=ssd_impl)
            m[n_units] = _cell_metrics(low_s.compile())
        per_unit = {k: m[2][k] - m[1][k] for k in ("flops", "bytes", "wire")}
        metrics = {
            k: m[1][k] + max(per_unit[k], 0.0) * (units - 1)
            for k in ("flops", "bytes", "wire")
        }
        metrics["collectives"] = m[2]["collectives"]
        calib = {
            "applied": True,
            "units": units,
            "per_unit": per_unit,
            "base": {k: m[1][k] for k in ("flops", "bytes", "wire")},
        }

    params_shape = jax.eval_shape(lambda: build_model(cfg).init(jax.random.PRNGKey(0)))
    hw = TPU_V5E
    compute_s = metrics["flops"] / hw["peak_flops_bf16"]
    memory_s = metrics["bytes"] / hw["hbm_bw"]
    collective_s = metrics["wire"] / hw["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    mf = model_flops(cfg, shape, params_shape)
    ideal_s = (mf / n_chips) / hw["peak_flops_bf16"]
    bound = max(terms.values())
    roof = Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=max(terms, key=terms.get),
        model_flops=mf,
        hlo_flops_per_chip=metrics["flops"],
        hlo_bytes_per_chip=metrics["bytes"],
        wire_bytes_per_chip=metrics["wire"],
        useful_ratio=(mf / n_chips / metrics["flops"]) if metrics["flops"] else 0.0,
        roofline_fraction=(ideal_s / bound) if bound > 0 else 0.0,
        collectives=metrics["collectives"],
    )

    # Per-device residency: params+opt live in donated arguments.
    bytes_per_device = (
        mem.get("argument_size_in_bytes", 0) / n_chips
        + mem.get("temp_size_in_bytes", 0) / n_chips
    )
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": meta["kind"],
        "n_params": meta["n_params"],
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory_analysis": mem,
        "bytes_per_device_est": bytes_per_device,
        "roofline": roof.as_dict(),
        "calibration": calib,
        "options": {"remat": remat, "sp": sp, "donate": donate,
                    "shard_mode": shard_mode},
    }


def cell_path(arch: str, shape: str, multi_pod: bool) -> Path:
    mesh = "pod2" if multi_pod else "pod1"
    return OUT_DIR / f"{arch}__{shape}__{mesh}.json"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-sp", action="store_true")
    args = ap.parse_args()

    from ..configs.registry import all_cells

    meshes = []
    if args.multi_pod or not args.single_pod:
        meshes.append(True)
    if args.single_pod or not args.multi_pod:
        meshes.insert(0, False)

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    failures = []
    # Cheapest-first ordering: maximizes coverage per wall-clock on 1 core.
    arch_order = [
        "whisper-tiny", "qwen2-vl-2b", "minicpm-2b", "zamba2-1.2b",
        "mamba2-2.7b", "granite-3-8b", "deepseek-moe-16b",
        "qwen3-moe-30b-a3b", "gemma3-27b", "command-r-35b",
    ]
    shape_order = ["decode_32k", "train_4k", "long_500k", "prefill_32k"]
    cells = sorted(
        all_cells(),
        key=lambda c: (shape_order.index(c[1]), arch_order.index(c[0])),
    )
    for arch, shape, skipped in cells:
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape != args.shape:
            continue
        if skipped:
            print(f"SKIP {arch} × {shape} (full-attention arch at 500k — "
                  f"DESIGN.md §6)")
            continue
        for mp in meshes:
            path = cell_path(arch, shape, mp)
            if path.exists() and not args.force:
                print(f"CACHED {path.name}")
                continue
            label = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
            print(f"RUN {label} ...", flush=True)
            try:
                # Roofline calibration (extra depth-1/-2 compiles) only for
                # the single-pod mesh — the §Roofline table is single-pod;
                # the multi-pod pass proves the "pod" axis shards.
                art = run_cell(arch, shape, mp, remat=not args.no_remat,
                               sp=not args.no_sp, calibrate=not mp)
                path.write_text(json.dumps(art, indent=1))
                r = art["roofline"]
                print(
                    f"  OK lower={art['lower_s']}s compile={art['compile_s']}s "
                    f"dominant={r['dominant']} "
                    f"terms=({r['compute_s']:.3e},{r['memory_s']:.3e},"
                    f"{r['collective_s']:.3e})s frac={r['roofline_fraction']:.2f}",
                    flush=True,
                )
            except Exception as e:
                failures.append((label, repr(e)))
                print(f"  FAIL {label}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for label, err in failures:
            print(f"  {label}: {err[:200]}")
        return 1
    print("\nAll requested dry-run cells passed.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
