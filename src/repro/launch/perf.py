import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ first lines, same contract as dryrun.py.
"""Perf hillclimbing driver (§Perf): run named lowering variants of a cell,
record the three roofline terms per variant, append to
benchmarks/out/perf_log.json.

    PYTHONPATH=src python -m repro.launch.perf --arch gemma3-27b \
        --shape train_4k --variant no_sp --variant tp_only ...

Variants are combinations of the framework's optimization knobs:
    base        remat + SP activation constraint + TP/FSDP sharding
    no_sp       drop the sequence-parallel activation constraint
    no_remat    store activations instead of recomputing in backward
    tp_only     replicate params over data (no FSDP gathers)
    no_sp_tp_only, no_remat_no_sp, ...   combinations
"""

import argparse
import json
import time
from pathlib import Path

OUT = Path(__file__).resolve().parents[3] / "benchmarks" / "out"

VARIANTS = {
    "base": {},
    "no_sp": {"sp": False},
    "no_remat": {"remat": False},
    "tp_only": {"shard_mode": "tp_only"},
    "no_sp_tp_only": {"sp": False, "shard_mode": "tp_only"},
    "no_remat_no_sp": {"remat": False, "sp": False},
    "no_remat_tp_only": {"remat": False, "shard_mode": "tp_only"},
    # SSD: scan over chunks instead of materializing all (L×L) tiles.
    "ssd_scanned": {"ssd_impl": "chunked_scan"},
    "ssd_scanned_no_sp": {"ssd_impl": "chunked_scan", "sp": False},
    "ssd_scanned_no_remat": {"ssd_impl": "chunked_scan", "remat": False},
    # MoE: capacity factor 1.0 (20% less expert compute, more drops).
    "cf1": {"cfg_patch": {"moe": {"capacity_factor": 1.0}}},
    "cf1_no_sp": {"cfg_patch": {"moe": {"capacity_factor": 1.0}},
                  "sp": False},
    # ZeRO-1: params replicated over data, optimizer moments sharded.
    "zero1": {"shard_mode": "zero1"},
    "zero1_cf1": {"shard_mode": "zero1",
                  "cfg_patch": {"moe": {"capacity_factor": 1.0}}},
    "zero1_no_remat": {"shard_mode": "zero1", "remat": False},
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", action="append", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from .dryrun import run_cell

    names = args.variant or list(VARIANTS)
    log_path = OUT / "perf_log.json"
    log = json.loads(log_path.read_text()) if log_path.exists() else []
    for name in names:
        kw = VARIANTS[name]
        label = f"{args.arch}×{args.shape}×{name}"
        print(f"VARIANT {label} ...", flush=True)
        t0 = time.time()
        try:
            art = run_cell(args.arch, args.shape, args.multi_pod, **kw)
        except Exception as e:
            print(f"  FAIL {e}")
            log.append({"cell": f"{args.arch}×{args.shape}", "variant": name,
                        "error": repr(e)[:300]})
            continue
        r = art["roofline"]
        rec = {
            "cell": f"{args.arch}×{args.shape}",
            "variant": name,
            "options": art["options"],
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "dominant": r["dominant"],
            "fraction": r["roofline_fraction"],
            "useful": r["useful_ratio"],
            "wall_s": round(time.time() - t0, 1),
        }
        log.append(rec)
        print(f"  terms=({r['compute_s']:.3e},{r['memory_s']:.3e},"
              f"{r['collective_s']:.3e}) dom={r['dominant']} "
              f"frac={r['roofline_fraction']:.3f}", flush=True)
        log_path.write_text(json.dumps(log, indent=1))


if __name__ == "__main__":
    main()
