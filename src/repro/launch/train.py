"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
        --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt \
        --ocs-switches 4 --ocs-delta-us 20

``--reduced`` (default) trains the smoke-scale config on local devices;
the full configs are exercised via the dry-run (this container is CPU-only).
With ``--ocs-switches`` the loop runs the SPECTRA fabric controller every
``--ocs-every`` steps and logs the optical CCT.
"""

from __future__ import annotations

import argparse
import json

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ocs-switches", type=int, default=0)
    ap.add_argument("--ocs-delta-us", type=float, default=20.0)
    ap.add_argument("--ocs-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..configs.registry import get_arch
    from ..data.pipeline import make_stream
    from ..fabric.ocs import OCSFabric
    from ..models.registry import build_model
    from ..parallel.steps import make_train_step
    from ..train.loop import LoopConfig, Trainer
    from ..train.optimizer import AdamW, warmup_stable_decay

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, attn_impl="chunked", ssd_impl="chunked")
    opt = AdamW(schedule=warmup_stable_decay(args.lr, args.steps))
    stream = make_stream(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    step = jax.jit(make_train_step(model, opt))
    fabric = None
    if args.ocs_switches:
        fabric = OCSFabric(
            num_switches=args.ocs_switches,
            reconfig_delay_s=args.ocs_delta_us * 1e-6,
        )
    loop_cfg = LoopConfig(
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        ocs_every=args.ocs_every if fabric else 0,
    )
    tr = Trainer(model, opt, stream, step, loop_cfg, fabric=fabric)
    state = tr.run(jax.random.PRNGKey(args.seed))
    print(json.dumps({
        "arch": args.arch,
        "steps": state.step,
        "restarts": state.restarts,
        "stragglers": state.stragglers,
        "history": state.history[-5:],
        "cct": state.cct_log[-3:],
    }, indent=1))


if __name__ == "__main__":
    main()
