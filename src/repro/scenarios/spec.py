"""TrafficSpec / DemandTrace — declarative scenario inputs, materialized traces.

A ``TrafficSpec`` describes *time-varying* traffic the way the paper's
controller sees it: a workload family re-sampled every controller period,
``T`` periods long, over ``n`` ports feeding ``s`` parallel switches with
reconfiguration delay δ. A ``DemandTrace`` is the materialized result — a
dense ``(T, n, n)`` stack plus per-period metadata — which is exactly the
shape ``repro.api.solve_many`` consumes in one batched call.

Units policy (``TrafficSpec.units``):

* ``"demand"`` — matrices are already in normalized demand-time units
  (one unit of demand takes one unit of time on one switch link) and
  ``delta`` is in those units. This is the paper's evaluation setting.
* ``"bytes"`` — matrices are raw byte counts (e.g. collective traffic) and
  ``delta`` is the physical reconfiguration delay in *seconds*.
  ``DemandTrace.normalized`` converts the whole trace with one global
  scale (peak entry across all periods), so δ-in-units is constant over
  the trace and the batched solver sees one uniform problem family —
  per-period CCT seconds are then ``makespan · unit_s``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Iterator, Mapping

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from ..fabric.ocs import OCSFabric

_UNITS = ("demand", "bytes")


@dataclass(frozen=True)
class TrafficSpec:
    """Declarative spec of one scenario: family, sizes, δ, units, T, seed.

    ``params["delta_schedule"]`` makes δ itself time-varying: the sequence
    is cycled per period by ``Scenario.trace`` (resolved values land in
    ``period_meta[t]["delta"]`` / ``DemandTrace.deltas``), overriding the
    scalar ``delta`` field; pass ``delta_schedule=None`` to pin the scalar
    back. Byte-denominated traces reject a varying δ (the fabric's physical
    reconfiguration delay is one number).
    """

    family: str                 # generator family in scenarios.registry
    n: int                      # ports (racks)
    s: int                      # parallel switches
    delta: float                # reconfig delay: demand units, or seconds for units="bytes"
    periods: int = 1            # T controller periods
    seed: int = 0               # base seed; period t draws from seed + t
    units: str = "demand"       # "demand" | "bytes"
    link_bandwidth_Bps: float | None = None  # bytes traces; None → OCSFabric default
    params: Mapping[str, Any] = field(default_factory=dict)  # family kwargs
    # Flow-level replay knobs (repro.flowsim.FlowSimOptions kwargs:
    # buffer_limit, indirection, line_rate, tol) — the defaults
    # run_scenario(..., flowsim=True) builds its FlowSimOptions from
    # unless an explicit flowsim_options argument overrides them.
    flowsim_params: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.n < 2:
            raise ValueError(f"need at least two ports, got n={self.n}")
        if self.s < 1:
            raise ValueError(f"need at least one switch, got s={self.s}")
        if self.delta < 0:
            raise ValueError(f"delta must be nonnegative, got {self.delta}")
        if self.periods < 1:
            raise ValueError(f"need at least one period, got T={self.periods}")
        if self.units not in _UNITS:
            raise ValueError(f"units must be one of {_UNITS}, got {self.units!r}")
        object.__setattr__(self, "params", dict(self.params))
        object.__setattr__(self, "flowsim_params", dict(self.flowsim_params))

    def replace(self, **overrides: Any) -> "TrafficSpec":
        """New spec with overrides; unknown keys merge into ``params``.

        Top-level field names (``n``, ``periods``, ``seed``, …) replace the
        field; anything else is a family knob and merges into the existing
        ``params`` (``params=`` itself also *merges*, it does not wipe the
        dict — explicit scalar knobs take precedence over a registered
        ``<knob>_schedule``, see ``library._knob``). So
        ``spec.replace(n=8, periods=3, noise=0.01)`` is the tiny variant
        idiom used by the smoke tests.
        """
        names = {f.name for f in dataclasses.fields(self)}
        top = {k: v for k, v in overrides.items() if k in names and k != "params"}
        extra = {k: v for k, v in overrides.items() if k not in names}
        params = {**self.params, **extra, **dict(overrides.get("params", {}))}
        return dataclasses.replace(self, params=params, **top)


@dataclass
class DemandTrace:
    """A materialized scenario: (T, n, n) demand stack + per-period metadata."""

    spec: TrafficSpec
    demands: np.ndarray           # (T, n, n) float64, nonnegative
    period_meta: list[dict]       # one dict per period (knob values, seeds)

    def __post_init__(self) -> None:
        self.demands = np.asarray(self.demands, dtype=np.float64)
        if self.demands.ndim != 3 or self.demands.shape[1] != self.demands.shape[2]:
            raise ValueError(
                f"demands must be (T, n, n), got shape {self.demands.shape}"
            )
        if len(self.period_meta) != self.demands.shape[0]:
            raise ValueError("need exactly one metadata dict per period")

    @property
    def T(self) -> int:
        return int(self.demands.shape[0])

    @property
    def n(self) -> int:
        return int(self.demands.shape[1])

    @property
    def deltas(self) -> np.ndarray:
        """Per-period reconfiguration delay, shape (T,).

        Constant ``spec.delta`` unless the scenario registered a
        ``delta_schedule`` (cycled per period by ``Scenario.trace``, which
        records the resolved value in ``period_meta[t]["delta"]``).
        """
        return np.array(
            [m.get("delta", self.spec.delta) for m in self.period_meta],
            dtype=np.float64,
        )

    @property
    def varying_delta(self) -> bool:
        """True when a ``delta_schedule`` makes δ differ across periods."""
        d = self.deltas
        return bool(len(d)) and bool((d != d[0]).any())

    def __len__(self) -> int:
        return self.T

    def __iter__(self) -> Iterator[np.ndarray]:
        return iter(self.demands)

    def fabric(self) -> "OCSFabric":
        """The OCSFabric this byte trace is denominated against."""
        from ..fabric.ocs import OCSFabric

        kw = {}
        if self.spec.link_bandwidth_Bps is not None:
            kw["link_bandwidth_Bps"] = self.spec.link_bandwidth_Bps
        return OCSFabric(
            num_switches=self.spec.s, reconfig_delay_s=self.spec.delta, **kw
        )

    def normalized(self) -> tuple[np.ndarray, float, float | np.ndarray]:
        """Whole-trace bytes→units conversion: (units stack, unit_s, δ_units).

        Delegates the scale math to ``OCSFabric.normalize`` over the entire
        ``(T, n, n)`` stack — one global scale (the peak entry across *all*
        periods) so a single δ-in-units holds for the whole trace and
        ``solve_many`` can treat it as one uniform batch. All-zero traces
        inherit the fabric's contract: ``unit_s = 0.0``, ``δ_units = 0.0``
        (nothing to serve, no reconfigurations needed).

        A ``delta_schedule`` (trace-aware δ sweep) returns δ_units as the
        per-period (T,) vector instead of a scalar — nothing downstream may
        silently collapse it. Byte traces reject per-period δ with a clear
        error: the fabric's physical reconfiguration delay is one number,
        and pretending otherwise would silently mis-price every period.
        """
        if self.spec.units != "bytes":
            if self.varying_delta:
                return self.demands, float("nan"), self.deltas
            return self.demands, float("nan"), self.spec.delta
        if self.varying_delta:
            raise ValueError(
                "per-period delta_schedule is not supported for "
                "byte-denominated traces: δ is the fabric's physical "
                "reconfiguration delay (one value); drop the schedule or "
                "use units='demand'"
            )
        fabric = self.fabric()
        units, unit_s = fabric.normalize(self.demands)
        return units, unit_s, fabric.delta_units(unit_s)
