"""String-addressable scenario registry (mirrors ``repro.api.registry``).

Two levels:

* a **family** is a generator ``(spec, t, rng) -> (n, n) demand [, meta]``
  producing period ``t`` of a trace — registered with ``register_family``;
* a **scenario** is a named ``TrafficSpec`` binding a family to concrete
  sizes/knobs — registered with ``register_scenario`` and materialized with
  ``make_trace(name, **overrides)``.

Period ``t`` always draws from ``np.random.default_rng(spec.seed + t)``, so
a trace is deterministic under a fixed seed, periods are independent of
generation order, and — with ``seed=0`` — period ``t`` reproduces exactly
the matrix the figure benchmarks historically drew for ``seed=t``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from .spec import DemandTrace, TrafficSpec

# (spec, period, rng) -> (n, n) ndarray, or (ndarray, per-period-meta dict)
FamilyFn = Callable[..., Any]

_FAMILIES: dict[str, FamilyFn] = {}
_SCENARIOS: dict[str, "Scenario"] = {}


def register_family(name: str, fn: FamilyFn | None = None, *, overwrite: bool = False):
    """Register a traffic family generator under ``name``; usable as a decorator."""

    def _register(f: FamilyFn) -> FamilyFn:
        if name in _FAMILIES and not overwrite:
            raise ValueError(f"traffic family {name!r} already registered")
        _FAMILIES[name] = f
        return f

    return _register if fn is None else _register(fn)


def get_family(name: str) -> FamilyFn:
    if name not in _FAMILIES:
        raise KeyError(f"unknown traffic family {name!r}; available: {list_families()}")
    return _FAMILIES[name]


def list_families() -> list[str]:
    return sorted(_FAMILIES)


@dataclass(frozen=True)
class Scenario:
    """A named, declarative scenario: spec + description; materializes traces."""

    name: str
    spec: TrafficSpec
    description: str = ""

    def trace(self, **overrides: Any) -> DemandTrace:
        """Materialize the (T, n, n) demand trace, deterministically.

        Overrides go through ``TrafficSpec.replace`` — spec fields replace,
        anything else merges into the family params — so tiny variants are
        ``scenario.trace(n=8, periods=3)``.
        """
        spec = self.spec.replace(**overrides) if overrides else self.spec
        fn = get_family(spec.family)
        # δ is a spec field, not a family knob, so a delta_schedule would be
        # silently ignored by the generators — resolve it here instead
        # (cycled per period, recorded in period_meta, pinnable by passing
        # delta_schedule=None).
        delta_schedule = spec.params.get("delta_schedule")
        if delta_schedule is not None:
            if not len(delta_schedule):
                raise ValueError("delta_schedule must not be empty")
            if any(d < 0 for d in delta_schedule):
                raise ValueError(
                    f"delta_schedule entries must be nonnegative, got "
                    f"{tuple(delta_schedule)}"
                )
        demands = np.zeros((spec.periods, spec.n, spec.n), dtype=np.float64)
        metas: list[dict] = []
        for t in range(spec.periods):
            rng = np.random.default_rng(spec.seed + t)
            out = fn(spec, t, rng)
            D, meta = out if isinstance(out, tuple) else (out, {})
            D = np.asarray(D, dtype=np.float64)
            if D.shape != (spec.n, spec.n):
                raise ValueError(
                    f"family {spec.family!r} produced shape {D.shape} for period "
                    f"{t}, expected {(spec.n, spec.n)}"
                )
            demands[t] = D
            metas.append({"period": t, "seed": spec.seed + t, **meta})
            if delta_schedule is not None:
                metas[-1]["delta"] = float(
                    delta_schedule[t % len(delta_schedule)]
                )
        return DemandTrace(spec=spec, demands=demands, period_meta=metas)


def register_scenario(
    name: str,
    spec: TrafficSpec,
    *,
    description: str = "",
    overwrite: bool = False,
) -> Scenario:
    """Register ``spec`` as the named scenario and return it."""
    if name in _SCENARIOS and not overwrite:
        raise ValueError(f"scenario {name!r} already registered")
    sc = Scenario(name=name, spec=spec, description=description)
    _SCENARIOS[name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    if name not in _SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; available: {list_scenarios()}")
    return _SCENARIOS[name]


def list_scenarios() -> list[str]:
    return sorted(_SCENARIOS)


def make_trace(scenario: str | Scenario, **overrides: Any) -> DemandTrace:
    """Materialize a registered scenario (or Scenario object) into a trace."""
    sc = get_scenario(scenario) if isinstance(scenario, str) else scenario
    return sc.trace(**overrides)
