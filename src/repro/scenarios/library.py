"""Built-in traffic families and named scenarios.

Families wrap the generators in ``repro.traffic`` into the per-period
``(spec, t, rng) -> D`` shape of the scenario registry; the registered
scenarios cover the paper's three evaluation workloads (§V-A), their noise
variants (Fig. 8), the synthetic sparsity/degree sweeps that Figs. 10/11
previously hand-rolled, and collective/HLO-derived byte traffic.

Any scalar family knob can also be supplied as ``<knob>_schedule`` — a
sequence cycled over periods — which is how time-varying sweeps (e.g. the
sparsity scenario's per-period ``m``) are expressed declaratively.
"""

from __future__ import annotations

from typing import Any, Mapping

import numpy as np

from ..traffic.hlo_traffic import demand_from_collectives
from ..traffic.workloads import benchmark_workload, gpt3b_workload, moe_workload
from .registry import register_family, register_scenario
from .spec import TrafficSpec


def _knob(params: Mapping[str, Any], key: str, t: int, default):
    """Resolve a family knob for period ``t``.

    An explicit scalar (``key`` present in params) wins — so overriding a
    sweep scenario with e.g. ``make_trace("sparsity_sweep", m=4)`` pins the
    knob even though the registered spec carries ``m_schedule``. Otherwise
    ``<key>_schedule`` cycles over periods, then the family default applies.
    """
    if key in params:
        return params[key]
    schedule = params.get(f"{key}_schedule")
    if schedule is not None:
        return schedule[t % len(schedule)]
    return default


def _gpt_dims(n: int) -> tuple[int, int, int]:
    """Factor n GPUs into (tp, pp, dp) with tp·pp·dp = n, tp/pp ≤ 4 preferred.

    n=32 recovers the workload's DeepSpeed default (4, 4, 2); n=8 gives
    (4, 2, 1) for the tiny smoke variants.
    """

    def largest_divisor_leq(x: int, cap: int) -> int:
        for d in range(min(cap, x), 0, -1):
            if x % d == 0:
                return d
        return 1

    tp = largest_divisor_leq(n, 4)
    pp = largest_divisor_leq(n // tp, 4)
    dp = n // (tp * pp)
    return tp, pp, dp


@register_family("gpt")
def _gpt_family(spec: TrafficSpec, t: int, rng: np.random.Generator):
    """GPT-3B 3D-parallel training traffic, re-sampled per controller period."""
    p = spec.params
    tp, pp, dp = p.get("dims") or _gpt_dims(spec.n)
    if tp * pp * dp != spec.n:
        raise ValueError(f"dims {tp}x{pp}x{dp} != n={spec.n}")
    noise = _knob(p, "noise", t, 0.003)
    kw = {k: p[k] for k in (
        "tp_bytes", "pp_bytes", "dp_bytes", "emb_bytes", "bg_flows", "bg_bytes"
    ) if k in p}
    D = gpt3b_workload(noise=noise, rng=rng, tp=tp, pp=pp, dp=dp, **kw)
    return D, {"noise": noise, "dims": (tp, pp, dp)}


@register_family("moe")
def _moe_family(spec: TrafficSpec, t: int, rng: np.random.Generator):
    """Qwen-MoE expert routing, re-sampled per period (router drift)."""
    p = spec.params
    top_k = int(_knob(p, "top_k", t, 6))
    skew = float(_knob(p, "skew", t, 0.25))
    noise = float(_knob(p, "noise", t, 0.0))
    tokens = int(p.get("tokens_per_gpu", 8192))
    D = moe_workload(
        n=spec.n, top_k=top_k, tokens_per_gpu=tokens, skew=skew,
        noise=noise, rng=rng,
    )
    return D, {"top_k": top_k, "skew": skew, "noise": noise}


@register_family("benchmark")
def _benchmark_family(spec: TrafficSpec, t: int, rng: np.random.Generator):
    """Standard m-permutation benchmark; ``num_big`` tracks m/4 by default."""
    p = spec.params
    m = int(_knob(p, "m", t, 16))
    num_big = int(_knob(p, "num_big", t, max(1, m // 4)))
    big_frac = float(p.get("big_frac", 0.7))
    noise = float(_knob(p, "noise", t, 0.003))
    D = benchmark_workload(
        n=spec.n, m=m, num_big=num_big, big_frac=big_frac, noise=noise, rng=rng
    )
    return D, {"m": m, "num_big": num_big, "noise": noise}


@register_family("permutations")
def _permutations_family(spec: TrafficSpec, t: int, rng: np.random.Generator):
    """Sum of k random permutations with weights in [floor, 1+floor) (Fig. 11)."""
    p = spec.params
    k = int(_knob(p, "k", t, 16))
    floor = float(p.get("weight_floor", 0.05))
    n = spec.n
    D = np.zeros((n, n), dtype=np.float64)
    for _ in range(k):
        D[np.arange(n), rng.permutation(n)] += rng.random() + floor
    return D, {"k": k}


@register_family("moe_phases")
def _moe_phases_family(spec: TrafficSpec, t: int, rng: np.random.Generator):
    """Phase-cycling MoE expert routing: the support-cache workload.

    A router alternates between ``phases`` fixed expert-assignment
    patterns; period ``t`` replays pattern ``t % phases`` with small
    multiplicative weight noise (support preserved exactly). Each phase is
    a sum of ``fanout`` *disjoint* expert-shift permutations (rotations of
    one random permutation), so consecutive periods share no support — the
    adjacency warm start always misses — while every recurrence of a phase
    is an exact support match for the support-pattern cache, host and
    device alike.
    """
    p = spec.params
    phases = int(p.get("phases", 2))
    fanout = int(_knob(p, "fanout", t, 4))
    noise = float(_knob(p, "noise", t, 0.01))
    phase = t % phases
    n = spec.n
    prng = np.random.default_rng(1000 * spec.seed + int(p.get("phase_seed", 0)) + phase)
    sigma = prng.permutation(n)
    rows = np.arange(n)
    D = np.zeros((n, n), dtype=np.float64)
    for j in prng.choice(n, size=min(fanout, n), replace=False):
        D[rows, np.roll(sigma, int(j))] += prng.random() + 0.2
    if noise > 0:
        D *= 1.0 + noise * rng.standard_normal((n, n))
        np.maximum(D, 0.0, out=D)
    return D, {"phase": phase, "phases": phases, "fanout": fanout}


@register_family("mixed")
def _mixed_family(spec: TrafficSpec, t: int, rng: np.random.Generator):
    """Multi-tenant serving mix: period ``t`` draws one tenant class.

    Cycles through ``classes`` (family names) period by period — the
    heterogeneous open-loop traffic a shared scheduling control plane
    sees. Per-class knobs pass through ``params`` unchanged.
    """
    from .registry import get_family

    p = spec.params
    classes = tuple(p.get("classes", ("moe_phases", "permutations", "uniform")))
    cls = classes[t % len(classes)]
    out = get_family(cls)(spec.replace(family=cls), t, rng)
    D, meta = out if isinstance(out, tuple) else (out, {})
    meta = dict(meta)
    meta["tenant_class"] = cls
    return D, meta


_DEFAULT_WIRE_BYTES = {
    "all-reduce": 4.0e9,       # DP/FSDP gradient sync per chip per step
    "all-to-all": 1.0e9,       # MoE expert dispatch
    "collective-permute": 0.5e9,  # pipeline activations
}


@register_family("uniform")
def _uniform_family(spec: TrafficSpec, t: int, rng: np.random.Generator):
    """Uniform all-to-all demand — the traffic rotors are built for.

    Every off-diagonal pair carries ``load`` units (optionally jittered by
    multiplicative ``noise``); the demand-oblivious round-robin sequence is
    near-optimal here, which is exactly the regime where scheduled fabrics
    stop paying for their matching solves.
    """
    p = spec.params
    load = float(_knob(p, "load", t, 1.0))
    noise = float(_knob(p, "noise", t, 0.0))
    n = spec.n
    D = np.full((n, n), load, dtype=np.float64)
    np.fill_diagonal(D, 0.0)
    if noise > 0:
        D *= 1.0 + noise * rng.standard_normal((n, n))
        np.maximum(D, 0.0, out=D)
        np.fill_diagonal(D, 0.0)
    return D, {"load": load, "noise": noise}


@register_family("collectives")
def _collectives_family(spec: TrafficSpec, t: int, rng: np.random.Generator):
    """HLO-collective-derived rack traffic in *bytes*, bursty per period.

    Per-op-class wire bytes fluctuate lognormally period to period
    (``burstiness`` = σ of the log factor), modeling step-time variation;
    the mapping onto the rack fabric is ``demand_from_collectives``.
    """
    p = spec.params
    wire = dict(p.get("wire_bytes", _DEFAULT_WIRE_BYTES))
    sigma = float(p.get("burstiness", 0.2))
    scales = {
        op: float(rng.lognormal(mean=0.0, sigma=sigma)) if sigma > 0 else 1.0
        for op in wire
    }
    wire = {op: b * scales[op] for op, b in wire.items()}
    chips_per_rack = int(p.get("chips_per_rack", 8))
    D = demand_from_collectives(
        wire,
        n_chips=spec.n * chips_per_rack,
        chips_per_rack=chips_per_rack,
        model_axis=int(p.get("model_axis", 16)),
    )
    return D, {"scales": scales}


# ---------------------------------------------------------------------------
# Named scenarios. s/δ defaults are the mid-grid evaluation point; benchmark
# sweeps override them per datapoint, run_scenario uses them as-is.
# ---------------------------------------------------------------------------

register_scenario(
    "gpt",
    TrafficSpec(family="gpt", n=32, s=4, delta=0.01, periods=8),
    description="GPT-3B 3D-parallel training traffic (32 racks, Fig. 6a)",
)
register_scenario(
    "gpt_noisy",
    TrafficSpec(family="gpt", n=32, s=4, delta=0.01, periods=8,
                params={"noise": 0.01}),
    description="GPT workload at 1% measurement noise (Fig. 8)",
)
register_scenario(
    "moe",
    TrafficSpec(family="moe", n=64, s=4, delta=0.01, periods=8),
    description="Qwen-MoE expert-routing traffic (64 GPUs, Fig. 6b)",
)
register_scenario(
    "moe_noisy",
    TrafficSpec(family="moe", n=64, s=4, delta=0.01, periods=8,
                params={"noise": 0.01}),
    description="MoE workload at 1% noise (Fig. 8)",
)
register_scenario(
    "benchmark",
    TrafficSpec(family="benchmark", n=100, s=4, delta=0.01, periods=8),
    description="Standard 100×100 16-permutation benchmark (Fig. 9)",
)
register_scenario(
    "sparsity_sweep",
    TrafficSpec(family="benchmark", n=100, s=4, delta=0.04, periods=6,
                params={"m_schedule": (4, 8, 12, 16, 24, 32)}),
    description="Per-period sparsity sweep: m flows/port cycling Fig. 10's grid",
)
register_scenario(
    "permutations",
    TrafficSpec(family="permutations", n=100, s=4, delta=0.01, periods=8),
    description="Sum of k=16 random permutations, fixed k (Fig. 11 trials)",
)
register_scenario(
    "degree_sweep",
    TrafficSpec(family="permutations", n=100, s=4, delta=0.01, periods=8,
                params={"k_schedule": (2, 4, 8, 12, 16, 20, 24, 32)}),
    description="Sum-of-k-permutations degree statistics (Fig. 11 / Appendix)",
)
register_scenario(
    "uniform",
    TrafficSpec(family="uniform", n=32, s=4, delta=0.01, periods=8),
    description="Uniform all-to-all traffic — the rotor/VLB home turf",
)
# Large-n scaling tier: the regime the fused auction kernel exists for.
# Short traces (few periods) keep wall-clock sane — per-period cost is what
# these scenarios measure, not trace length.
register_scenario(
    "benchmark_large",
    TrafficSpec(family="benchmark", n=256, s=4, delta=0.01, periods=4,
                params={"m": 32}),
    description="256-port m=32 benchmark — large-n matcher scaling tier",
)
register_scenario(
    "permutations_large",
    TrafficSpec(family="permutations", n=512, s=4, delta=0.01, periods=3,
                params={"k": 16}),
    description="512-port sum-of-16-permutations — large-n scaling tier",
)
register_scenario(
    "pod_1024",
    TrafficSpec(family="permutations", n=1024, s=4, delta=0.01, periods=2,
                params={"k": 8}),
    description="1024-port pod-scale smoke (k=8 perms, 2 periods)",
)
register_scenario(
    "moe_phases",
    TrafficSpec(family="moe_phases", n=64, s=4, delta=0.01, periods=8,
                params={"phases": 2}),
    description="Phase-cycling MoE routing — the support-cache workload "
                "(2 alternating sparse phases, 8 periods)",
)
register_scenario(
    "serve_mixed",
    TrafficSpec(family="mixed", n=16, s=4, delta=0.01, periods=8),
    description="Multi-tenant serving mix: moe_phases/permutations/uniform "
                "classes interleaved — the control-plane load profile",
)
register_scenario(
    "collective_ring",
    TrafficSpec(family="collectives", n=32, s=4, delta=20e-6, periods=8,
                units="bytes"),
    description="HLO-collective byte traffic over 32 racks, bursty per step",
)
