"""Scenario & trace API: one registry from workload to scheduled report.

    from repro.scenarios import make_trace, run_scenario, list_scenarios

    trace = make_trace("gpt", periods=8)            # (8, 32, 32) demand stack
    report = run_scenario("moe", solver="spectra_jax")
    print(report.summary())

A ``Scenario`` is a declarative ``TrafficSpec`` (workload family, n, s, δ,
bytes→units policy, T periods, seed) registered under a string name —
mirroring the solver registry in ``repro.api`` — that materializes a
``DemandTrace``: the time-varying traffic the paper's controller reschedules
every period. ``run_scenario`` pushes the whole trace through the batched
``solve_many`` (one fused device dispatch per shape bucket on
``spectra_jax``) and returns per-period makespans, lower-bound gaps, CCT
seconds for byte traces, and aggregate stats.

Built-in scenarios live in ``library`` (imported here so registration is a
side effect of importing the package); add your own with
``register_family`` / ``register_scenario``.
"""

from .registry import (
    Scenario,
    get_family,
    get_scenario,
    list_families,
    list_scenarios,
    make_trace,
    register_family,
    register_scenario,
)
from .runner import (
    OnlinePeriod,
    OnlineReport,
    PeriodResult,
    ScenarioReport,
    run_scenario,
)
from .spec import DemandTrace, TrafficSpec

from . import library  # noqa: E402,F401  (registers the built-in scenarios)

__all__ = [
    "DemandTrace", "OnlinePeriod", "OnlineReport", "PeriodResult", "Scenario",
    "ScenarioReport", "TrafficSpec",
    "get_family", "get_scenario", "list_families", "list_scenarios",
    "make_trace", "register_family", "register_scenario", "run_scenario",
]
