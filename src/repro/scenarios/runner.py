"""``run_scenario`` — a whole trace through the batched solver, one report.

The controller-period view of the paper: materialize a scenario's
``(T, n, n)`` demand trace, push every period through ``repro.api
.solve_many`` (on ``spectra_jax`` that is ONE fused device dispatch per
shape bucket), optionally replay each period through the event-level
simulator, and aggregate per-period makespans, lower-bound gaps, and — for
byte traces — CCT seconds under the scenario's ``OCSFabric``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from ..api import SolveOptions, SolveReport, solve_many
from .registry import Scenario, get_scenario
from .spec import DemandTrace, TrafficSpec


@dataclass
class PeriodResult:
    """One controller period's scheduling outcome."""

    period: int
    makespan: float          # demand-time units
    lower_bound: float       # §IV bound, same units (NaN if compute_lb=False)
    gap: float               # makespan / lower_bound
    num_configs: int
    cct_s: float             # wall-clock CCT seconds (NaN for unit traces)
    meta: dict = field(default_factory=dict)
    demand_met: bool | None = None   # simulator verdict (None unless simulated)
    ref_makespan: float = float("nan")  # quality_ref solver's makespan


@dataclass
class ScenarioReport:
    """Aggregate result of one scenario × solver run."""

    scenario: str
    solver: str
    spec: TrafficSpec
    trace: DemandTrace
    reports: list[SolveReport]       # per-period SolveReports, trace order
    periods: list[PeriodResult]
    unit_s: float                    # seconds per demand unit (NaN: unit trace)
    delta_units: float               # δ the solver actually saw, in units
    num_shape_buckets: int           # solve_many dispatch groups (1 per shape)
    runtime_s: float                 # wall time of the solve_many call
    quality_ref: str | None = None   # reference solver of the quality ratios

    @property
    def makespans(self) -> np.ndarray:
        return np.array([p.makespan for p in self.periods])

    @property
    def lower_bounds(self) -> np.ndarray:
        return np.array([p.lower_bound for p in self.periods])

    @property
    def gaps(self) -> np.ndarray:
        return np.array([p.gap for p in self.periods])

    @property
    def cct_s(self) -> np.ndarray:
        return np.array([p.cct_s for p in self.periods])

    @property
    def total_cct_s(self) -> float:
        finite = self.cct_s[np.isfinite(self.cct_s)]
        return float(finite.sum()) if len(finite) else float("nan")

    @property
    def geomean_gap(self) -> float:
        gaps = self.gaps
        finite = gaps[np.isfinite(gaps) & (gaps > 0)]
        return float(np.exp(np.mean(np.log(finite)))) if len(finite) else float("nan")

    @property
    def quality_ratios(self) -> np.ndarray:
        """Per-period makespan / ``quality_ref`` solver's makespan (NaN when
        ``run_scenario`` ran without a reference)."""
        return np.array(
            [p.makespan / p.ref_makespan if p.ref_makespan else float("nan")
             for p in self.periods]
        )

    @property
    def geomean_quality_ratio(self) -> float:
        r = self.quality_ratios
        finite = r[np.isfinite(r) & (r > 0)]
        return float(np.exp(np.mean(np.log(finite)))) if len(finite) else float("nan")

    @property
    def max_quality_ratio(self) -> float:
        r = self.quality_ratios
        finite = r[np.isfinite(r)]
        return float(finite.max()) if len(finite) else float("nan")

    def summary(self) -> dict[str, Any]:
        """Flat aggregate row (what the smoke lane and benchmarks print)."""
        mk = self.makespans
        return {
            "scenario": self.scenario,
            "solver": self.solver,
            "periods": self.trace.T,
            "n": self.trace.n,
            "s": self.spec.s,
            "mean_makespan": float(mk.mean()) if len(mk) else float("nan"),
            "max_makespan": float(mk.max()) if len(mk) else float("nan"),
            "geomean_gap": self.geomean_gap,
            "total_cct_s": self.total_cct_s,
            "buckets": self.num_shape_buckets,
            "runtime_s": self.runtime_s,
            # Device-vs-host (or any solver-vs-solver) quality: geomean of
            # per-period makespan ratios against quality_ref; NaN when the
            # run carried no reference.
            "quality_ratio": self.geomean_quality_ratio,
            "quality_ref": self.quality_ref,
        }


def run_scenario(
    scenario: str | Scenario | DemandTrace,
    *,
    solver: str = "spectra",
    options: SolveOptions | None = None,
    simulate: bool = False,
    processes: int | None = None,
    quality_ref: str | None = None,
    **overrides: Any,
) -> ScenarioReport:
    """Schedule a whole scenario trace with one batched ``solve_many`` call.

    ``scenario`` is a registered name, a ``Scenario``, or an
    already-materialized ``DemandTrace`` (overrides only apply to the first
    two). Byte traces are normalized trace-globally (one ``unit_s``, one
    δ-in-units) so the batch stays uniform; per-period CCT seconds are
    ``makespan · unit_s``. ``simulate=True`` additionally replays every
    period through ``repro.fabric.simulator`` and records ``demand_met``.

    ``quality_ref`` names a second solver (e.g. ``"spectra"`` as the exact
    host reference for a ``solver="spectra_jax"`` run) to solve the same
    trace with; per-period ``ref_makespan`` and the report's quality-ratio
    aggregates (``quality_ratios`` / ``geomean_quality_ratio`` /
    ``max_quality_ratio``, plus ``summary()["quality_ratio"]``) compare
    against it.
    """
    if isinstance(scenario, DemandTrace):
        if overrides:
            raise TypeError("overrides only apply to named scenarios, not traces")
        trace, name = scenario, f"trace[{scenario.spec.family}]"
    else:
        sc = get_scenario(scenario) if isinstance(scenario, str) else scenario
        trace, name = sc.trace(**overrides), sc.name
    spec = trace.spec
    options = options or SolveOptions()

    units, unit_s, delta_units = trace.normalized()
    t0 = time.perf_counter()
    reports = solve_many(
        units, spec.s, delta_units, solver=solver,
        options=options, processes=processes,
    )
    runtime_s = time.perf_counter() - t0

    ref_makespans = [float("nan")] * len(reports)
    if quality_ref is not None:
        ref_reports = solve_many(
            units, spec.s, delta_units, solver=quality_ref,
            options=SolveOptions(validate=False, compute_lb=False),
            processes=processes,
        )
        ref_makespans = [r.makespan for r in ref_reports]

    periods: list[PeriodResult] = []
    for t, rep in enumerate(reports):
        demand_met = None
        if simulate:
            from ..fabric.simulator import simulate as sim

            demand_met = bool(
                sim(rep, units[t], tol=options.tol(rep.backend)).demand_met
            )
        periods.append(
            PeriodResult(
                period=t,
                makespan=rep.makespan,
                lower_bound=rep.lower_bound,
                gap=rep.optimality_gap,
                num_configs=rep.num_configs,
                cct_s=rep.makespan * unit_s if np.isfinite(unit_s) else float("nan"),
                meta=dict(trace.period_meta[t]),
                demand_met=demand_met,
                ref_makespan=ref_makespans[t],
            )
        )
    # Traces are uniform (T, n, n) stacks today, so this is 1 until
    # mixed-n multi-pod traces land; derived from the same bucketing
    # solve_many applied to the actual submission.
    from ..api.batch import shape_buckets

    return ScenarioReport(
        scenario=name,
        solver=solver,
        spec=spec,
        trace=trace,
        reports=reports,
        periods=periods,
        unit_s=unit_s,
        delta_units=delta_units,
        num_shape_buckets=len(shape_buckets(list(units))),
        runtime_s=runtime_s,
        quality_ref=quality_ref,
    )
