"""``run_scenario`` — a whole trace through the batched solver, one report.

The controller-period view of the paper: materialize a scenario's
``(T, n, n)`` demand trace, push every period through ``repro.api
.solve_many`` (on ``spectra_jax`` that is ONE fused device dispatch per
shape bucket), optionally replay each period through the event-level
simulator, and aggregate per-period makespans, lower-bound gaps, and — for
byte traces — CCT seconds under the scenario's ``OCSFabric``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from ..api import SolveOptions, SolveReport, solve_many
from ..obs.trace import get_tracer
from .registry import Scenario, get_scenario
from .spec import DemandTrace, TrafficSpec


@dataclass
class PeriodResult:
    """One controller period's scheduling outcome."""

    period: int
    makespan: float          # demand-time units
    lower_bound: float       # §IV bound, same units (NaN if compute_lb=False)
    gap: float               # makespan / lower_bound
    num_configs: int
    cct_s: float             # wall-clock CCT seconds (NaN for unit traces)
    meta: dict = field(default_factory=dict)
    demand_met: bool | None = None   # simulator verdict (None unless simulated)
    ref_makespan: float = float("nan")  # quality_ref solver's makespan
    flowsim: Any = None              # FlowSimReport (None unless flowsim=True)


@dataclass
class ScenarioReport:
    """Aggregate result of one scenario × solver run."""

    scenario: str
    solver: str
    spec: TrafficSpec
    trace: DemandTrace
    reports: list[SolveReport]       # per-period SolveReports, trace order
    periods: list[PeriodResult]
    unit_s: float                    # seconds per demand unit (NaN: unit trace)
    delta_units: Any                 # δ in units: scalar, or (T,) for δ sweeps
    num_shape_buckets: int           # solve_many dispatch groups (1 per shape)
    runtime_s: float                 # wall time of the solve_many call
    quality_ref: str | None = None   # reference solver of the quality ratios
    flowsim_options: Any = None      # resolved FlowSimOptions (None: no flowsim)

    @property
    def deltas_units(self) -> np.ndarray:
        """Per-period δ in units, shape (T,) — broadcast when constant."""
        return np.broadcast_to(
            np.asarray(self.delta_units, dtype=np.float64), (self.trace.T,)
        )

    @property
    def makespans(self) -> np.ndarray:
        return np.array([p.makespan for p in self.periods])

    @property
    def lower_bounds(self) -> np.ndarray:
        return np.array([p.lower_bound for p in self.periods])

    @property
    def gaps(self) -> np.ndarray:
        return np.array([p.gap for p in self.periods])

    @property
    def cct_s(self) -> np.ndarray:
        return np.array([p.cct_s for p in self.periods])

    @property
    def total_cct_s(self) -> float:
        finite = self.cct_s[np.isfinite(self.cct_s)]
        return float(finite.sum()) if len(finite) else float("nan")

    @property
    def geomean_gap(self) -> float:
        gaps = self.gaps
        finite = gaps[np.isfinite(gaps) & (gaps > 0)]
        return float(np.exp(np.mean(np.log(finite)))) if len(finite) else float("nan")

    @property
    def quality_ratios(self) -> np.ndarray:
        """Per-period makespan / ``quality_ref`` solver's makespan (NaN when
        ``run_scenario`` ran without a reference)."""
        return np.array(
            [p.makespan / p.ref_makespan if p.ref_makespan else float("nan")
             for p in self.periods]
        )

    @property
    def geomean_quality_ratio(self) -> float:
        r = self.quality_ratios
        finite = r[np.isfinite(r) & (r > 0)]
        return float(np.exp(np.mean(np.log(finite)))) if len(finite) else float("nan")

    @property
    def max_quality_ratio(self) -> float:
        r = self.quality_ratios
        finite = r[np.isfinite(r)]
        return float(finite.max()) if len(finite) else float("nan")

    def warning_counters(self):
        """Solver warnings across all periods, tallied as obs ``Counters``
        (``matcher_budget_exhausted`` / ``equalize_headroom_exhausted``)."""
        from ..obs.metrics import warning_counts

        return warning_counts(self.reports)

    def attribution_summary(self, tol: float | None = None) -> dict[str, Any]:
        """Makespan attribution over the whole trace: where the switch-time
        budget went (serve / δ paid / idle shares) and the exact LB-gap
        decomposition, with the identity checked on every period. Expands
        every period's timeline — materializes lazy device schedules."""
        from ..obs.timeline_table import attribute_scenario

        att = attribute_scenario(self, tol=tol)
        att.check()
        return att.summary()

    @property
    def flowsim_reports(self) -> list:
        """Per-period FlowSimReports, trace order (empty when flowsim off)."""
        return [p.flowsim for p in self.periods if p.flowsim is not None]

    @property
    def fct_all(self) -> np.ndarray:
        """Every period's flow completion times pooled into one sample."""
        fs = self.flowsim_reports
        if not fs:
            return np.array([])
        return np.concatenate([f.fct for f in fs])

    def flowsim_summary(self) -> dict[str, Any]:
        """Trace-level flow stats: pooled FCT distribution, worst-period
        CCT, conservation verdict over every period, mean utilization and
        δ-overhead. Raises if the report was built without flowsim."""
        from ..flowsim import FlowStats

        fs = self.flowsim_reports
        if not fs:
            raise ValueError(
                "no flow-level results: run_scenario(..., flowsim=True)"
            )
        stats = FlowStats.from_sample(self.fct_all)
        return {
            "scenario": self.scenario,
            "solver": self.solver,
            "periods": len(fs),
            "flows": int(sum(f.num_flows for f in fs)),
            "completed": int(sum(f.completed for f in fs)),
            "fct_p50": stats.p50,
            "fct_p90": stats.p90,
            "fct_p99": stats.p99,
            "fct_mean": stats.mean,
            "fct_max": stats.max,
            "cct_max": float(max(f.cct for f in fs)),
            "cct_mean": float(np.mean([f.cct for f in fs])),
            "util_mean": float(
                np.mean([f.utilization.mean() for f in fs])
            ),
            "delta_overhead": float(np.mean([f.delta_overhead for f in fs])),
            # Mean per-period switch-time attribution shares (see
            # repro.obs.timeline_table): serve + δ + idle = 1 per switch.
            "delta_share": float(
                np.mean([f.summary()["delta_share"] for f in fs])
            ),
            "idle_share": float(
                np.mean([f.summary()["idle_share"] for f in fs])
            ),
            "indirect_frac": float(
                np.mean([f.indirect_fraction for f in fs])
            ),
            "conserved": bool(all(f.conserved for f in fs)),
            "residual": float(sum(f.residual for f in fs)),
        }

    def summary(self) -> dict[str, Any]:
        """Flat aggregate row (what the smoke lane and benchmarks print).

        When the run carried ``flowsim=True`` the row also gets the
        flow-level headline keys (``fct_p50``/``fct_p99``/``conserved``)
        from ``flowsim_summary()``.
        """
        mk = self.makespans
        row = {
            "scenario": self.scenario,
            "solver": self.solver,
            "periods": self.trace.T,
            "n": self.trace.n,
            "s": self.spec.s,
            "mean_makespan": float(mk.mean()) if len(mk) else float("nan"),
            "max_makespan": float(mk.max()) if len(mk) else float("nan"),
            "geomean_gap": self.geomean_gap,
            "total_cct_s": self.total_cct_s,
            "buckets": self.num_shape_buckets,
            "runtime_s": self.runtime_s,
            # Device-vs-host (or any solver-vs-solver) quality: geomean of
            # per-period makespan ratios against quality_ref; NaN when the
            # run carried no reference.
            "quality_ratio": self.geomean_quality_ratio,
            "quality_ref": self.quality_ref,
        }
        # Degraded solves, visible without digging into per-report extras:
        # total warning count always; the per-category tally when nonzero.
        warnings = self.warning_counters()
        row["warnings"] = warnings.total
        if warnings:
            row["warning_counts"] = warnings.export()
        if self.flowsim_reports:
            fs = self.flowsim_summary()
            row.update(
                fct_p50=fs["fct_p50"],
                fct_p99=fs["fct_p99"],
                conserved=fs["conserved"],
            )
        return row


@dataclass
class OnlinePeriod:
    """One controller period of the *online* (stateful) pass."""

    period: int
    makespan: float            # credit-aware effective makespan
    stateless_makespan: float  # the same period's stateless baseline
    reuse_count: int           # switches serving a carried config δ-free
    delta_paid: float          # δ · (configs − reuse_count)
    delta_avoided: float       # δ · reuse_count
    warm: bool                 # warm-start decomposition used
    num_configs: int
    schedule: Any = None       # ParallelSchedule in reuse serve order
    demand_met: bool | None = None  # online simulator verdict

    @property
    def ratio(self) -> float:
        """online / stateless makespan (≤ 1 + float tolerance)."""
        return (
            self.makespan / self.stateless_makespan
            if self.stateless_makespan
            else 1.0
        )


@dataclass
class OnlineReport(ScenarioReport):
    """``ScenarioReport`` plus the stateful (online) pass over the trace.

    The base fields describe the stateless per-period solve — the baseline.
    ``online_periods`` carries the stateful controller's outcomes: per
    period, the reuse credit earned (δ avoided), δ actually paid, and the
    effective makespan, which is ≤ the stateless makespan by construction
    (the stateless schedule with the credit applied post-hoc is always a
    candidate).
    """

    online_periods: list[OnlinePeriod] = field(default_factory=list)
    online_runtime_s: float = float("nan")
    online_solver: str = ""          # "host" (controller) or "scan" (device)

    @property
    def online_makespans(self) -> np.ndarray:
        return np.array([p.makespan for p in self.online_periods])

    @property
    def online_ratios(self) -> np.ndarray:
        """Per-period online / stateless makespan ratios."""
        return np.array([p.ratio for p in self.online_periods])

    @property
    def reuse_counts(self) -> np.ndarray:
        return np.array([p.reuse_count for p in self.online_periods])

    @property
    def total_reuse(self) -> int:
        return int(self.reuse_counts.sum())

    @property
    def total_delta_avoided(self) -> float:
        return float(sum(p.delta_avoided for p in self.online_periods))

    @property
    def total_delta_paid(self) -> float:
        return float(sum(p.delta_paid for p in self.online_periods))

    @property
    def total_improvement(self) -> float:
        """Σ_t (stateless − online) makespan over the trace (≥ 0)."""
        return float(
            sum(p.stateless_makespan - p.makespan for p in self.online_periods)
        )

    def online_summary(self) -> dict[str, Any]:
        base = self.summary()
        mk = self.online_makespans
        base.update(
            online_solver=self.online_solver,
            online_mean_makespan=float(mk.mean()) if len(mk) else float("nan"),
            online_total_makespan=float(mk.sum()) if len(mk) else float("nan"),
            stateless_total_makespan=float(
                sum(p.stateless_makespan for p in self.online_periods)
            ),
            total_reuse=self.total_reuse,
            total_delta_avoided=self.total_delta_avoided,
            total_delta_paid=self.total_delta_paid,
            mean_online_ratio=(
                float(self.online_ratios.mean())
                if len(self.online_periods)
                else float("nan")
            ),
            online_runtime_s=self.online_runtime_s,
        )
        return base


# Registry-name sugar: run_scenario(solver="spectra_online[_jax]") implies
# online=True with the matching stateless baseline solver.
_ONLINE_SOLVER_ALIASES = {
    "spectra_online": "spectra",
    "spectra_online_jax": "spectra_jax",
}


def run_scenario(
    scenario: str | Scenario | DemandTrace,
    *,
    solver: str = "spectra",
    options: SolveOptions | None = None,
    simulate: bool = False,
    flowsim: bool = False,
    flowsim_options: Any = None,
    processes: int | None = None,
    quality_ref: str | None = None,
    online: bool = False,
    **overrides: Any,
) -> ScenarioReport:
    """Schedule a whole scenario trace with one batched ``solve_many`` call.

    ``scenario`` is a registered name, a ``Scenario``, or an
    already-materialized ``DemandTrace`` (overrides only apply to the first
    two). Byte traces are normalized trace-globally (one ``unit_s``, one
    δ-in-units) so the batch stays uniform; per-period CCT seconds are
    ``makespan · unit_s``. ``simulate=True`` additionally replays every
    period through ``repro.fabric.simulator`` and records ``demand_met``.

    ``flowsim=True`` replays every period at the *flow* level
    (``repro.flowsim.simulate_flows``): each ``PeriodResult.flowsim`` gets
    a ``FlowSimReport`` (FCT/CCT distributions, utilization, conservation)
    and the report grows ``flowsim_reports`` / ``fct_all`` /
    ``flowsim_summary()``. Options resolve from ``flowsim_options`` if
    given, else from the spec's ``flowsim_params``; solvers that mark
    ``extras["indirection"]`` (e.g. ``rotor_vlb``) get 2-hop VLB
    automatically under the default ``indirection="auto"``.

    ``quality_ref`` names a second solver (e.g. ``"spectra"`` as the exact
    host reference for a ``solver="spectra_jax"`` run) to solve the same
    trace with; per-period ``ref_makespan`` and the report's quality-ratio
    aggregates (``quality_ratios`` / ``geomean_quality_ratio`` /
    ``max_quality_ratio``, plus ``summary()["quality_ratio"]``) compare
    against it.

    ``online=True`` (or ``solver="spectra_online[_jax]"``) additionally runs
    the *stateful* cross-period controller over the trace — host
    ``repro.online.OnlineController`` for numpy solvers, the single-dispatch
    ``lax.scan`` rolling solve for ``spectra_jax`` — and returns an
    ``OnlineReport`` whose base fields stay the stateless baseline. A
    ``delta_schedule`` on the scenario threads per-period δ through both
    passes.
    """
    if isinstance(scenario, DemandTrace):
        if overrides:
            raise TypeError("overrides only apply to named scenarios, not traces")
        trace, name = scenario, f"trace[{scenario.spec.family}]"
    else:
        sc = get_scenario(scenario) if isinstance(scenario, str) else scenario
        trace, name = sc.trace(**overrides), sc.name
    spec = trace.spec
    options = options or SolveOptions()
    if solver in _ONLINE_SOLVER_ALIASES:
        online, solver = True, _ONLINE_SOLVER_ALIASES[solver]

    units, unit_s, delta_units = trace.normalized()
    t0 = time.perf_counter()
    reports = solve_many(
        units, spec.s, delta_units, solver=solver,
        options=options, processes=processes,
    )
    runtime_s = time.perf_counter() - t0

    ref_makespans = [float("nan")] * len(reports)
    if quality_ref is not None:
        ref_reports = solve_many(
            units, spec.s, delta_units, solver=quality_ref,
            options=SolveOptions(validate=False, compute_lb=False),
            processes=processes,
        )
        ref_makespans = [r.makespan for r in ref_reports]

    fs_opts = None
    if flowsim:
        from ..flowsim import FlowSimOptions, simulate_flows

        fs_opts = flowsim_options or FlowSimOptions.from_params(
            spec.flowsim_params
        )

    tracer = get_tracer()
    periods: list[PeriodResult] = []
    for t, rep in enumerate(reports):
        # "install" is the fabric handoff: the point the period's schedule
        # leaves the solver and is replayed/recorded against the switches.
        with tracer.span(
            "period", {"period": t} if tracer.enabled else None
        ), tracer.span("install", {"period": t} if tracer.enabled else None):
            demand_met = None
            if simulate:
                from ..fabric.simulator import simulate as sim

                demand_met = bool(
                    sim(rep, units[t], tol=options.tol(rep.backend)).demand_met
                )
            fs_report = None
            if flowsim:
                fs_report = simulate_flows(rep, units[t], options=fs_opts)
            periods.append(
                PeriodResult(
                    period=t,
                    makespan=rep.makespan,
                    lower_bound=rep.lower_bound,
                    gap=rep.optimality_gap,
                    num_configs=rep.num_configs,
                    cct_s=rep.makespan * unit_s if np.isfinite(unit_s) else float("nan"),
                    meta=dict(trace.period_meta[t]),
                    demand_met=demand_met,
                    ref_makespan=ref_makespans[t],
                    flowsim=fs_report,
                )
            )
    # Traces are uniform (T, n, n) stacks today, so this is 1 until
    # mixed-n multi-pod traces land; derived from the same bucketing
    # solve_many applied to the actual submission.
    from ..api.batch import shape_buckets

    base = dict(
        scenario=name,
        solver=solver,
        spec=spec,
        trace=trace,
        reports=reports,
        periods=periods,
        unit_s=unit_s,
        delta_units=delta_units,
        num_shape_buckets=len(shape_buckets(list(units))),
        runtime_s=runtime_s,
        quality_ref=quality_ref,
        flowsim_options=fs_opts,
    )
    if not online:
        return ScenarioReport(**base)

    online_periods, online_runtime_s, mode = _run_online(
        trace, units, delta_units, reports, options,
        simulate=simulate, solver=solver,
    )
    return OnlineReport(
        **base,
        online_periods=online_periods,
        online_runtime_s=online_runtime_s,
        online_solver=mode,
    )


def _run_online(
    trace: DemandTrace,
    units: np.ndarray,
    delta_units,
    stateless: list[SolveReport],
    options: SolveOptions,
    *,
    simulate: bool,
    solver: str,
) -> tuple[list[OnlinePeriod], float, str]:
    """The stateful pass: host controller loop or device ``lax.scan``.

    Whatever the backend produced, every period is re-priced and clamped
    here against the TRUE stateless baseline (the batched ``stateless``
    reports) along one sequential replay chain: the backend's candidate and
    the stateless schedule with the reuse credit applied post-hoc are both
    evaluated against the *reported* installed state, and the better one is
    kept. This pins ``online ≤ stateless`` per period by construction even
    when a warm-start decomposition (a different decomposition than the
    baseline's) slipped past the quality gate, and keeps the credit
    accounting consistent with the replayed chain.
    """
    from ..online import (
        SwitchState,
        advance_installed,
        apply_reuse_order,
        effective_loads,
    )

    spec = trace.spec
    deltas = np.broadcast_to(
        np.asarray(delta_units, dtype=np.float64), (trace.T,)
    )
    device = solver == "spectra_jax"
    t0 = time.perf_counter()
    if device:
        rows = _online_scan_rows(trace, units, deltas, options)
    else:
        rows = _online_host_rows(trace, units, deltas, stateless, options)
    online_runtime_s = time.perf_counter() - t0

    tracer = get_tracer()
    tol = options.tol("jax" if device else "numpy")
    periods: list[OnlinePeriod] = []
    installed = [None] * spec.s  # the reported replay chain
    for t, (sched, _marks, row) in enumerate(rows):
        with tracer.span(
            "online.period", {"period": t} if tracer.enabled else None
        ):
            state = SwitchState(installed=installed)
            cand, cand_marks = apply_reuse_order(sched, state)
            cand_mk = float(effective_loads(cand, cand_marks).max())
            base, base_marks = apply_reuse_order(stateless[t].schedule, state)
            base_mk = float(effective_loads(base, base_marks).max())
            if cand_mk <= base_mk:
                chosen, marks, mk = cand, cand_marks, cand_mk
            else:
                chosen, marks, mk = base, base_marks, base_mk
            reuse_count = int(marks.sum())
            num_configs = chosen.num_configs()
            d = float(deltas[t])
            row = dict(
                row,
                makespan=mk,
                stateless_makespan=float(stateless[t].makespan),
                reuse_count=reuse_count,
                delta_avoided=d * reuse_count,
                delta_paid=d * (num_configs - reuse_count),
                num_configs=num_configs,
            )
            with tracer.span(
                "install", {"period": t} if tracer.enabled else None
            ):
                if options.validate:
                    chosen.validate(units[t], tol=tol)
                demand_met = None
                if simulate:
                    from ..fabric.simulator import simulate as sim

                    demand_met = bool(
                        sim(
                            chosen, units[t], tol=tol, installed=installed
                        ).demand_met
                    )
                installed = advance_installed(chosen, state, marks)
            periods.append(
                OnlinePeriod(
                    period=t,
                    schedule=chosen,
                    demand_met=demand_met,
                    **row,
                )
            )
    return periods, online_runtime_s, "scan" if device else "host"


def _online_host_rows(trace, units, deltas, stateless, options):
    """Host controller over the trace, donating the batched stateless
    schedules/decompositions as the baseline candidates."""
    from ..online import OnlineController

    spec = trace.spec
    ctl = OnlineController(
        s=spec.s,
        delta=float(deltas[0]),
        warm_start=bool(options.extra.get("warm_start", True)),
        warm_slack=float(options.extra.get("warm_slack", 0.05)),
        merge_aware=bool(options.extra.get("merge_aware", False)),
        do_equalize=bool(options.extra.get("equalize", True)),
        cache_size=int(options.extra.get("cache_size", 8)),
    )
    rows = []
    for t in range(trace.T):
        out = ctl.step(
            units[t],
            delta=float(deltas[t]),
            stateless=stateless[t].schedule,
            decomposition=stateless[t].decomposition,
        )
        rows.append(
            (
                out.schedule,
                out.reused_switches,
                dict(
                    makespan=out.makespan,
                    stateless_makespan=out.stateless_makespan,
                    reuse_count=out.reuse_count,
                    delta_paid=out.delta_paid,
                    delta_avoided=out.delta_avoided,
                    warm=out.warm,
                    num_configs=out.num_configs,
                ),
            )
        )
    return rows


def _online_scan_rows(trace, units, deltas, options):
    """Device rolling solve: the whole trace in ONE ``lax.scan`` dispatch."""
    import jax

    from ..core.jaxopt.matching import default_matcher
    from ..core.jaxopt.online_jax import spectra_online_scan
    from ..core.schedule_ir import DeviceSchedule
    from ..kernels.backend import resolve_use_kernel
    from ..online import online_ir_to_schedule

    spec = trace.spec
    res, _ = spectra_online_scan(
        units.astype(np.float32),
        spec.s,
        deltas.astype(np.float32),
        use_kernel=resolve_use_kernel(options.extra.get("use_kernel")),
        do_equalize=bool(options.extra.get("equalize", True)),
        merge_aware=bool(options.extra.get("merge_aware", False)),
        extra_slots=int(options.extra.get("extra_slots", 64)),
        matcher=str(options.extra.get("matcher") or default_matcher(trace.n)),
        repair_rounds=int(options.extra.get("repair_rounds", 0)),
        warm_start=bool(options.extra.get("warm_start", True)),
        warm_prices=bool(options.extra.get("warm_prices", False)),
        warm_slack=float(options.extra.get("warm_slack", 0.05)),
        cache_size=int(options.extra.get("cache_size", 8)),
    )
    jax.block_until_ready(res.makespan)
    perms = np.asarray(res.schedule.perms)
    alphas = np.asarray(res.schedule.alphas, dtype=np.float64)
    switch = np.asarray(res.schedule.switch)
    reused = np.asarray(res.reused)
    makespans = np.asarray(res.makespan, dtype=np.float64)
    stateless_mks = np.asarray(res.stateless_makespan, dtype=np.float64)
    reuse_counts = np.asarray(res.reuse_count)
    warms = np.asarray(res.warm)
    rows = []
    for t in range(trace.T):
        ds = DeviceSchedule(
            perms=perms[t], alphas=alphas[t], switch=switch[t],
            delta=float(deltas[t]),
        )
        sched, marks = online_ir_to_schedule(ds, spec.s, reused[t])
        num_configs = int((switch[t] >= 0).sum())
        rc = int(reuse_counts[t])
        rows.append(
            (
                sched,
                marks,
                dict(
                    makespan=float(makespans[t]),
                    stateless_makespan=float(stateless_mks[t]),
                    reuse_count=rc,
                    delta_paid=float(deltas[t]) * (num_configs - rc),
                    delta_avoided=float(deltas[t]) * rc,
                    warm=bool(warms[t]),
                    num_configs=num_configs,
                ),
            )
        )
    return rows
