"""Model + input-spec factory."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeCfg
from .lm import LM, _dtype


def build_model(cfg: ModelConfig, **kw) -> LM:
    return LM(cfg=cfg, **kw)


def input_specs(cfg: ModelConfig, shape: ShapeCfg) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    Training/prefill: token batch (+ stub modality inputs).
    Decode: one new token; the KV/SSM caches are provided separately by
    ``cache_specs`` (they are donated step state, not fresh inputs).
    """
    B, S = shape.global_batch, shape.seq_len
    dt = _dtype(cfg)
    tok = jax.ShapeDtypeStruct

    if shape.kind == "decode":
        batch = {"tokens": tok((B, 1), jnp.int32)}
        return batch

    batch = {"tokens": tok((B, S), jnp.int32)}
    if cfg.family == "audio":
        # Conv frontend stub: precomputed frame embeddings at 2× downsample.
        batch["frames"] = tok((B, max(S // 2, 8), cfg.d_model), dt)
    if cfg.family == "vlm":
        n_patch = min(256, S)
        batch["patch_embeds"] = tok((B, n_patch, cfg.d_model), dt)
        batch["positions"] = tok((B, S, len(cfg.mrope_sections)), jnp.int32)
    return batch


def cache_specs(cfg: ModelConfig, shape: ShapeCfg) -> dict | None:
    """ShapeDtypeStruct pytree for the decode caches of a cell."""
    if shape.kind != "decode":
        return None
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len

    def make(p=None):
        enc_out = None
        if cfg.family == "audio":
            enc_out = jnp.zeros((B, max(S // 2, 8), cfg.d_model), _dtype(cfg))
        return model.init_cache(p, B, S, enc_out=enc_out)

    return jax.eval_shape(lambda: make(None))


def concrete_inputs(cfg: ModelConfig, shape: ShapeCfg, seed: int = 0) -> dict:
    """Materialized random inputs (smoke tests / examples)."""
    specs = input_specs(cfg, shape)
    rng = jax.random.PRNGKey(seed)
    out = {}
    for name, spec in specs.items():
        rng, k = jax.random.split(rng)
        if spec.dtype == jnp.int32 and name in ("tokens",):
            out[name] = jax.random.randint(k, spec.shape, 0, cfg.vocab_size)
        elif spec.dtype == jnp.int32:
            pos = jnp.arange(spec.shape[1])[None, :, None]
            out[name] = jnp.broadcast_to(pos, spec.shape).astype(jnp.int32)
        else:
            out[name] = jax.random.normal(k, spec.shape, spec.dtype) * 0.02
    return out
