"""Transformer / MoE / Mamba-2 blocks with train and decode paths.

Every block is a pure function ``(params, x, ...) -> (y, new_cache)``.
Caches are dicts of arrays (pytrees) so they thread through jit/pjit and
can be donated in the serving loop.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, MoECfg, SSMCfg
from ..kernels.flash_attention.ops import mha
from ..kernels.ssd_scan.ops import ssd_decode_step, ssd_scan
from .layers import apply_rope, causal_conv1d, dense, rms_norm, silu, winit, zinit

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Attention block.
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, d_ff: int | None = None,
              with_mlp: bool = True) -> Params:
    D = cfg.d_model
    dh = cfg.resolved_head_dim
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    F = cfg.d_ff if d_ff is None else d_ff
    ks = jax.random.split(key, 8)
    p = {
        "norm1": zinit((D,)),
        "wq": winit(ks[0], (D, Hq * dh)),
        "wk": winit(ks[1], (D, Hkv * dh)),
        "wv": winit(ks[2], (D, Hkv * dh)),
        "wo": winit(ks[3], (Hq * dh, D)),
    }
    if with_mlp:
        p.update({
            "norm2": zinit((D,)),
            "wi_gate": winit(ks[4], (D, F)),
            "wi_up": winit(ks[5], (D, F)),
            "wdown": winit(ks[6], (F, D)),
        })
    return p


def _split_heads(x, n_heads, dh):
    B, S, _ = x.shape
    return x.reshape(B, S, n_heads, dh).transpose(0, 2, 1, 3)  # (B,H,S,dh)


def _merge_heads(x):
    B, H, S, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * dh)


def _decode_attention(q, k_cache, v_cache, keep, scale):
    """Masked single-query attention over a static-size cache.

    q: (B, Hq, 1, dh); caches: (B, Hkv, Smax, dh); keep: (Smax,) bool mask of
    valid cache slots.
    """
    B, Hq, _, dh = q.shape
    Hkv = k_cache.shape[1]
    group = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, dh)
    s = jnp.einsum("bhgd,bhsd->bhgs", qf, k_cache.astype(jnp.float32)) * scale
    s = jnp.where(keep[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, 1, dh).astype(q.dtype)


def attn_apply(
    p: Params,
    x,
    *,
    cfg: ModelConfig,
    positions,
    causal: bool = True,
    window: int | None = None,
    cache: Params | None = None,
    attn_impl: str = "pallas",
    kv_override=None,
    with_mlp: bool = True,
    chunk_unroll: bool = False,
):
    """Self-attention (+ SwiGLU MLP) block with pre-norms and residuals.

    ``cache`` (decode): {"k": (B,Hkv,Smax,dh), "v": ..., "pos": ()}.
    ``kv_override``: (k_src, v_src) activations for cross-attention.
    """
    D = cfg.d_model
    dh = cfg.resolved_head_dim
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    scale = dh ** -0.5

    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    q = _split_heads(dense(h, p["wq"]), Hq, dh)
    if kv_override is None:
        k = _split_heads(dense(h, p["wk"]), Hkv, dh)
        v = _split_heads(dense(h, p["wv"]), Hkv, dh)
    else:
        ksrc, vsrc = kv_override
        k = _split_heads(dense(ksrc, p["wk"]), Hkv, dh)
        v = _split_heads(dense(vsrc, p["wv"]), Hkv, dh)

    new_cache = None
    if cache is None:
        if kv_override is None:  # self-attention: rotate q and k
            q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        attn = mha(q, k, v, causal=causal, window=window, scale=scale,
                   impl=attn_impl, chunk_unroll=chunk_unroll)
    else:
        pos = cache["pos"]  # () int32 — current absolute position
        if kv_override is None:
            pos_b = jnp.broadcast_to(pos[None, None], (x.shape[0], 1))
            if cfg.mrope_sections:
                pos_b = jnp.broadcast_to(
                    pos[None, None, None],
                    (x.shape[0], 1, len(cfg.mrope_sections)),
                )
            q = apply_rope(q, pos_b, cfg.rope_theta, cfg.mrope_sections)
            k = apply_rope(k, pos_b, cfg.rope_theta, cfg.mrope_sections)
            smax = cache["k"].shape[2]
            slots = jnp.arange(smax)
            if window is not None and smax == window:
                # Ring buffer: the cache holds only the last `window` keys.
                write = pos % window
                k_cache = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k, write, 2
                )
                v_cache = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v, write, 2
                )
                abs_pos = pos - jnp.mod(pos - slots, window)
                keep = abs_pos >= 0  # uninitialized slots are negative
            else:
                k_cache = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k, pos, 2
                )
                v_cache = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v, pos, 2
                )
                keep = slots <= pos
                if window is not None:
                    keep &= slots > pos - window
            new_cache = {"k": k_cache, "v": v_cache, "pos": pos + 1}
        else:
            # Cross-attention (decode): K/V recomputed from the encoder
            # output each step (a production server would precompute them
            # once per request; noted in EXPERIMENTS.md §Perf).
            k_cache, v_cache = k, v
            keep = jnp.ones((k.shape[2],), bool)
            new_cache = cache
        attn = _decode_attention(q, k_cache, v_cache, keep, scale)

    x = x + dense(_merge_heads(attn), p["wo"])
    if with_mlp:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + dense(
            silu(dense(h, p["wi_gate"])) * dense(h, p["wi_up"]), p["wdown"]
        )
    return x, new_cache


# ---------------------------------------------------------------------------
# MoE block (capacity-based gather/scatter dispatch — active-FLOPs faithful).
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> Params:
    m = cfg.moe
    D, Fe, E = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(key, 8)
    p = {
        "norm1": zinit((D,)),
        "router": winit(ks[0], (D, E)),
        "we_gate": winit(ks[1], (E, D, Fe)),
        "we_up": winit(ks[2], (E, D, Fe)),
        "we_down": winit(ks[3], (E, Fe, D)),
    }
    if m.num_shared:
        Fs = Fe * m.num_shared
        p["ws_gate"] = winit(ks[4], (D, Fs))
        p["ws_up"] = winit(ks[5], (D, Fs))
        p["ws_down"] = winit(ks[6], (Fs, D))
    return p


def moe_ffn(p: Params, x, m: MoECfg):
    """Routed expert FFN on (T, D) tokens → (T, D), plus routing stats."""
    T, D = x.shape
    E, K = m.num_experts, m.top_k
    logits = dense(x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    C = int(math.ceil(T * K * m.capacity_factor / E))
    C = max(4, -(-C // 4) * 4)  # round up to a multiple of 4
    # Position of each (token, choice) within its expert queue.
    e_flat = gate_idx.reshape(-1)  # (T*K,) token-major, choice-minor
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # exclusive prefix count
    pos = (pos * onehot).sum(-1)  # (T*K,)
    keep = pos < C
    slot = jnp.where(keep, e_flat * C + pos, E * C)  # overflow → sentinel
    tok_ids = jnp.repeat(jnp.arange(T), K)
    # slot → token index / gate weight maps (sentinel row dropped).
    token_map = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(tok_ids)
    gate_map = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
        gate_vals.reshape(-1)
    )
    valid = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(1.0)
    token_map, gate_map, valid = (
        token_map[:-1], gate_map[:-1], valid[:-1])

    xe = x[token_map] * valid[:, None].astype(x.dtype)  # (E*C, D)
    xe = xe.reshape(E, C, D)
    he = jnp.einsum("ecd,edf->ecf", xe, p["we_gate"].astype(x.dtype))
    ue = jnp.einsum("ecd,edf->ecf", xe, p["we_up"].astype(x.dtype))
    ye = jnp.einsum("ecf,efd->ecd", silu(he) * ue, p["we_down"].astype(x.dtype))
    ye = ye.reshape(E * C, D) * (gate_map * valid)[:, None].astype(x.dtype)
    y = jnp.zeros_like(x).at[token_map].add(ye)

    # Stats: per-expert token load (drives the MoE demand matrix) + aux loss.
    load = jnp.bincount(e_flat, length=E).astype(jnp.float32)
    importance = probs.sum(0)
    aux = E * jnp.mean(
        (load / jnp.maximum(load.sum(), 1.0))
        * (importance / jnp.maximum(importance.sum(), 1.0))
    )
    return y, {"expert_load": load, "aux_loss": aux * m.router_aux_coef}


def moe_apply(p: Params, x, *, cfg: ModelConfig):
    """Pre-norm MoE FFN (+ optional shared experts) with residual.

    Dispatch granularity: tokens are grouped **per batch row** whenever a
    row holds enough tokens (S ≥ 4·E). Group-local gather/scatter keeps the
    batch dim shardable over the data axis and the expert dim over the
    model axis — global dispatch would force the compiler to replicate the
    expert GEMMs (observed 700× FLOPs blow-up in the dry-run). Decode
    (S = 1) and tiny rows fall back to one global group.
    """
    B, S, D = x.shape
    m = cfg.moe
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if S >= 4 * m.num_experts:
        groups = h.reshape(B, S, D)
        y, stats = jax.vmap(lambda xg: moe_ffn(p, xg, m))(groups)
        stats = {
            "expert_load": stats["expert_load"].sum(0),
            "aux_loss": stats["aux_loss"].mean(),
        }
        y = y.reshape(B, S, D)
    else:
        flat = h.reshape(B * S, D)
        y, stats = moe_ffn(p, flat, m)
        y = y.reshape(B, S, D)
    if "ws_gate" in p:
        flat = h.reshape(B, S, D)
        y = y + dense(
            silu(dense(flat, p["ws_gate"])) * dense(flat, p["ws_up"]),
            p["ws_down"],
        )
    return x + y, stats


# ---------------------------------------------------------------------------
# Mamba-2 block.
# ---------------------------------------------------------------------------

def _ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return s, d_inner, H, conv_dim


def mamba_init(key, cfg: ModelConfig) -> Params:
    s, d_inner, H, conv_dim = _ssm_dims(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "norm": zinit((D,)),
        "w_xz": winit(ks[0], (D, 2 * d_inner)),
        "w_bc": winit(ks[1], (D, 2 * s.n_groups * s.d_state)),
        "w_dt": winit(ks[2], (D, H)),
        "dt_bias": zinit((H,)),
        "A_log": jnp.zeros((H,)),  # A = -exp(A_log) = -1 initially
        "skip_D": jnp.ones((H,)),
        "conv_w": winit(ks[3], (s.conv_width, conv_dim), scale=0.5),
        "out_norm": zinit((d_inner,)),
        "w_out": winit(ks[4], (d_inner, D)),
    }


def mamba_apply(
    p: Params,
    x,
    *,
    cfg: ModelConfig,
    cache: Params | None = None,
    ssd_impl: str = "pallas",
    chunk_unroll: bool = False,
):
    """Mamba-2 (SSD) block. cache: {"conv": (B,K-1,convdim), "ssm": (B·H,N,P)}."""
    s, d_inner, H, conv_dim = _ssm_dims(cfg)
    B, S, D = x.shape
    N, P, G = s.d_state, s.head_dim, s.n_groups

    h = rms_norm(x, p["norm"], cfg.norm_eps)
    xz = dense(h, p["w_xz"])
    xi, z = jnp.split(xz, 2, axis=-1)  # (B,S,d_inner) each
    bc = dense(h, p["w_bc"])  # (B,S,2GN)
    dt_raw = dense(h, p["w_dt"])  # (B,S,H)

    conv_in = jnp.concatenate([xi, bc], axis=-1)  # (B,S,convdim)
    conv_state = None if cache is None else cache["conv"]
    conv_out, new_conv_state = causal_conv1d(conv_in, p["conv_w"], conv_state)
    conv_out = silu(conv_out)
    xi = conv_out[..., :d_inner]
    Bmat, Cmat = jnp.split(conv_out[..., d_inner:], 2, axis=-1)  # (B,S,GN)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    loga = -jnp.exp(p["A_log"])[None, None, :] * dt  # (B,S,H) ≤ 0
    # Heads: xd (B,S,H,P); B/C broadcast over heads within each group.
    xh = xi.reshape(B, S, H, P)
    xd = xh * dt[..., None].astype(xh.dtype)
    heads_per_group = H // G
    Bh = jnp.repeat(Bmat.reshape(B, S, G, N), heads_per_group, axis=2)
    Ch = jnp.repeat(Cmat.reshape(B, S, G, N), heads_per_group, axis=2)

    def fold(a):  # (B,S,H,...) → (B·H,S,...)
        return a.transpose(0, 2, 1, *range(3, a.ndim)).reshape(
            B * H, S, *a.shape[3:]
        )

    xd_f, loga_f, B_f, C_f = fold(xd), fold(loga[..., None])[..., 0], fold(Bh), fold(Ch)
    h0 = None if cache is None else cache["ssm"]
    if cache is None or S > 1:
        y_f, hT = ssd_scan(xd_f, loga_f, B_f, C_f, h0, impl=ssd_impl,
                           chunk_unroll=chunk_unroll)
    else:
        hT, y_step = ssd_decode_step(
            h0, xd_f[:, 0], loga_f[:, 0], B_f[:, 0], C_f[:, 0]
        )
        y_f = y_step[:, None]
    y = y_f.reshape(B, H, S, P).transpose(0, 2, 1, 3)  # (B,S,H,P)
    y = y + xh.astype(y.dtype) * p["skip_D"][None, None, :, None]
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y * silu(z), p["out_norm"], cfg.norm_eps)
    out = x + dense(y, p["w_out"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv_state, "ssm": hT}
    return out, new_cache
