"""Model primitives: norms, rotary embeddings, initializers.

Functional style: params are nested dicts of jnp arrays; every layer is a
pure function. Weights are stored 2-D (d_in, d_out_flat) so tensor-parallel
sharding over the flattened output dim always divides the mesh (DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def dense(x, w, b=None):
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + multimodal sections).
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 1e4, sections: tuple[int, ...] = ()):
    """x: (B, H, S, D); positions: (B, S) or (B, S, len(sections)) for M-RoPE.

    With ``sections`` (Qwen2-VL M-RoPE), the half-dim frequency bands are
    split into len(sections) groups, each rotated by its own position stream
    (temporal / height / width). Text-only streams pass identical positions
    in all sections, which reduces exactly to standard RoPE.
    """
    B, H, S, D = x.shape
    half = D // 2
    inv = rope_freqs(D, theta)  # (half,)
    if sections:
        assert sum(sections) == half, (sections, half)
        assert positions.ndim == 3 and positions.shape[-1] == len(sections)
        pos_parts = []
        for i, sec in enumerate(sections):
            pos_parts.append(
                jnp.broadcast_to(positions[..., i : i + 1], (B, S, sec))
            )
        pos = jnp.concatenate(pos_parts, axis=-1)  # (B, S, half)
        ang = pos.astype(jnp.float32) * inv[None, None, :]
    else:
        if positions.ndim == 3:
            positions = positions[..., 0]
        ang = positions[..., None].astype(jnp.float32) * inv[None, None, :]
    cos = jnp.cos(ang)[:, None]  # (B, 1, S, half)
    sin = jnp.sin(ang)[:, None]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(dt)


# ---------------------------------------------------------------------------
# Initializers.
# ---------------------------------------------------------------------------

def winit(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = (fan_in ** -0.5) if scale is None else scale
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def zinit(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def causal_conv1d(x, w, state=None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C). Returns (y, new_state).

    ``state`` is the last K−1 inputs from the previous segment (B, K−1, C);
    None means zero history (segment start).
    """
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, S+K-1, C)
    y = sum(
        xp[:, i : i + S, :] * w[i][None, None, :].astype(x.dtype) for i in range(K)
    )
    new_state = xp[:, S:, :] if K > 1 else state
    return y, new_state
