"""Language-model assembly for all assigned architecture families.

Families (config-driven, one ``LM`` class):
  dense   — GQA transformer (command-r / minicpm / granite / gemma3 pattern)
  moe     — dense attention + routed-expert FFN (qwen3-moe / deepseek-moe)
  ssm     — pure Mamba-2 stack (mamba2-2.7b)
  hybrid  — Mamba-2 stack with a *shared* attention block every k layers
            (zamba2: the same attention params are reused at every insertion)
  audio   — whisper-style encoder-decoder; conv frontend is a stub (inputs
            are precomputed frame embeddings, per the assignment spec)
  vlm     — qwen2-vl backbone: M-RoPE, patch embeddings occupy the first
            n_patch positions (patch frontend stubbed likewise)

All homogeneous stacks scan over stacked layer params (compile time —
and HLO size — independent of depth). Patterned stacks (gemma3 5:1
local:global) scan over stacked *periods*; remainder layers get their own
params. Decode caches are pytrees threaded through the same scans.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeCfg
from .blocks import attn_apply, attn_init, mamba_apply, mamba_init, moe_apply, moe_init
from .layers import rms_norm, winit, zinit

Params = dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _layer_pattern(cfg: ModelConfig) -> tuple[list[bool], int, list[bool]]:
    """Returns (period pattern of is_local flags, n_periods, remainder flags)."""
    if cfg.pattern_local:
        period = [True] * cfg.pattern_local + [False] * cfg.pattern_global
        n = cfg.num_layers // len(period)
        rem_len = cfg.num_layers - n * len(period)
        rem = period[:rem_len]
        return period, n, rem
    return [False], cfg.num_layers, []


@dataclass(frozen=True)
class LM:
    cfg: ModelConfig
    attn_impl: str = "pallas"
    ssd_impl: str = "pallas"
    remat: bool = False           # checkpoint each scanned block in backward
    unroll: bool = False          # python-loop layers (cost calibration)
    act_pspec: tuple | None = None  # activation sharding constraint (see
    # parallel/sharding.py) applied between scanned blocks — requires an
    # active mesh context (dryrun/train use `with mesh:`)

    def _maybe_remat(self, fn):
        return jax.checkpoint(fn, prevent_cse=False) if self.remat else fn

    def _scan(self, body, carry, xs):
        """lax.scan, or an unrolled python loop when ``unroll=True``.

        The unrolled form exists for dry-run cost calibration: XLA's
        HloCostAnalysis counts while-loop bodies once regardless of trip
        count, so per-layer costs are measured from unrolled depth-1/-2
        variants and extrapolated (launch/dryrun.py).
        """
        if not self.unroll:
            return jax.lax.scan(body, carry, xs)
        n = jax.tree.leaves(xs)[0].shape[0]
        ys = []
        for i in range(n):
            x_i = jax.tree.map(lambda a: a[i], xs)
            carry, y = body(carry, x_i)
            ys.append(y)
        if ys and ys[0] is not None:
            ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
        else:
            ys = None
        return carry, ys

    def _constrain(self, x):
        if self.act_pspec is None:
            return x
        from jax.sharding import PartitionSpec as P

        return jax.lax.with_sharding_constraint(x, P(*self.act_pspec))

    # ------------------------------------------------------------------ init
    def init(self, key) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = iter(jax.random.split(key, 64 + 4 * cfg.num_layers))
        p: Params = {
            "embed": winit(next(keys), (cfg.vocab_size, cfg.d_model), scale=0.02),
            "final_norm": zinit((cfg.d_model,)),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = winit(next(keys), (cfg.d_model, cfg.vocab_size))

        def stack(init_fn, n):
            ks = jnp.stack([jax.random.fold_in(next(keys), i) for i in range(n)])
            return jax.vmap(init_fn)(ks)

        fam = cfg.family
        if fam in ("dense", "moe", "vlm"):
            period, n_periods, rem = _layer_pattern(cfg)
            if fam == "moe":
                layer_init = lambda k: {  # noqa: E731
                    "attn": attn_init(k, cfg, with_mlp=False),
                    "moe": moe_init(jax.random.fold_in(k, 1), cfg),
                }
            else:
                layer_init = lambda k: {"attn": attn_init(k, cfg)}  # noqa: E731

            def period_init(k):
                return [
                    layer_init(jax.random.fold_in(k, i)) for i in range(len(period))
                ]

            p["periods"] = stack(period_init, n_periods)
            if rem:
                p["remainder"] = [layer_init(next(keys)) for _ in rem]
        elif fam == "ssm":
            p["layers"] = stack(lambda k: mamba_init(k, cfg), cfg.num_layers)
        elif fam == "hybrid":
            k_grp = cfg.attn_every
            n_groups = cfg.num_layers // k_grp
            rem_n = cfg.num_layers - n_groups * k_grp

            def group_init(k):
                return [
                    mamba_init(jax.random.fold_in(k, i), cfg) for i in range(k_grp)
                ]

            p["groups"] = stack(group_init, n_groups)
            p["shared_attn"] = attn_init(next(keys), cfg)  # ONE set of params
            if rem_n:
                p["remainder"] = [mamba_init(next(keys), cfg) for _ in range(rem_n)]
        elif fam == "audio":
            p["enc_layers"] = stack(
                lambda k: attn_init(k, cfg), cfg.encoder_layers
            )
            p["enc_norm"] = zinit((cfg.d_model,))

            def dec_init(k):
                ks = jax.random.split(k, 2)
                return {
                    "self": attn_init(ks[0], cfg, with_mlp=False),
                    "cross": attn_init(ks[1], cfg, with_mlp=True),
                }

            p["dec_layers"] = stack(dec_init, cfg.num_layers)
        else:
            raise ValueError(f"unknown family {fam}")
        return jax.tree.map(lambda a: a.astype(dt), p)

    # ------------------------------------------------------------- forward
    def _backbone(self, p, x, positions, caches=None):
        """Shared decoder trunk. caches=None → full-sequence forward."""
        cfg = self.cfg
        fam = cfg.family
        decode = caches is not None
        new_caches: Params = {}

        def run_attn(lp, x, cache, local: bool):
            ap = lp["attn"] if "attn" in lp else lp
            return attn_apply(
                ap,
                x,
                cfg=cfg,
                positions=positions,
                causal=True,
                window=cfg.window if local else None,
                cache=cache,
                attn_impl=self.attn_impl,
                with_mlp="norm2" in ap,
                chunk_unroll=self.unroll,
            )

        if fam in ("dense", "moe", "vlm"):
            period, n_periods, rem = _layer_pattern(cfg)

            def apply_layer(lp, x, cache, local):
                if fam == "moe":
                    x, nc = run_attn(lp, x, cache, local)
                    x, stats = moe_apply(lp["moe"], x, cfg=cfg)
                    return x, nc, stats
                x, nc = run_attn(lp, x, cache, local)
                return x, nc, None

            def period_body(carry, scanned):
                x, aux = carry
                lps, lcs = scanned
                ncs = []
                for i, local in enumerate(period):
                    x, nc, stats = apply_layer(
                        lps[i], x, None if lcs is None else lcs[i], local
                    )
                    ncs.append(nc)
                    if stats is not None:
                        aux = {
                            "aux_loss": aux["aux_loss"] + stats["aux_loss"],
                            "expert_load": aux["expert_load"] + stats["expert_load"],
                        }
                return (x, aux), ncs if decode else None

            aux0 = {
                "aux_loss": jnp.zeros((), jnp.float32),
                "expert_load": jnp.zeros(
                    (cfg.moe.num_experts if cfg.moe else 1,), jnp.float32
                ),
            }
            scanned = (
                (p["periods"], caches["periods"]) if decode
                else (p["periods"], None)
            )
            if decode:
                (x, aux), new_period_caches = self._scan(
                    lambda c, s: period_body(c, s), (x, aux0), scanned
                )
                new_caches["periods"] = new_period_caches
            else:
                def train_period(c, lps):
                    (x, aux), _ = period_body(c, (lps, None))
                    return (self._constrain(x), aux), None

                (x, aux), _ = self._scan(
                    self._maybe_remat(train_period), (x, aux0), p["periods"]
                )
            for i, local in enumerate(rem):
                cache = caches["remainder"][i] if decode else None
                x, nc, stats = apply_layer(p["remainder"][i], x, cache, local)
                if decode:
                    new_caches.setdefault("remainder", []).append(nc)
                if stats is not None:
                    aux = {
                        "aux_loss": aux["aux_loss"] + stats["aux_loss"],
                        "expert_load": aux["expert_load"] + stats["expert_load"],
                    }
            return x, aux, new_caches

        if fam == "ssm":
            def body(carry, scanned):
                x = carry
                lp, lc = scanned if decode else (scanned, None)
                x, nc = mamba_apply(lp, x, cfg=cfg, cache=lc, ssd_impl=self.ssd_impl)
                return x, nc if decode else None

            if decode:
                x, ncs = self._scan(body, x, (p["layers"], caches["layers"]))
                new_caches["layers"] = ncs
            else:
                def train_body(x, lp):
                    x, _ = body(x, lp)
                    return self._constrain(x), None

                x, _ = self._scan(self._maybe_remat(train_body), x, p["layers"])
            return x, {}, new_caches

        if fam == "hybrid":
            k_grp = cfg.attn_every
            shared = p["shared_attn"]

            def group_body(carry, scanned):
                x = carry
                lps, lcs = scanned
                m_ncs, a_nc = [], None
                for i in range(k_grp):
                    x, nc = mamba_apply(
                        lps[i], x, cfg=cfg,
                        cache=None if lcs is None else lcs["mamba"][i],
                        ssd_impl=self.ssd_impl,
                        chunk_unroll=self.unroll,
                    )
                    m_ncs.append(nc)
                x, a_nc = run_attn(
                    {"attn": shared}, x,
                    None if lcs is None else lcs["attn"], False,
                )
                out = {"mamba": m_ncs, "attn": a_nc} if decode else None
                return x, out

            if decode:
                x, ncs = self._scan(
                    group_body, x, (p["groups"], caches["groups"])
                )
                new_caches["groups"] = ncs
            else:
                def train_group(x, lps):
                    x, _ = group_body(x, (lps, None))
                    return self._constrain(x), None

                x, _ = self._scan(self._maybe_remat(train_group), x, p["groups"])
            rem = p.get("remainder", [])
            for i, lp in enumerate(rem):
                lc = caches["remainder"][i] if decode else None
                x, nc = mamba_apply(lp, x, cfg=cfg, cache=lc, ssd_impl=self.ssd_impl)
                if decode:
                    new_caches.setdefault("remainder", []).append(nc)
            return x, {}, new_caches

        raise ValueError(f"_backbone does not handle family {fam}")

    def encode(self, p, frames):
        """Audio encoder (whisper): frames (B, S_enc, D) → (B, S_enc, D)."""
        cfg = self.cfg
        positions = jnp.broadcast_to(
            jnp.arange(frames.shape[1])[None], frames.shape[:2]
        )

        def body(x, lp):
            x, _ = attn_apply(
                lp, x, cfg=cfg, positions=positions, causal=False,
                attn_impl=self.attn_impl, chunk_unroll=self.unroll,
            )
            return x, None

        x, _ = self._scan(body, frames.astype(_dtype(cfg)), p["enc_layers"])
        return rms_norm(x, p["enc_norm"], cfg.norm_eps)

    def _decoder_audio(self, p, x, enc_out, positions, caches=None):
        cfg = self.cfg
        decode = caches is not None
        new_caches: Params = {}

        def body(carry, scanned):
            x = carry
            lp, lc = scanned
            x, self_nc = attn_apply(
                lp["self"], x, cfg=cfg, positions=positions, causal=True,
                cache=None if lc is None else lc["self"],
                attn_impl=self.attn_impl, with_mlp=False,
                chunk_unroll=self.unroll,
            )
            x, cross_nc = attn_apply(
                lp["cross"], x, cfg=cfg, positions=positions, causal=False,
                cache=None if lc is None else lc["cross"],
                attn_impl=self.attn_impl, kv_override=(enc_out, enc_out),
                chunk_unroll=self.unroll,
            )
            return x, ({"self": self_nc, "cross": cross_nc} if decode else None)

        if decode:
            x, ncs = self._scan(body, x, (p["dec_layers"], caches["dec_layers"]))
            new_caches["dec_layers"] = ncs
        else:
            def train_dec(c, lp):
                x, _ = body(c, (lp, None))
                return self._constrain(x), None

            x, _ = self._scan(self._maybe_remat(train_dec), x, p["dec_layers"])
        return x, new_caches

    def _logits(self, p, x):
        cfg = self.cfg
        x = rms_norm(x, p["final_norm"], cfg.norm_eps)
        head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
        return (x @ head.astype(x.dtype)).astype(jnp.float32)

    def apply(self, p: Params, batch: dict) -> dict:
        """Full-sequence forward: returns {"logits", "aux_loss", ...}."""
        cfg = self.cfg
        dt = _dtype(cfg)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = p["embed"].astype(dt)[tokens]
        if cfg.family == "vlm" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(dt)
            n_patch = min(pe.shape[1], S)
            x = jax.lax.dynamic_update_slice(x, pe[:, :n_patch], (0, 0, 0))
        if cfg.mrope_sections:
            positions = batch.get("positions")
            if positions is None:
                base = jnp.arange(S)[None].repeat(B, 0)
                positions = jnp.stack([base] * len(cfg.mrope_sections), axis=-1)
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        if cfg.family == "audio":
            enc_out = self.encode(p, batch["frames"])
            x, _ = self._decoder_audio(p, x, enc_out, positions)
            aux = {}
        else:
            x, aux, _ = self._backbone(p, x, positions)
        out = {"logits": self._logits(p, x)}
        out.update(aux)
        return out

    def loss(self, p: Params, batch: dict):
        out = self.apply(p, batch)
        logits = out["logits"]
        tokens = batch["tokens"]
        tgt = tokens[:, 1:]
        lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask")
        if mask is not None:
            nll = nll * mask[:, 1:]
            denom = jnp.maximum(mask[:, 1:].sum(), 1.0)
        else:
            denom = nll.size
        loss = nll.sum() / denom
        if "aux_loss" in out:
            loss = loss + out["aux_loss"]
        metrics = {"ce": nll.sum() / denom}
        if self.cfg.moe is not None and "expert_load" in out:
            metrics["expert_load"] = out["expert_load"]
        return loss, metrics

    # -------------------------------------------------------------- decode
    def init_cache(self, p: Params, batch_size: int, max_len: int,
                   enc_out=None) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg)
        dh = cfg.resolved_head_dim
        Hkv = cfg.num_kv_heads

        def kv(length):
            return {
                "k": jnp.zeros((batch_size, Hkv, length, dh), dt),
                "v": jnp.zeros((batch_size, Hkv, length, dh), dt),
                "pos": jnp.zeros((), jnp.int32),
            }

        def ssm_cache():
            s = cfg.ssm
            d_inner = s.expand * cfg.d_model
            H = d_inner // s.head_dim
            conv_dim = d_inner + 2 * s.n_groups * s.d_state
            return {
                "conv": jnp.zeros((batch_size, s.conv_width - 1, conv_dim), dt),
                "ssm": jnp.zeros(
                    (batch_size * H, s.d_state, s.head_dim), jnp.float32
                ),
            }

        fam = cfg.family
        if fam in ("dense", "moe", "vlm"):
            period, n_periods, rem = _layer_pattern(cfg)

            def layer_len(local):  # local layers only need a window-size cache
                if local and cfg.window:
                    return min(cfg.window, max_len)
                return max_len

            periods = [
                jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (n_periods,) + a.shape),
                    kv(layer_len(local)),
                )
                for local in period
            ]
            caches = {"periods": periods}
            if rem:
                caches["remainder"] = [kv(layer_len(local)) for local in rem]
            return caches
        if fam == "ssm":
            L = cfg.num_layers
            return {
                "layers": jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (L,) + a.shape), ssm_cache()
                )
            }
        if fam == "hybrid":
            n_groups = cfg.num_layers // cfg.attn_every
            rem_n = cfg.num_layers - n_groups * cfg.attn_every
            group = {
                "mamba": [ssm_cache() for _ in range(cfg.attn_every)],
                "attn": kv(max_len),
            }
            caches = {
                "groups": jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), group
                )
            }
            if rem_n:
                caches["remainder"] = [ssm_cache() for _ in range(rem_n)]
            return caches
        if fam == "audio":
            assert enc_out is not None, "audio decode cache needs encoder output"
            L = cfg.num_layers

            def dec_cache():
                # Cross K/V are recomputed from enc_out per step (see
                # blocks.attn_apply); this entry is a structural placeholder.
                return {"self": kv(max_len), "cross": kv(8)}

            caches = {
                "dec_layers": jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (L,) + a.shape), dec_cache()
                ),
                "enc_out": enc_out,
            }
            return caches
        raise ValueError(fam)

    def decode_step(self, p: Params, caches: Params, token):
        """token: (B, 1) int32 → (logits (B, 1, V), new caches)."""
        cfg = self.cfg
        dt = _dtype(cfg)
        x = p["embed"].astype(dt)[token]
        positions = None  # per-layer code uses cache["pos"]
        if cfg.family == "audio":
            enc_out = caches["enc_out"]
            x, new_caches = self._decoder_audio(
                p, x, enc_out, positions, caches=caches
            )
            new_caches["enc_out"] = enc_out
        else:
            x, _, new_caches = self._backbone(p, x, positions, caches=caches)
        return self._logits(p, x), new_caches

    def param_count(self, p: Params) -> int:
        return sum(a.size for a in jax.tree.leaves(p))
