"""Sharded checkpointing: atomic, async, resharding-aware, CRC-verified.

Layout (one directory per step):
    <dir>/step_000042/
        manifest.json     # step, tree structure, shapes/dtypes, crc32s,
                          # mesh shape, PRNG key, data-iterator state
        arr_<n>.npy       # one file per leaf (process-local full arrays)
        _COMMITTED        # written last — marks the checkpoint atomic

Restore accepts a *different* mesh (elastic scaling): arrays are loaded
full and re-placed with the new shardings via device_put.
"""

from __future__ import annotations

import json
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _leaves_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return flat, treedef


def save_checkpoint(
    directory: str | Path,
    step: int,
    tree: Any,
    *,
    extra: dict | None = None,
    keep: int = 3,
    async_: bool = False,
) -> Path | threading.Thread:
    """Write checkpoint; returns final path (or the thread if async)."""
    directory = Path(directory)
    host_tree = jax.tree.map(np.asarray, tree)  # device → host copy (sync)

    def _write() -> Path:
        tmp = directory / f".tmp_step_{step:09d}"
        final = directory / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat, _ = _leaves_with_paths(host_tree)
        manifest = {"step": step, "extra": extra or {}, "leaves": []}
        for i, (path, leaf) in enumerate(flat):
            fname = f"arr_{i}.npy"
            np.save(tmp / fname, leaf)
            manifest["leaves"].append(
                {
                    "path": jax.tree_util.keystr(path),
                    "file": fname,
                    "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                    "crc32": zlib.crc32(np.ascontiguousarray(leaf).tobytes()),
                }
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        (tmp / "_COMMITTED").write_text("ok")
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        _gc(directory, keep)
        return final

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    return _write()


def _gc(directory: Path, keep: int) -> None:
    ckpts = sorted(d for d in directory.glob("step_*") if d.is_dir())
    for d in ckpts[:-keep]:
        shutil.rmtree(d, ignore_errors=True)


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    steps = [
        int(d.name.split("_")[1])
        for d in directory.glob("step_*")
        if (d / "_COMMITTED").exists()
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str | Path,
    like: Any,
    *,
    step: int | None = None,
    shardings: Any = None,
    verify: bool = True,
) -> tuple[Any, dict]:
    """Load into the structure of ``like``; optional resharding placement.

    Returns (tree, extra). Raises FileNotFoundError if no committed
    checkpoint exists.
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory}")
    final = directory / f"step_{step:09d}"
    manifest = json.loads((final / "manifest.json").read_text())
    flat_like, treedef = _leaves_with_paths(like)
    if len(flat_like) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"expected {len(flat_like)}"
        )
    leaves = []
    for (path, leaf_like), rec in zip(flat_like, manifest["leaves"]):
        if jax.tree_util.keystr(path) != rec["path"]:
            raise ValueError(f"leaf mismatch: {rec['path']} vs {path}")
        arr = np.load(final / rec["file"])
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != rec["crc32"]:
                raise IOError(f"crc mismatch in {rec['file']} (corrupt ckpt)")
        if tuple(arr.shape) != tuple(leaf_like.shape):
            raise ValueError(
                f"shape mismatch at {rec['path']}: {arr.shape} vs "
                f"{leaf_like.shape}"
            )
        leaves.append(arr.astype(leaf_like.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, manifest["extra"]
