"""Fault-tolerant training loop with OCS-fabric scheduling integration.

Production behaviors, testable on one host:
  * checkpoint/restart — restores (params, opt state, step); the data
    pipeline is stateless-resumable, so a crash + restore replays the exact
    remaining schedule (bit-identical on CPU f32; verified in tests).
  * failure injection — any callable raising ``SimulatedFailure`` at chosen
    steps; the loop restores from the last committed checkpoint and
    continues, counting restarts (restart budget guards infinite crash
    loops).
  * straggler watchdog — per-step wall-time EMA + z-score detection; slow
    steps fire a remap hook (at scale: re-shard/evict; here: logged +
    counted).
  * OCS integration (the paper as a first-class feature) — every
    ``ocs_every`` steps the loop builds the rack-level demand matrix from
    the parallelism plan (+ measured MoE expert loads when present) and
    schedules it with SPECTRA on the configured fabric, logging the CCT the
    optical core would need. This is the controller loop of Fig. 1.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from ..data.pipeline import TokenStream
from ..fabric.ocs import OCSFabric
from ..traffic.collectives import Placement, TrafficModel
from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .optimizer import AdamW


class SimulatedFailure(RuntimeError):
    """Raised by failure injectors to simulate a node crash."""


@dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    max_restarts: int = 8
    straggler_zscore: float = 4.0
    straggler_warmup: int = 5  # ignore compile-dominated early steps
    ocs_every: int = 0  # 0 → disabled
    ocs_num_racks: int = 8


@dataclass
class LoopState:
    params: Any
    opt_state: Any
    step: int = 0
    restarts: int = 0
    stragglers: int = 0
    history: list = field(default_factory=list)
    cct_log: list = field(default_factory=list)


def _demand_from_stats(
    num_racks: int, metrics: dict, step: int
) -> np.ndarray | None:
    """Rack demand from measured expert loads (MoE) or DP-ring defaults."""
    tm = TrafficModel(Placement(num_racks, 1))
    load = metrics.get("expert_load")
    if load is not None:
        load = np.asarray(load, dtype=np.float64)
        if load.sum() <= 0:
            return None
        # Experts → racks round-robin; tokens to expert e land on its rack.
        per_rack = np.zeros(num_racks)
        for e, cnt in enumerate(load):
            per_rack[e % num_racks] += float(cnt)
        # All-to-all: every source rack sends proportionally to expert racks.
        D = np.outer(np.full(num_racks, 1.0 / num_racks), per_rack)
        np.fill_diagonal(D, 0.0)
        return D
    # Dense model: DP gradient ring across racks.
    tm.ring_allreduce(list(range(num_racks)), 1.0)
    return tm.demand_bytes


class Trainer:
    def __init__(
        self,
        model,
        optimizer: AdamW,
        stream: TokenStream,
        train_step: Callable,
        cfg: LoopConfig,
        *,
        fabric: OCSFabric | None = None,
        failure_injector: Callable[[int], None] | None = None,
        remap_hook: Callable[[int, float], None] | None = None,
    ):
        self.model = model
        self.optimizer = optimizer
        self.stream = stream
        self.train_step = train_step
        self.cfg = cfg
        self.fabric = fabric
        self.failure_injector = failure_injector
        self.remap_hook = remap_hook

    # -------------------------------------------------------------- state
    def init_state(self, rng_key) -> LoopState:
        params = self.model.init(rng_key)
        opt_state = self.optimizer.init(params)
        return LoopState(params=params, opt_state=opt_state)

    def _try_restore(self, state: LoopState) -> LoopState:
        if not self.cfg.ckpt_dir or latest_step(self.cfg.ckpt_dir) is None:
            return state
        tree = {"params": state.params, "opt": state.opt_state}
        restored, extra = restore_checkpoint(self.cfg.ckpt_dir, tree)
        state.params = restored["params"]
        state.opt_state = restored["opt"]
        state.step = int(extra["step"])
        return state

    def _save(self, state: LoopState, async_: bool = False):
        if not self.cfg.ckpt_dir:
            return
        save_checkpoint(
            self.cfg.ckpt_dir,
            state.step,
            {"params": state.params, "opt": state.opt_state},
            extra={"step": state.step, "data": self.stream.state(state.step)},
            keep=self.cfg.ckpt_keep,
            async_=async_,
        )

    # ---------------------------------------------------------------- run
    def run(self, rng_key) -> LoopState:
        state = self._try_restore(self.init_state(rng_key))
        ema_t, ema_v = None, 0.0
        while state.step < self.cfg.total_steps:
            step = state.step
            batch = self.stream.next_batch(step)
            t0 = time.perf_counter()
            try:
                if self.failure_injector is not None:
                    self.failure_injector(step)
                params, opt_state, metrics = self.train_step(
                    state.params, state.opt_state, batch
                )
                jax.block_until_ready(metrics["loss"])
            except SimulatedFailure:
                state.restarts += 1
                if state.restarts > self.cfg.max_restarts:
                    raise
                # Crash: lose in-flight state, restore last committed ckpt.
                fresh = self.init_state(rng_key)
                state_r = self._try_restore(fresh)
                state_r.restarts = state.restarts
                state_r.stragglers = state.stragglers
                state_r.history = state.history
                state_r.cct_log = state.cct_log
                state = state_r
                continue
            dt = time.perf_counter() - t0
            # Straggler watchdog (EMA + variance z-score), after a warmup
            # window so compile-time outliers don't inflate the baseline.
            if step < self.cfg.straggler_warmup:
                pass
            elif ema_t is None:
                ema_t, ema_v = dt, 0.0
            else:
                # Variance floor of 0.25·ema: a straggler must be ≥ ~2× the
                # typical step before variance statistics are established.
                z = (dt - ema_t) / max(np.sqrt(ema_v), 0.25 * ema_t, 1e-9)
                if z > self.cfg.straggler_zscore:
                    state.stragglers += 1
                    if self.remap_hook:
                        self.remap_hook(step, dt)
                ema_v = 0.9 * ema_v + 0.1 * (dt - ema_t) ** 2
                ema_t = 0.9 * ema_t + 0.1 * dt
            state.params, state.opt_state = params, opt_state
            state.step = step + 1
            if step % self.cfg.log_every == 0 or state.step == self.cfg.total_steps:
                state.history.append(
                    {"step": step, "loss": float(metrics["loss"]), "time_s": dt}
                )
            # OCS controller tick: schedule this period's demand matrix.
            if (
                self.fabric is not None
                and self.cfg.ocs_every
                and state.step % self.cfg.ocs_every == 0
            ):
                D = _demand_from_stats(self.cfg.ocs_num_racks, metrics, step)
                if D is not None and D.max() > 0:
                    res, cct = self.fabric.schedule_bytes(D * 1e9)
                    state.cct_log.append(
                        {
                            "step": step,
                            "cct_s": cct,
                            "makespan": res.makespan,
                            "lb": res.lower_bound,
                            "configs": res.schedule.num_configs(),
                        }
                    )
            if self.cfg.ckpt_dir and state.step % self.cfg.ckpt_every == 0:
                self._save(state)
        if self.cfg.ckpt_dir:
            self._save(state)
        return state
