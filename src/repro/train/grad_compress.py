"""Gradient compression with error feedback (distributed-optimization trick).

Top-k sparsification per leaf with an error-feedback accumulator: the
residual of the compressed gradient is carried into the next step, which
preserves convergence (Stich et al.; 1-bit Adam lineage). At scale this
shrinks the DP all-reduce payload by ~(1 − k/n); the OCS fabric scheduler
sees correspondingly smaller DP demand entries.

Pure pytree functions so they compose with any optimizer.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_topk(grads: Any, error: Any, frac: float = 0.05):
    """Returns (compressed grads, new error). Keeps top-frac |g| per leaf."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        flat = g.reshape(-1)
        k = max(1, int(flat.size * frac))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = jnp.abs(g) >= thresh
        sent = jnp.where(mask, g, 0.0)
        return sent, g - sent

    out = jax.tree.map(one, grads, error)
    sent = jax.tree.map(lambda x: x[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda x: x[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return sent, new_err


def compression_ratio(grads: Any, frac: float = 0.05) -> float:
    """Payload ratio vs dense all-reduce (values + indices, fp32+int32)."""
    total = sum(g.size for g in jax.tree.leaves(grads))
    kept = sum(max(1, int(g.size * frac)) for g in jax.tree.leaves(grads))
    return (kept * 8) / (total * 4)
