"""Fault-tolerance utilities: failure injection, heartbeats, elastic meshes."""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import jax

from .loop import SimulatedFailure


def fail_at(steps: set[int]):
    """Failure injector that crashes once at each step in ``steps``."""
    fired: set[int] = set()

    def inject(step: int):
        if step in steps and step not in fired:
            fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")

    return inject


@dataclass
class Heartbeat:
    """Worker-liveness monitor (thread-based single-host simulation).

    Workers ping; a monitor thread marks any worker silent for
    ``timeout_s`` as dead and invokes the callback (at scale: trigger
    checkpoint-restore with a shrunken mesh — see ``largest_mesh``).
    """

    num_workers: int
    timeout_s: float = 1.0
    last_seen: dict = field(default_factory=dict)
    dead: set = field(default_factory=set)
    _stop: bool = False

    def ping(self, worker: int):
        self.last_seen[worker] = time.monotonic()

    def check(self) -> set:
        now = time.monotonic()
        for w in range(self.num_workers):
            seen = self.last_seen.get(w)
            if seen is not None and now - seen > self.timeout_s:
                self.dead.add(w)
        return self.dead

    def watch(self, on_dead, poll_s: float = 0.05):
        def loop():
            while not self._stop:
                dead = self.check()
                if dead:
                    on_dead(dead)
                    return
                time.sleep(poll_s)

        t = threading.Thread(target=loop, daemon=True)
        t.start()
        return t

    def stop(self):
        self._stop = True


def largest_mesh(n_devices: int, prefer_model: int = 16):
    """Elastic re-mesh: biggest (data × model) grid ≤ n_devices.

    Keeps the model axis as close to ``prefer_model`` as divisibility
    allows, shrinking data parallelism first (the cheap direction: only
    the per-device batch changes, parameters reshard along data only).
    """
    model = min(prefer_model, n_devices)
    while model > 1 and n_devices % model:
        model //= 2
    data = n_devices // model
    return (data, model)
