"""Optimizers + LR schedules (pure pytree functions, no external deps).

AdamW with decoupled weight decay and global-norm clipping; schedules
include WSD (warmup–stable–decay, the MiniCPM schedule) and cosine.
Optimizer state mirrors the param pytree, so the same sharding rules apply
(ZeRO-style: states are sharded exactly like their params).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


def warmup_stable_decay(
    peak_lr: float, total_steps: int, warmup: float = 0.01, decay: float = 0.1,
    floor: float = 0.1,
) -> Callable:
    """WSD: linear warmup → constant → linear decay to floor·peak."""
    w = max(int(total_steps * warmup), 1)
    d = max(int(total_steps * decay), 1)

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / w, 1.0)
        decay_start = total_steps - d
        frac = jnp.clip((step - decay_start) / d, 0.0, 1.0)
        return jnp.where(
            step < decay_start, warm, peak_lr * (1.0 - (1.0 - floor) * frac)
        )

    return lr


def cosine_schedule(peak_lr: float, total_steps: int, warmup: float = 0.01,
                    floor: float = 0.1) -> Callable:
    w = max(int(total_steps * warmup), 1)

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / w, 1.0)
        t = jnp.clip((step - w) / jnp.maximum(total_steps - w, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < w, warm, peak_lr * cos)

    return lr


@dataclass(frozen=True)
class AdamW:
    schedule: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0

    def init(self, params: Params) -> dict:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads: Params, state: dict, params: Params):
        step = state["step"] + 1
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["mu"], grads,
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads,
        )
        lr = self.schedule(step)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "step": step}


def global_norm(tree: Params):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )
