"""Config module for --arch deepseek-moe-16b (exact dims in registry.py)."""

from .registry import ARCHS

CONFIG = ARCHS["deepseek-moe-16b"]
REDUCED = CONFIG.reduced()
