"""Config module for --arch qwen3-moe-30b-a3b (exact dims in registry.py)."""

from .registry import ARCHS

CONFIG = ARCHS["qwen3-moe-30b-a3b"]
REDUCED = CONFIG.reduced()
