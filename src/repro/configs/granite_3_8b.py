"""Config module for --arch granite-3-8b (exact dims in registry.py)."""

from .registry import ARCHS

CONFIG = ARCHS["granite-3-8b"]
REDUCED = CONFIG.reduced()
