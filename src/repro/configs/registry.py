"""The 10 assigned architectures (exact dims from the assignment) + shapes.

Sources per the assignment block; `head_dim` choices follow the public
configs where the assignment leaves them implicit.
"""

from __future__ import annotations

from .base import SHAPES, ModelConfig, MoECfg, SSMCfg, ShapeCfg

ARCHS: dict[str, ModelConfig] = {}


def _reg(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# — hybrid: Mamba2 + shared attention blocks [arXiv:2411.15242] —
_reg(ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm=SSMCfg(d_state=64, head_dim=64, expand=2, n_groups=1),
    attn_every=6,  # shared attn block after every 6 Mamba2 layers
))

# — dense GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01] —
_reg(ModelConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22528, vocab_size=256000,
))

# — dense, WSD schedule, llama-like [arXiv:2404.06395] —
_reg(ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36, head_dim=64,
    d_ff=5760, vocab_size=122753,
))

# — dense, 5:1 local:global sliding window, 128k [hf:google/gemma-3] —
_reg(ModelConfig(
    name="gemma3-27b", family="dense",
    num_layers=62, d_model=5376, num_heads=32, num_kv_heads=16, head_dim=128,
    d_ff=21504, vocab_size=262144,
    window=1024, pattern_local=5, pattern_global=1,
))

# — dense GQA [hf:ibm-granite/granite-3.0] —
_reg(ModelConfig(
    name="granite-3-8b", family="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=12800, vocab_size=49155,
))

# — audio enc-dec, conv frontend stubbed [arXiv:2212.04356] —
_reg(ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, encoder_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    head_dim=64, d_ff=1536, vocab_size=51865,
))

# — MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B] —
_reg(ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936,
    moe=MoECfg(num_experts=128, top_k=8, d_ff_expert=768),
))

# — MoE 2 shared + 64 routed top-6, fine-grained [arXiv:2401.06066] —
_reg(ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400,
    moe=MoECfg(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2),
))

# — VLM backbone, M-RoPE, patch frontend stubbed [arXiv:2409.12191] —
_reg(ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    mrope_sections=(16, 24, 24),
))

# — pure SSM (SSD) [arXiv:2405.21060] —
_reg(ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=1, num_kv_heads=1, head_dim=64,
    d_ff=0, vocab_size=50280,
    ssm=SSMCfg(d_state=128, head_dim=64, expand=2, n_groups=1),
))

# Sub-quadratic archs eligible for the long_500k decode cell (DESIGN.md §6).
LONG_CONTEXT_OK = {"zamba2-1.2b", "mamba2-2.7b", "gemma3-27b"}
# Cells skipped: long_500k × pure full-attention archs (+ whisper audio).
SKIPPED_CELLS = {
    (a, "long_500k")
    for a in ARCHS
    if a not in LONG_CONTEXT_OK
}


def get_arch(name: str) -> ModelConfig:
    return ARCHS[name]


def get_shape(name: str) -> ShapeCfg:
    return SHAPES[name]


def all_cells():
    """All (arch, shape) dry-run cells, with skip markers."""
    for arch in ARCHS:
        for shape in SHAPES:
            yield arch, shape, (arch, shape) in SKIPPED_CELLS
