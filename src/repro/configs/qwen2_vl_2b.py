"""Config module for --arch qwen2-vl-2b (exact dims in registry.py)."""

from .registry import ARCHS

CONFIG = ARCHS["qwen2-vl-2b"]
REDUCED = CONFIG.reduced()
