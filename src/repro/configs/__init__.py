"""Architecture configs: one module per assigned arch + the registry."""

from .base import SHAPES, ModelConfig, MoECfg, SSMCfg, ShapeCfg
from .registry import ARCHS, LONG_CONTEXT_OK, SKIPPED_CELLS, all_cells, get_arch, get_shape

__all__ = [
    "ARCHS", "LONG_CONTEXT_OK", "SHAPES", "SKIPPED_CELLS", "ModelConfig",
    "MoECfg", "SSMCfg", "ShapeCfg", "all_cells", "get_arch", "get_shape",
]
