"""Config module for --arch whisper-tiny (exact dims in registry.py)."""

from .registry import ARCHS

CONFIG = ARCHS["whisper-tiny"]
REDUCED = CONFIG.reduced()
