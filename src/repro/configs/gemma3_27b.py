"""Config module for --arch gemma3-27b (exact dims in registry.py)."""

from .registry import ARCHS

CONFIG = ARCHS["gemma3-27b"]
REDUCED = CONFIG.reduced()
