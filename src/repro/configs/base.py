"""Config system: model architecture + input-shape cells."""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class SSMCfg:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // num_heads
    # Attention pattern: window size for local layers; pattern gives the
    # repeating local:global structure (e.g. gemma3 = 5 local + 1 global).
    window: int | None = None
    pattern_local: int = 0  # local layers per period (0 → all global/full)
    pattern_global: int = 1
    rope_theta: float = 1e4
    mrope_sections: tuple[int, ...] = ()
    # MoE / SSM / hybrid extras
    moe: MoECfg | None = None
    ssm: SSMCfg | None = None
    attn_every: int = 0  # hybrid: shared attn block after every k SSM layers
    # Encoder-decoder (audio)
    encoder_layers: int = 0
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            num_layers=min(self.num_layers, 4),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads * 4 // self.num_heads)),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            window=min(self.window, 16) if self.window else None,
            encoder_layers=min(self.encoder_layers, 2),
            attn_every=2 if self.attn_every else 0,
        )
        if self.moe:
            kw["moe"] = replace(
                self.moe, num_experts=8, top_k=2, d_ff_expert=64,
                num_shared=min(self.moe.num_shared, 1),
            )
        if self.ssm:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=16)
        if self.mrope_sections:
            kw["mrope_sections"] = (4, 6, 6)  # scaled to half of head_dim=32
        if self.pattern_local:
            kw["pattern_local"] = 2
            kw["pattern_global"] = 1
            kw["num_layers"] = 6
        if self.attn_every:
            kw["num_layers"] = 5  # 2 groups of 2 + 1 remainder
        kw["dtype"] = "float32"
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}
