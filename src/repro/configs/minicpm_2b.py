"""Config module for --arch minicpm-2b (exact dims in registry.py)."""

from .registry import ARCHS

CONFIG = ARCHS["minicpm-2b"]
REDUCED = CONFIG.reduced()
