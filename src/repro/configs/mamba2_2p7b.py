"""Config module for --arch mamba2-2.7b (exact dims in registry.py)."""

from .registry import ARCHS

CONFIG = ARCHS["mamba2-2.7b"]
REDUCED = CONFIG.reduced()
