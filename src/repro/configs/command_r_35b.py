"""Config module for --arch command-r-35b (exact dims in registry.py)."""

from .registry import ARCHS

CONFIG = ARCHS["command-r-35b"]
REDUCED = CONFIG.reduced()
