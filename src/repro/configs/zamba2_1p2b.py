"""Config module for --arch zamba2-1.2b (exact dims in registry.py)."""

from .registry import ARCHS

CONFIG = ARCHS["zamba2-1.2b"]
REDUCED = CONFIG.reduced()
