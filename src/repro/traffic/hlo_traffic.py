"""Compiled-HLO collective bytes → rack-level OCS demand matrices.

Bridges the dry-run artifacts to the paper's scheduler: each cell's
per-step collective traffic (parsed from its compiled HLO) is mapped onto
the Fig.-1 rack topology, and SPECTRA schedules the result — giving the
optical-fabric CCT for every (arch × shape) cell next to its roofline
terms.

Mapping (per collective class, per training step):
  all-reduce / all-gather / reduce-scatter  → ring traffic over the mesh's
    data/pod axes (TP collectives stay inside a rack: with 8 chips per
    rack, the model axis is rack-local by construction for axis groups
    ≤ chips_per_rack; larger groups spill a proportional share).
  all-to-all   → uniform rack-to-rack (EP dispatch).
  collective-permute → neighbor ring (pipeline-style).
"""

from __future__ import annotations

import numpy as np

from .collectives import Placement, TrafficModel


def demand_from_collectives(
    wire_bytes: dict[str, float],
    *,
    n_chips: int = 256,
    chips_per_rack: int = 8,
    model_axis: int = 16,
) -> np.ndarray:
    """Rack demand (bytes) for one step, from per-op-class wire bytes/chip."""
    pl = Placement(n_chips, chips_per_rack)
    tm = TrafficModel(pl)
    n_racks = pl.num_racks
    racks = list(range(n_racks))
    # Fraction of a model-axis group that leaves the rack: groups of
    # ``model_axis`` chips laid out contiguously span model_axis/cpr racks.
    spill = max(0.0, 1.0 - chips_per_rack / model_axis)

    def ring(total_bytes: float):
        if total_bytes <= 0 or n_racks < 2:
            return
        per_edge = total_bytes / n_racks
        for i in racks:
            tm.demand_bytes[i, (i + 1) % n_racks] += per_edge

    def uniform(total_bytes: float):
        if total_bytes <= 0 or n_racks < 2:
            return
        per_pair = total_bytes / (n_racks * (n_racks - 1))
        for a in racks:
            for b in racks:
                if a != b:
                    tm.demand_bytes[a, b] += per_pair

    # wire_bytes are per chip; scale to global and split rack-local share.
    for op, per_chip in wire_bytes.items():
        total = per_chip * n_chips
        if op in ("all-reduce", "all-gather", "reduce-scatter"):
            # DP/FSDP share crosses racks (ring); TP share mostly intra-rack.
            ring(total * 0.5 + total * 0.5 * spill)
        elif op in ("all-to-all", "ragged-all-to-all"):
            uniform(total)
        elif op == "collective-permute":
            ring(total)
    return tm.demand_bytes


def schedule_cell_demand(
    artifact: dict,
    *,
    num_switches: int = 4,
    reconfig_delay_s: float = 20e-6,
    chips_per_rack: int = 8,
):
    """Dry-run artifact → (SpectraResult, CCT seconds, demand matrix)."""
    from ..fabric.ocs import OCSFabric

    wire = artifact["roofline"]["collectives"]["wire_bytes"]
    n_chips = artifact["n_chips"]
    D = demand_from_collectives(
        wire, n_chips=n_chips, chips_per_rack=chips_per_rack
    )
    fabric = OCSFabric(num_switches=num_switches,
                       reconfig_delay_s=reconfig_delay_s)
    res, cct = fabric.schedule_bytes(D)
    return res, cct, D
