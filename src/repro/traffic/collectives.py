"""Collective-communication traffic models → rack-level demand matrices.

Maps the framework's parallelism plan (which collectives run over which mesh
axes, with how many bytes) onto the Fig.-1 topology: ``n`` racks whose ToRs
feed ``s`` parallel OCSes. Chip→rack placement is configurable; traffic
between chips in the same rack never reaches the optical core.

Byte counts per collective follow the standard ring algorithms:
  ring all-reduce  : each member sends 2(g−1)/g · V to its ring successor
  all-gather / RS  : (g−1)/g · V per member to its successor
  all-to-all       : V/g from every member to every other member
  point-to-point   : V from src to dst
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class Placement:
    """Maps global chip ids to racks (n racks × chips_per_rack)."""

    num_chips: int
    chips_per_rack: int

    def __post_init__(self) -> None:
        if self.num_chips % self.chips_per_rack:
            raise ValueError("num_chips must be divisible by chips_per_rack")
        self.num_racks = self.num_chips // self.chips_per_rack

    def rack(self, chip: int) -> int:
        return chip // self.chips_per_rack


@dataclass
class TrafficModel:
    """Accumulates chip-to-chip collective traffic into a rack demand matrix."""

    placement: Placement
    demand_bytes: np.ndarray = field(init=False)

    def __post_init__(self) -> None:
        n = self.placement.num_racks
        self.demand_bytes = np.zeros((n, n), dtype=np.float64)

    def _add(self, src_chip: int, dst_chip: int, nbytes: float) -> None:
        a, b = self.placement.rack(src_chip), self.placement.rack(dst_chip)
        if a != b:  # intra-rack traffic stays on the ToR
            self.demand_bytes[a, b] += nbytes

    def p2p(self, src: int, dst: int, nbytes: float) -> None:
        self._add(src, dst, nbytes)

    def ring_allreduce(self, group: list[int], nbytes: float) -> None:
        g = len(group)
        if g < 2:
            return
        per_edge = 2.0 * (g - 1) / g * nbytes
        for i, chip in enumerate(group):
            self._add(chip, group[(i + 1) % g], per_edge)

    def ring_allgather(self, group: list[int], nbytes: float) -> None:
        g = len(group)
        if g < 2:
            return
        per_edge = (g - 1) / g * nbytes
        for i, chip in enumerate(group):
            self._add(chip, group[(i + 1) % g], per_edge)

    ring_reducescatter = ring_allgather  # identical byte profile

    def all_to_all(self, group: list[int], nbytes: float) -> None:
        g = len(group)
        if g < 2:
            return
        per_pair = nbytes / g
        for a in group:
            for b in group:
                if a != b:
                    self._add(a, b, per_pair)

    def weighted_all_to_all(self, group: list[int], matrix_bytes: np.ndarray) -> None:
        """Non-uniform all-to-all (e.g. measured MoE routing), g×g bytes."""
        for i, a in enumerate(group):
            for j, b in enumerate(group):
                if a != b:
                    self._add(a, b, float(matrix_bytes[i, j]))


def sinkhorn(D: np.ndarray, iters: int = 200, tol: float = 1e-10) -> np.ndarray:
    """Scale D (on its support) to doubly stochastic."""
    D = np.asarray(D, dtype=np.float64).copy()
    for _ in range(iters):
        r = D.sum(axis=1, keepdims=True)
        D = np.divide(D, np.maximum(r, 1e-300))
        c = D.sum(axis=0, keepdims=True)
        D = np.divide(D, np.maximum(c, 1e-300))
        if abs(D.sum(1) - 1).max() < tol and abs(D.sum(0) - 1).max() < tol:
            break
    return D


def normalize_max_line(D: np.ndarray) -> np.ndarray:
    """Scale so the max row/col sum is 1 (schedulable in one unit sans δ)."""
    D = np.asarray(D, dtype=np.float64)
    T = max(D.sum(1).max(), D.sum(0).max())
    return D / T if T > 0 else D


def add_noise(D: np.ndarray, sigma: float, rng: np.random.Generator) -> np.ndarray:
    """Gaussian noise of std ``sigma`` on nonzero entries (paper's 0.3%/1%)."""
    D = np.asarray(D, dtype=np.float64).copy()
    nz = D > 0
    D[nz] = np.maximum(D[nz] + rng.normal(0.0, sigma, size=int(nz.sum())), 1e-9)
    return D
