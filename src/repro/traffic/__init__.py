"""Traffic generators behind the scenario registry.

The callables here build *one* demand matrix; the front door for
time-varying traffic is ``repro.scenarios``, whose registered scenario
names ("gpt", "moe", "benchmark", "collective_ring", …) wrap these
generators into declarative ``TrafficSpec``s and materialize whole
``(T, n, n)`` ``DemandTrace``s — the shape the batched solver and the
benchmarks consume. Reach for these functions directly only when you need a
single matrix outside any scenario.
"""

from .collectives import (
    Placement,
    TrafficModel,
    add_noise,
    normalize_max_line,
    sinkhorn,
)
from .hlo_traffic import demand_from_collectives, schedule_cell_demand
from .workloads import (
    WORKLOADS,
    benchmark_workload,
    gpt3b_workload,
    moe_workload,
)

__all__ = [
    "Placement",
    "TrafficModel",
    "WORKLOADS",
    "add_noise",
    "benchmark_workload",
    "demand_from_collectives",
    "gpt3b_workload",
    "moe_workload",
    "normalize_max_line",
    "schedule_cell_demand",
    "sinkhorn",
]
