"""The paper's three evaluation workloads (§V-A).

1. ``gpt3b_workload``  — 32×32, sparse, strongly skewed, doubly stochastic.
   Reconstructed (the Li et al. [20] measurement is not public) from our own
   collective traffic models under the DeepSpeed default 3D mapping the
   paper describes: TP innermost, then PP stages, then DP replicas. TP
   all-reduce dominates, PP activations next, DP gradient rings last;
   Sinkhorn-normalized to doubly stochastic + 0.3% noise on nonzeros.

2. ``moe_workload``    — 64×64 Qwen2-57B-style expert routing: dense,
   near-uniform with mild expert (column) popularity skew, strongly
   sub-stochastic. Token-count matrix from a simulated top-6 router.

3. ``benchmark_workload`` — the standard 100×100 benchmark [6][7][9]:
   m=16 random permutation flows per port — 4 large splitting 70% of the
   bandwidth, 12 small splitting 30% — plus 0.3% Gaussian noise.
"""

from __future__ import annotations

import numpy as np

from .collectives import Placement, TrafficModel, add_noise, normalize_max_line, sinkhorn


def gpt3b_workload(
    *,
    noise: float = 0.003,
    rng: np.random.Generator | None = None,
    tp: int = 4,
    pp: int = 4,
    dp: int = 2,
    tp_bytes: float = 10.0,
    pp_bytes: float = 3.0,
    dp_bytes: float = 1.0,
    emb_bytes: float = 2.0,
    bg_flows: int = 4,
    bg_bytes: float = 0.25,
) -> np.ndarray:
    """32×32 (tp·pp·dp = 32 GPUs, one per 'rack' port) GPT-3B traffic.

    Structure (DeepSpeed default 3D mapping, TP innermost): heavy TP
    activation all-reduce rings, medium PP activation/gradient p2p between
    neighbor stages, tied-embedding all-reduce between first and last
    stages, light DP gradient rings, plus a handful of small background
    flows per GPU (control plane / stragglers — present in any measured
    matrix and responsible for its long tail of small nonzeros).
    """
    rng = rng or np.random.default_rng(0)
    n = tp * pp * dp
    pl = Placement(num_chips=n, chips_per_rack=1)
    tm = TrafficModel(pl)

    def rank(d: int, p: int, t: int) -> int:
        return d * (pp * tp) + p * tp + t

    for d in range(dp):
        for p in range(pp):
            # TP all-reduce within each TP group (activations, per layer).
            tm.ring_allreduce([rank(d, p, t) for t in range(tp)], tp_bytes)
            # PP activations forward + grads backward to the next stage.
            if p + 1 < pp:
                for t in range(tp):
                    tm.p2p(rank(d, p, t), rank(d, p + 1, t), pp_bytes)
                    tm.p2p(rank(d, p + 1, t), rank(d, p, t), pp_bytes)
        # Tied input/output embedding gradient sync: first ↔ last stage.
        if emb_bytes > 0 and pp > 1:
            for t in range(tp):
                tm.ring_allreduce([rank(d, 0, t), rank(d, pp - 1, t)], emb_bytes)
    # DP gradient all-reduce across replicas of the same (p, t).
    for p in range(pp):
        for t in range(tp):
            tm.ring_allreduce([rank(d, p, t) for d in range(dp)], dp_bytes)
    # Background small flows (long tail of the measured matrix).
    for i in range(n):
        others = np.array([x for x in range(n) if x != i])
        for j in rng.choice(others, size=bg_flows, replace=False):
            tm.p2p(i, int(j), bg_bytes * (0.5 + rng.random()))

    D = sinkhorn(tm.demand_bytes)
    return add_noise(D, noise, rng)


def moe_workload(
    *,
    n: int = 64,
    top_k: int = 6,
    tokens_per_gpu: int = 8192,
    skew: float = 0.25,
    noise: float = 0.0,
    rng: np.random.Generator | None = None,
) -> np.ndarray:
    """64×64 MoE expert-routing demand (token counts, normalized)."""
    rng = rng or np.random.default_rng(0)
    # Expert popularity: near-uniform with a mild skew (Fig. 5's column
    # structure) — a few persistently hot destination experts.
    pop = 1.0 + skew * np.abs(rng.standard_normal(n))
    pop /= pop.sum()
    D = np.zeros((n, n), dtype=np.float64)
    for src in range(n):
        # Sample top-k destinations per token in aggregate: multinomial of
        # tokens×top_k routed choices, excluding the local expert (stays on
        # the GPU, never crosses the fabric).
        p = pop.copy()
        p[src] = 0.0
        p /= p.sum()
        counts = rng.multinomial(tokens_per_gpu * top_k, p)
        D[src, :] = counts
    D = normalize_max_line(D)
    if noise > 0:
        D = add_noise(D, noise, rng)
    return D


def benchmark_workload(
    *,
    n: int = 100,
    m: int = 16,
    num_big: int = 4,
    big_frac: float = 0.7,
    noise: float = 0.003,
    rng: np.random.Generator | None = None,
) -> np.ndarray:
    """Standard benchmark: m permutation flows per port (4 big / 12 small)."""
    rng = rng or np.random.default_rng(0)
    if m < num_big:
        raise ValueError("m must be at least num_big")
    D = np.zeros((n, n), dtype=np.float64)
    big_w = big_frac / num_big
    small_w = (1.0 - big_frac) / max(m - num_big, 1)
    for f in range(m):
        w = big_w if f < num_big else small_w
        D[np.arange(n), rng.permutation(n)] += w
    return add_noise(D, noise, rng)


WORKLOADS = {
    "gpt": gpt3b_workload,
    "moe": moe_workload,
    "benchmark": benchmark_workload,
}
