"""Static HTML report: per-switch Gantt strips + attribution tables.

``render_html`` turns a ``ScenarioAttribution`` into one self-contained
HTML file (no external assets): per period, a Gantt strip per switch
(serve = blue, reconfigure = orange, idle = neutral gray — categorical
slots 1/2 and the neutral from the validated reference palette, with the
dark-mode steps under ``prefers-color-scheme``), a legend naming each
color in text, and a numbers table carrying the same data for readers
the color channel does not serve. Interval tooltips ride the native
``title`` attribute.
"""

from __future__ import annotations

from html import escape
from pathlib import Path

from .timeline_table import ScenarioAttribution, TimelineTable

__all__ = ["render_html", "save_html"]

_CSS = """\
.obs-root {
  color-scheme: light;
  --surface-1: #fcfcfb;
  --page: #f9f9f7;
  --text-primary: #0b0b0b;
  --text-secondary: #52514e;
  --muted: #898781;
  --grid: #e1e0d9;
  --border: rgba(11,11,11,0.10);
  --serve: #2a78d6;   /* categorical slot 1 (blue) */
  --reconf: #eb6834;  /* categorical slot 2 (orange) */
  --idle: #f0efec;    /* neutral gray */
  font-family: system-ui, -apple-system, "Segoe UI", sans-serif;
  background: var(--page);
  color: var(--text-primary);
  margin: 0;
  padding: 24px;
}
@media (prefers-color-scheme: dark) {
  :root:where(:not([data-theme="light"])) .obs-root {
    color-scheme: dark;
    --surface-1: #1a1a19;
    --page: #0d0d0d;
    --text-primary: #ffffff;
    --text-secondary: #c3c2b7;
    --grid: #2c2c2a;
    --border: rgba(255,255,255,0.10);
    --serve: #3987e5;
    --reconf: #d95926;
    --idle: #383835;
  }
}
:root[data-theme="dark"] .obs-root {
  color-scheme: dark;
  --surface-1: #1a1a19;
  --page: #0d0d0d;
  --text-primary: #ffffff;
  --text-secondary: #c3c2b7;
  --grid: #2c2c2a;
  --border: rgba(255,255,255,0.10);
  --serve: #3987e5;
  --reconf: #d95926;
  --idle: #383835;
}
.obs-root h1 { font-size: 20px; margin: 0 0 4px; }
.obs-root h2 { font-size: 15px; margin: 24px 0 8px; }
.obs-root .sub { color: var(--text-secondary); font-size: 13px; margin: 0 0 16px; }
.obs-card {
  background: var(--surface-1);
  border: 1px solid var(--border);
  border-radius: 8px;
  padding: 16px;
  margin-bottom: 16px;
}
.obs-legend { display: flex; gap: 16px; font-size: 12px;
  color: var(--text-secondary); margin: 0 0 12px; }
.obs-legend .chip { display: inline-block; width: 10px; height: 10px;
  border-radius: 2px; margin-right: 5px; vertical-align: -1px; }
.obs-row { display: flex; align-items: center; gap: 8px; margin: 3px 0; }
.obs-row .lab { width: 64px; font-size: 12px; color: var(--text-secondary);
  text-align: right; font-variant-numeric: tabular-nums; }
.obs-strip { position: relative; flex: 1; height: 16px;
  background: var(--idle); border-radius: 4px; overflow: hidden; }
.obs-strip .iv { position: absolute; top: 0; bottom: 0;
  border-left: 1px solid var(--surface-1);
  border-right: 1px solid var(--surface-1); box-sizing: border-box; }
.obs-strip .serve { background: var(--serve); }
.obs-strip .reconf { background: var(--reconf); }
.obs-row .util { width: 56px; font-size: 12px; color: var(--text-secondary);
  font-variant-numeric: tabular-nums; }
.obs-axis { display: flex; justify-content: space-between; font-size: 11px;
  color: var(--muted); margin: 4px 0 0 72px; }
table.obs-table { border-collapse: collapse; font-size: 12px; width: 100%; }
table.obs-table th, table.obs-table td {
  text-align: right; padding: 4px 10px;
  border-bottom: 1px solid var(--grid);
  font-variant-numeric: tabular-nums; }
table.obs-table th { color: var(--text-secondary); font-weight: 600; }
table.obs-table th:first-child, table.obs-table td:first-child {
  text-align: left; }
"""

_LEGEND = (
    '<p class="obs-legend">'
    '<span><span class="chip" style="background:var(--serve)"></span>serve</span>'
    '<span><span class="chip" style="background:var(--reconf)"></span>reconfigure (δ)</span>'
    '<span><span class="chip" style="background:var(--idle);'
    'outline:1px solid var(--grid)"></span>idle</span>'
    "</p>"
)


def _strip(table: TimelineTable, row_index: int) -> str:
    """One switch's Gantt strip: absolutely-positioned interval blocks."""
    row = table.rows[row_index]
    horizon = table.horizon or 1.0
    parts = []
    for iv in row.intervals:
        if iv.kind == "idle" or iv.duration <= 0:
            continue  # idle is the strip background
        left = 100.0 * iv.start / horizon
        width = 100.0 * iv.duration / horizon
        tip = (
            f"ocs{row.switch} {iv.kind} "
            f"[{iv.start:.4f}, {iv.end:.4f})"
            + (f" slot {iv.slot}" if iv.kind == "serve" else "")
        )
        parts.append(
            f'<span class="iv {iv.kind}" title="{escape(tip)}" '
            f'style="left:{left:.3f}%;width:{width:.3f}%"></span>'
        )
    reused = " +" if row.reused else ""
    return (
        '<div class="obs-row">'
        f'<span class="lab">ocs{row.switch}{reused}</span>'
        f'<span class="obs-strip">{"".join(parts)}</span>'
        f'<span class="util">{row.utilization:.1%}</span>'
        "</div>"
    )


def _period_card(title: str, table: TimelineTable) -> str:
    att = table.attribution
    strips = "".join(_strip(table, i) for i in range(len(table.rows)))
    gap = (
        f"gap ×{att.makespan / att.lower_bound:.4f}"
        if att.lower_bound and att.lower_bound == att.lower_bound
        else "no lower bound"
    )
    return (
        '<div class="obs-card">'
        f"<h2>{escape(title)}</h2>"
        f'<p class="sub">makespan {att.makespan:.4f} · {gap} · '
        f"shares: serve {att.transmission_share:.1%}, "
        f"δ {att.delta_share:.1%}, idle {att.idle_share:.1%}"
        + (
            f" · reuse {att.reuse_count} (δ avoided {att.delta_avoided:.4f})"
            if att.reuse_count
            else ""
        )
        + "</p>"
        + _LEGEND
        + strips
        + f'<div class="obs-axis"><span>0</span>'
        f"<span>{table.horizon:.4f}</span></div>"
        "</div>"
    )


def _numbers_table(att: ScenarioAttribution) -> str:
    """The table view: the same attribution numbers, per period."""
    head = (
        "<tr><th>period</th><th>makespan</th><th>LB</th><th>serve</th>"
        "<th>δ paid</th><th>idle</th><th>util mean</th><th>reuse</th></tr>"
    )
    rows = []
    for label, tables in (("", att.tables), ("online ", att.online_tables)):
        for t, table in enumerate(tables):
            a = table.attribution
            rows.append(
                f"<tr><td>{label}{t}</td><td>{a.makespan:.4f}</td>"
                f"<td>{a.lower_bound:.4f}</td><td>{a.transmission:.4f}</td>"
                f"<td>{a.delta_paid:.4f}</td><td>{a.idle:.4f}</td>"
                f"<td>{table.utilization.mean():.1%}</td>"
                f"<td>{a.reuse_count}</td></tr>"
            )
    return (
        '<div class="obs-card"><h2>Attribution table</h2>'
        f'<table class="obs-table">{head}{"".join(rows)}</table></div>'
    )


def render_html(att: ScenarioAttribution, *, title: str | None = None) -> str:
    """Self-contained HTML report for one scenario attribution."""
    title = title or f"{att.scenario} · {att.solver} — switch timelines"
    agg = att.summary()
    cards = [
        _period_card(f"period {t}", table) for t, table in enumerate(att.tables)
    ]
    cards += [
        _period_card(f"online period {t} (credit-aware)", table)
        for t, table in enumerate(att.online_tables)
    ]
    return (
        "<!DOCTYPE html>\n<html><head><meta charset='utf-8'>"
        f"<title>{escape(title)}</title><style>{_CSS}</style></head>"
        '<body class="obs-root">'
        f"<h1>{escape(title)}</h1>"
        f'<p class="sub">{agg["periods"]} periods · '
        f'serve {agg["transmission_share"]:.1%} · '
        f'δ {agg["delta_share"]:.1%} · idle {agg["idle_share"]:.1%} · '
        f'mean utilization {agg["util_mean"]:.1%}</p>'
        + "".join(cards)
        + _numbers_table(att)
        + "</body></html>\n"
    )


def save_html(
    att: ScenarioAttribution, path: str | Path, *, title: str | None = None
) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(render_html(att, title=title))
    return path
