"""One metrics vocabulary for serving, scenarios, and benchmarks.

Grew out of ``repro.serve.metrics`` (which now re-exports from here):
the log-spaced ``LatencyHistogram`` and the always-on ``ServeMetrics``
counters moved unchanged, joined by the generic ``Counters`` bag and the
solver-warning taxonomy (``warning_category`` / ``warning_counts``) that
surfaces degraded solves — matcher budget exhausted, EQUALIZE headroom
exhausted — without digging through per-instance ``extras``. Everything
exports as a plain dict so benchmarks write it straight to JSON and CI
can gate on the numbers.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Iterable


class LatencyHistogram:
    """Fixed log-spaced latency histogram (seconds).

    Bins span ``lo``..``hi`` with ``per_decade`` geometric bins per decade;
    observations clamp into the edge bins, so no sample is ever dropped.
    Quantiles interpolate within the winning bin (geometric), which is
    accurate to one bin width — plenty for p50/p99 SLO gating — while
    ``observe`` stays O(1) with no sample retention.
    """

    def __init__(
        self,
        lo: float = 1e-6,
        hi: float = 100.0,
        per_decade: int = 8,
    ) -> None:
        if not (0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got {lo}, {hi}")
        self.lo = float(lo)
        self.hi = float(hi)
        decades = math.log10(hi / lo)
        self._nbins = max(1, int(math.ceil(decades * per_decade)))
        self._scale = self._nbins / math.log(hi / lo)
        self._counts = [0] * self._nbins
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, seconds: float) -> None:
        x = float(seconds)
        self.count += 1
        self.sum += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)
        if x <= self.lo:
            b = 0
        elif x >= self.hi:
            b = self._nbins - 1
        else:
            b = min(int(self._scale * math.log(x / self.lo)), self._nbins - 1)
        self._counts[b] += 1

    def _edge(self, b: int) -> float:
        return self.lo * math.exp(b / self._scale)

    def percentile(self, p: float) -> float:
        """p in [0, 100]; NaN when empty. Clamped to the observed min/max."""
        if self.count == 0:
            return math.nan
        target = p / 100.0 * self.count
        cum = 0
        for b, c in enumerate(self._counts):
            cum += c
            if cum >= target:
                # Geometric midpoint-ish interpolation inside the bin.
                frac = 1.0 if c == 0 else 1.0 - (cum - target) / c
                val = self._edge(b) * math.exp(frac / self._scale)
                return min(max(val, self.min), self.max)
        return self.max  # pragma: no cover - cum always reaches count

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def export(self) -> dict:
        return {
            "count": self.count,
            "mean_s": self.mean,
            "min_s": self.min if self.count else math.nan,
            "max_s": self.max if self.count else math.nan,
            "p50_s": self.percentile(50),
            "p90_s": self.percentile(90),
            "p99_s": self.percentile(99),
        }


# The per-request pipeline stages the server times. "queue_wait" is
# submit→dispatch, "device" is dispatch→results-collected, "install" is the
# OCS programming/ACK latency per installed batch, "e2e" is submit→installed.
STAGES = ("queue_wait", "device", "install", "e2e")


class Counters:
    """Named monotonic counters with dict export — the obs counter bag."""

    def __init__(self) -> None:
        self._counts: dict[str, int] = {}

    def inc(self, name: str, by: int = 1) -> None:
        self._counts[name] = self._counts.get(name, 0) + int(by)

    def get(self, name: str) -> int:
        return self._counts.get(name, 0)

    @property
    def total(self) -> int:
        return sum(self._counts.values())

    def export(self) -> dict[str, int]:
        return dict(sorted(self._counts.items()))

    def __bool__(self) -> bool:
        return bool(self._counts)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Counters({self.export()})"


# ---------------------------------------------------------------- warnings
# Solver warnings live in SolveReport.extras["warnings"] as free-form
# strings; this taxonomy buckets them into stable counter names so reports
# and CI can alarm on them without string-matching per call site.

WARNING_CATEGORIES = (
    "matcher_budget_exhausted",
    "equalize_headroom_exhausted",
    "other",
)


def warning_category(message: str) -> str:
    """Bucket one warning string into a stable counter name."""
    low = message.lower()
    if "matcher" in low:
        return "matcher_budget_exhausted"
    if "equalize" in low:
        return "equalize_headroom_exhausted"
    return "other"


def warning_counts(reports: Iterable[Any]) -> Counters:
    """Tally ``extras["warnings"]`` across SolveReports into obs counters.

    Also mirrors each tally into the default tracer as counter samples
    (when tracing is enabled), so degraded solves show up on the trace
    timeline next to the spans that produced them.
    """
    from .trace import get_tracer

    counters = Counters()
    for rep in reports:
        extras = getattr(rep, "extras", None) or {}
        for msg in extras.get("warnings", ()):
            counters.inc(warning_category(str(msg)))
    tracer = get_tracer()
    if tracer.enabled:
        for name, value in counters.export().items():
            tracer.counter(f"warnings.{name}", value)
    return counters


@dataclass
class ServeMetrics:
    """Always-on counters + stage histograms for one server instance."""

    stages: dict[str, LatencyHistogram] = field(
        default_factory=lambda: {name: LatencyHistogram() for name in STAGES}
    )
    admitted: int = 0
    degraded: int = 0
    shed: int = 0
    cache_hit_exact: int = 0
    cache_hit_support: int = 0
    cache_miss: int = 0
    batches: int = 0
    schedules: int = 0
    _t0: float = field(default_factory=time.perf_counter)

    def observe(self, stage: str, seconds: float) -> None:
        self.stages[stage].observe(seconds)

    def count_verdict(self, verdict: str) -> None:
        if verdict == "ADMIT":
            self.admitted += 1
        elif verdict == "DEGRADED":
            self.degraded += 1
        elif verdict == "SHED":
            self.shed += 1
        else:
            raise ValueError(f"unknown admission verdict {verdict!r}")

    @property
    def cache_hits(self) -> int:
        return self.cache_hit_exact + self.cache_hit_support

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_miss
        return self.cache_hits / total if total else math.nan

    @property
    def elapsed_s(self) -> float:
        return time.perf_counter() - self._t0

    @property
    def schedules_per_sec(self) -> float:
        dt = self.elapsed_s
        return self.schedules / dt if dt > 0 else math.nan

    def export(self) -> dict:
        """JSON-safe snapshot: counters, rates, and per-stage histograms."""
        return {
            "admitted": self.admitted,
            "degraded": self.degraded,
            "shed": self.shed,
            "cache_hit_exact": self.cache_hit_exact,
            "cache_hit_support": self.cache_hit_support,
            "cache_miss": self.cache_miss,
            "cache_hit_rate": self.cache_hit_rate,
            "batches": self.batches,
            "schedules": self.schedules,
            "elapsed_s": self.elapsed_s,
            "schedules_per_sec": self.schedules_per_sec,
            "stages": {k: h.export() for k, h in self.stages.items()},
        }
