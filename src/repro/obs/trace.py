"""Nested-span tracer with Chrome trace-event export.

The tracer the whole pipeline is wired through: ``span("decompose")``
around each stage, ``span("matcher")`` around each matching round,
``span("jax.dispatch")`` / ``span("jax.collect")`` around the fused device
calls, ``span("serve.install")`` around switch programming, and so on.
One module-level default tracer (``get_tracer()``) is what the wiring
uses; tests and tools may construct their own ``Tracer``.

Cost discipline — the tracer is wired into hot paths, so:

* **Disabled** (the default), ``span()`` is one attribute check and
  returns a shared no-op context-manager singleton: no allocation, no
  timestamps, nothing recorded. Call sites that want to attach argument
  dicts guard on ``tracer.enabled`` (or pass ``args`` only when cheap) so
  the disabled path stays allocation-free end to end.
* **Enabled**, each span costs two ``perf_counter`` reads, one small
  object, and one list append. Spans nest via a per-thread stack; every
  finished span records its parent, so containment invariants
  (child ⊆ parent interval) are checkable directly.

Export is the Chrome trace-event JSON format (``ph: "X"`` complete
events, microsecond timestamps) — load the file in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing`` to see the pipeline as
a flame chart. ``device_sync=True`` asks the JAX wiring to block on
device buffers *inside* its dispatch spans so device time lands in the
span that launched it (off by default: it serializes the async pipeline).
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Mapping

__all__ = ["SpanEvent", "Tracer", "get_tracer", "span"]


class SpanEvent:
    """One finished (or in-flight) span: absolute perf_counter interval."""

    __slots__ = ("name", "cat", "start", "end", "parent", "tid", "args")

    def __init__(
        self,
        name: str,
        cat: str,
        start: float,
        parent: int | None,
        tid: int,
        args: Mapping[str, Any] | None,
    ) -> None:
        self.name = name
        self.cat = cat
        self.start = start
        self.end: float | None = None  # filled when the span closes
        self.parent = parent           # index into Tracer.events, or None
        self.tid = tid
        self.args = args

    @property
    def duration(self) -> float:
        return (self.end - self.start) if self.end is not None else 0.0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"SpanEvent({self.name!r}, dur={self.duration:.6f})"


class _NullSpan:
    """Shared no-op context manager returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **kw) -> None:
        """No-op counterpart of ``_LiveSpan.set``."""


_NULL_SPAN = _NullSpan()


class _LiveSpan:
    """Context manager recording one SpanEvent on the owning tracer."""

    __slots__ = ("_tracer", "_index")

    def __init__(self, tracer: "Tracer", index: int) -> None:
        self._tracer = tracer
        self._index = index

    def __enter__(self) -> "_LiveSpan":
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer._close(self._index)
        return False

    def set(self, **kw) -> None:
        """Attach/extend args on the open span (e.g. results known at exit)."""
        ev = self._tracer.events[self._index]
        ev.args = {**(ev.args or {}), **kw}


class Tracer:
    """Nested span recorder; disabled by default, O(1) no-op when off."""

    def __init__(self, *, enabled: bool = False, device_sync: bool = False):
        self.enabled = bool(enabled)
        self.device_sync = bool(device_sync)
        self.events: list[SpanEvent] = []
        self._t0 = time.perf_counter()
        self._local = threading.local()

    # ----------------------------------------------------------- control
    def enable(self, *, device_sync: bool | None = None) -> None:
        if device_sync is not None:
            self.device_sync = bool(device_sync)
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Drop all recorded events and restart the clock."""
        self.events = []
        self._t0 = time.perf_counter()
        self._local = threading.local()

    # --------------------------------------------------------- recording
    def _stack(self) -> list[int]:
        try:
            return self._local.stack
        except AttributeError:
            stack: list[int] = []
            self._local.stack = stack
            return stack

    def span(self, name: str, args: Mapping[str, Any] | None = None):
        """Context manager timing one nested span.

        ``args`` (an optional mapping) lands in the exported event's
        ``args`` field. When the tracer is disabled this returns a shared
        no-op singleton — build arg dicts only under ``tracer.enabled``
        if the call site is hot.
        """
        if not self.enabled:
            return _NULL_SPAN
        stack = self._stack()
        ev = SpanEvent(
            name,
            "repro",
            time.perf_counter(),
            stack[-1] if stack else None,
            threading.get_ident(),
            dict(args) if args else None,
        )
        index = len(self.events)
        self.events.append(ev)
        stack.append(index)
        return _LiveSpan(self, index)

    def _close(self, index: int) -> None:
        self.events[index].end = time.perf_counter()
        stack = self._stack()
        # The span being closed is the stack top in well-nested use; pop
        # down to it so an exception skipping inner __exit__s can't wedge
        # the stack (children left open are closed with their parent's end).
        while stack and stack[-1] >= index:
            j = stack.pop()
            if self.events[j].end is None:
                self.events[j].end = self.events[index].end

    def instant(self, name: str, args: Mapping[str, Any] | None = None) -> None:
        """Point-in-time marker (Chrome ``ph: "i"`` instant event)."""
        if not self.enabled:
            return
        ev = SpanEvent(
            name, "repro.instant", time.perf_counter(), None,
            threading.get_ident(), dict(args) if args else None,
        )
        ev.end = ev.start
        self.events.append(ev)

    def counter(self, name: str, value: float) -> None:
        """Time-series counter sample (Chrome ``ph: "C"`` counter event)."""
        if not self.enabled:
            return
        ev = SpanEvent(
            name, "repro.counter", time.perf_counter(), None,
            threading.get_ident(), {"value": float(value)},
        )
        ev.end = ev.start
        self.events.append(ev)

    # ------------------------------------------------------------ export
    def spans(self) -> list[SpanEvent]:
        """Finished spans (open spans and instant/counter samples excluded)."""
        return [
            e for e in self.events
            if e.cat == "repro" and e.end is not None
        ]

    def to_chrome(self) -> dict[str, Any]:
        """The Chrome trace-event JSON object (Perfetto-loadable)."""
        out = []
        for e in self.events:
            if e.end is None:
                continue  # never-closed span: not representable as "X"
            ts = (e.start - self._t0) * 1e6
            common = {
                "name": e.name,
                "cat": e.cat,
                "ts": ts,
                "pid": 0,
                "tid": e.tid,
            }
            if e.args:
                common["args"] = dict(e.args)
            if e.cat == "repro.instant":
                common.update(ph="i", s="t")
            elif e.cat == "repro.counter":
                common.update(ph="C")
            else:
                common.update(ph="X", dur=(e.end - e.start) * 1e6)
            out.append(common)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def save(self, path: str | Path) -> Path:
        """Write the Chrome trace JSON; returns the path written."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_chrome(), indent=1))
        return path


# The default tracer every pipeline call site records into. Enable it with
# ``get_tracer().enable()`` (or benchmarks/run.py --obs, or the dashboard).
_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def span(name: str, args: Mapping[str, Any] | None = None):
    """``get_tracer().span(...)`` — the form the pipeline wiring imports."""
    return _TRACER.span(name, args)
