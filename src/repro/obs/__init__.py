"""Observability: span tracing, time-expanded switch tables, shared metrics.

Three pillars, one package:

* ``trace`` — a lightweight nested-span tracer wired through the whole
  pipeline (API dispatch/collect, shape buckets, decompose/LPT/equalize
  stages, matcher rounds, the serving batch loop, scenario periods).
  Disabled it costs one attribute check per call site; enabled it exports
  Chrome trace-event JSON viewable in Perfetto (``chrome://tracing``).
* ``timeline_table`` — the time-expanded view of a schedule built on
  ``repro.fabric.timeline``: per-switch occupancy rows (serve /
  reconfigure / idle intervals), per-round utilization, and the makespan
  attribution identity ``transmission + δ paid + idle ≡ s · makespan``
  with an exact lower-bound-gap decomposition per period.
* ``metrics`` — the one metrics vocabulary (log-spaced latency
  histograms, named counters) shared by serving, scenarios, and
  benchmarks; ``repro.serve.metrics`` re-exports it for compatibility.

``python -m repro.obs.dashboard <scenario>`` renders the terminal
timeline; ``--html``/``--trace`` write the HTML report and the Chrome
trace.
"""

from .metrics import (
    STAGES,
    Counters,
    LatencyHistogram,
    ServeMetrics,
    warning_category,
    warning_counts,
)
from .trace import Tracer, get_tracer, span

# timeline_table builds on fabric.timeline (which builds on core.schedule),
# while core/api modules import obs.trace at module load — so its names
# resolve lazily (PEP 562) to keep the tracer importable from anywhere in
# the pipeline without a cycle.
_TIMELINE_NAMES = (
    "Interval",
    "MakespanAttribution",
    "ScenarioAttribution",
    "SwitchRow",
    "TimelineTable",
    "attribute_scenario",
    "timeline_table",
)


def __getattr__(name: str):
    if name in _TIMELINE_NAMES:
        # importlib (not ``from . import``): the submodule shares its name
        # with the ``timeline_table`` function, so a fromlist import would
        # re-enter this __getattr__ forever. Bind every lazy name at once —
        # importing the submodule sets the package attribute
        # ``timeline_table`` to the *module*, which must be overwritten
        # with the function before anyone can see it.
        import importlib

        mod = importlib.import_module(".timeline_table", __name__)
        for lazy in _TIMELINE_NAMES:
            globals()[lazy] = getattr(mod, lazy)
        return globals()[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Counters",
    "Interval",
    "LatencyHistogram",
    "MakespanAttribution",
    "STAGES",
    "ScenarioAttribution",
    "ServeMetrics",
    "SwitchRow",
    "TimelineTable",
    "Tracer",
    "attribute_scenario",
    "get_tracer",
    "span",
    "timeline_table",
    "warning_category",
    "warning_counts",
]
