"""Time-expanded switch tables and makespan attribution.

Built on ``repro.fabric.timeline.build_timeline`` — the one source of
truth for circuit timing — this module answers *where the makespan goes*:

* ``timeline_table`` expands one schedule into per-switch occupancy rows
  (``serve`` / ``reconf`` / ``idle`` intervals covering ``[0, horizon)``
  exactly), per-switch utilization, and per-round statistics.
* ``MakespanAttribution`` is the accounting identity underneath:

      transmission + δ paid + idle  ≡  s · makespan

  (each switch's horizon splits exactly into serve time, reconfiguration
  time actually paid, and idle tail). The same identity divided by ``s``
  gives an **exact** lower-bound-gap decomposition:

      makespan − LB  ≡  (transmission/s − LB)  +  δpaid/s  +  idle/s

  whose first term may be negative (the §IV bound already charges some
  transmission *and* δ) — the other two are the overheads SPECTRA's
  EQUALIZE and the online controller's reuse credit attack directly.
* ``attribute_scenario`` runs the expansion over every period of a
  ``ScenarioReport`` (and the credit-aware online pass of an
  ``OnlineReport``, replaying the installed-configuration chain), checks
  the identity per period, and aggregates — turning "the gap is 1.07×"
  into "4% δ, 2% idle, 1% imbalance".

Nothing here imports the scenario registry — reports are duck-typed — so
``repro.scenarios`` can lazily call back into this module without a cycle.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

import numpy as np

from ..fabric.timeline import Timeline, build_timeline

__all__ = [
    "Interval",
    "MakespanAttribution",
    "ScenarioAttribution",
    "SwitchRow",
    "TimelineTable",
    "attribute_scenario",
    "timeline_table",
]


@dataclass(frozen=True)
class Interval:
    """One occupancy interval on one switch: ``[start, end)``."""

    switch: int
    kind: str      # "serve" | "reconf" | "idle"
    start: float
    end: float
    slot: int = -1  # serve intervals: position in the switch's slot list

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclass
class SwitchRow:
    """One switch's time-expanded row over ``[0, horizon)``."""

    switch: int
    intervals: list[Interval]
    serve_time: float
    reconf_time: float
    idle_time: float
    horizon: float
    reused: bool  # first slot served δ-free via a carried configuration

    @property
    def utilization(self) -> float:
        """Serve-busy fraction of the horizon (0 for an empty horizon)."""
        return self.serve_time / self.horizon if self.horizon > 0 else 0.0

    @property
    def reconf_fraction(self) -> float:
        return self.reconf_time / self.horizon if self.horizon > 0 else 0.0

    @property
    def idle_fraction(self) -> float:
        return self.idle_time / self.horizon if self.horizon > 0 else 0.0


@dataclass
class MakespanAttribution:
    """The identity ``transmission + δ paid + idle == s · makespan``.

    All quantities are in demand-time units, summed over switches.
    ``lower_bound`` is NaN when the producing report carried none.
    """

    s: int
    makespan: float      # the horizon (credit-aware for online timelines)
    transmission: float  # Σ serve time over switches (Σ α)
    delta_paid: float    # Σ reconfiguration time actually paid
    idle: float          # Σ (horizon − busy) over switches
    lower_bound: float = float("nan")
    reuse_count: int = 0                 # switches that served δ-free
    delta_avoided: float = 0.0           # δ · reuse_count

    @property
    def identity_residual(self) -> float:
        """``transmission + δ paid + idle − s·makespan`` (≈ 0 by construction)."""
        return self.transmission + self.delta_paid + self.idle - self.s * self.makespan

    def check(self, tol: float = 1e-9) -> None:
        """Assert the identity within ``tol`` (relative to s·makespan)."""
        scale = max(1.0, self.s * abs(self.makespan))
        if abs(self.identity_residual) > tol * scale:
            raise AssertionError(
                f"attribution identity violated: transmission {self.transmission}"
                f" + delta {self.delta_paid} + idle {self.idle}"
                f" != {self.s} * {self.makespan}"
                f" (residual {self.identity_residual})"
            )

    # Shares of the total switch-time budget (sum to 1 when makespan > 0).
    @property
    def transmission_share(self) -> float:
        total = self.s * self.makespan
        return self.transmission / total if total > 0 else 0.0

    @property
    def delta_share(self) -> float:
        total = self.s * self.makespan
        return self.delta_paid / total if total > 0 else 0.0

    @property
    def idle_share(self) -> float:
        total = self.s * self.makespan
        return self.idle / total if total > 0 else 0.0

    # Exact LB-gap decomposition (see module doc): the three terms sum to
    # ``makespan − lower_bound`` identically.
    @property
    def lb_gap(self) -> float:
        return self.makespan - self.lower_bound

    @property
    def gap_from_transmission(self) -> float:
        """``transmission/s − LB`` — may be negative (LB charges δ too)."""
        return self.transmission / self.s - self.lower_bound

    @property
    def gap_from_delta(self) -> float:
        return self.delta_paid / self.s

    @property
    def gap_from_idle(self) -> float:
        return self.idle / self.s

    def summary(self) -> dict[str, Any]:
        return {
            "s": self.s,
            "makespan": self.makespan,
            "transmission": self.transmission,
            "delta_paid": self.delta_paid,
            "delta_avoided": self.delta_avoided,
            "idle": self.idle,
            "reuse_count": self.reuse_count,
            "transmission_share": self.transmission_share,
            "delta_share": self.delta_share,
            "idle_share": self.idle_share,
            "identity_residual": self.identity_residual,
            "lower_bound": self.lower_bound,
            "lb_gap": self.lb_gap,
            "gap_from_transmission": self.gap_from_transmission,
            "gap_from_delta": self.gap_from_delta,
            "gap_from_idle": self.gap_from_idle,
        }


@dataclass
class TimelineTable:
    """Time-expanded table of one schedule: rows, rounds, attribution."""

    rows: list[SwitchRow]
    horizon: float
    delta: float
    attribution: MakespanAttribution
    timeline: Timeline = field(repr=False, default=None)

    @property
    def s(self) -> int:
        return len(self.rows)

    @property
    def utilization(self) -> np.ndarray:
        """(s,) serve-busy fraction per switch."""
        return np.array([r.utilization for r in self.rows])

    def per_round(self) -> list[dict[str, Any]]:
        """Round (slot-index) statistics across switches.

        Round ``j`` aggregates every switch's j-th served configuration:
        how many switches are still active at that depth, the total and
        extreme serve durations, and the *spread* (max − min α) that
        EQUALIZE exists to shrink.
        """
        by_slot: dict[int, list[float]] = {}
        for w in self.timeline.windows:
            by_slot.setdefault(w.slot, []).append(w.alpha)
        out = []
        for j in sorted(by_slot):
            alphas = np.array(by_slot[j])
            out.append(
                {
                    "round": j,
                    "switches": int(len(alphas)),
                    "alpha_total": float(alphas.sum()),
                    "alpha_mean": float(alphas.mean()),
                    "alpha_max": float(alphas.max()),
                    "alpha_min": float(alphas.min()),
                    "spread": float(alphas.max() - alphas.min()),
                }
            )
        return out

    def render_ascii(self, width: int = 72) -> str:
        """Per-switch occupancy strips: ``#`` serve, ``/`` reconf, ``·`` idle."""
        if self.horizon <= 0 or not self.rows:
            return "(empty schedule)"
        chars = {"serve": "#", "reconf": "/", "idle": "·"}
        lines = []
        for row in self.rows:
            strip = []
            for c in range(width):
                # Sample the interval covering this column's midpoint.
                t = (c + 0.5) / width * self.horizon
                kind = "idle"
                for iv in row.intervals:
                    if iv.start <= t < iv.end:
                        kind = iv.kind
                        break
                strip.append(chars[kind])
            reuse = "+" if row.reused else " "
            lines.append(
                f"  ocs{row.switch:<3d}{reuse}|{''.join(strip)}| "
                f"util={row.utilization:5.1%} δ={row.reconf_fraction:5.1%} "
                f"idle={max(row.idle_fraction, 0.0):5.1%}"
            )
        lines.append(
            f"  {'':7s}|{'-' * width}| horizon={self.horizon:.4f} "
            f"(# serve, / reconf, · idle, + reused carry-over)"
        )
        return "\n".join(lines)


def timeline_table(
    sched,
    *,
    installed: Sequence[np.ndarray | None] | None = None,
    lower_bound: float | None = None,
    horizon: float | None = None,
) -> TimelineTable:
    """Expand a schedule into its time-expanded switch table.

    Accepts a ``ParallelSchedule`` or anything carrying one under
    ``.schedule`` (``SolveReport``; its ``lower_bound`` is picked up when
    ``lower_bound`` is not given). ``installed`` enables the online reuse
    credit exactly as in ``fabric.simulator``. ``horizon`` defaults to the
    timeline finish — pass the controller-period makespan to account a
    switch's time against a longer horizon (more idle).
    """
    if lower_bound is None:
        lower_bound = float(getattr(sched, "lower_bound", float("nan")))
    tl = build_timeline(sched, installed=installed)
    if horizon is None:
        horizon = tl.finish
    elif horizon < tl.finish - 1e-9 * max(1.0, tl.finish):
        raise ValueError(
            f"horizon {horizon} is shorter than the timeline finish {tl.finish}"
        )
    rows: list[SwitchRow] = []
    windows_by_switch: dict[int, list] = {h: [] for h in range(tl.s)}
    for w in tl.windows:
        windows_by_switch[w.switch].append(w)
    for h in range(tl.s):
        intervals: list[Interval] = []
        serve = reconf = 0.0
        t = 0.0
        for w in windows_by_switch[h]:
            if not w.reused:
                intervals.append(Interval(h, "reconf", t, w.start))
                reconf += w.start - t
            intervals.append(Interval(h, "serve", w.start, w.end, slot=w.slot))
            serve += w.alpha
            t = w.end
        if t < horizon:
            intervals.append(Interval(h, "idle", t, horizon))
        # Idle from the attribution identity, so the three components sum
        # to the horizon exactly even under float accumulation.
        idle = horizon - serve - reconf
        rows.append(
            SwitchRow(
                switch=h,
                intervals=intervals,
                serve_time=serve,
                reconf_time=reconf,
                idle_time=idle,
                horizon=horizon,
                reused=bool(tl.reused_switches[h]),
            )
        )
    attribution = MakespanAttribution(
        s=tl.s,
        makespan=horizon,
        transmission=float(sum(r.serve_time for r in rows)),
        delta_paid=float(sum(r.reconf_time for r in rows)),
        idle=float(sum(r.idle_time for r in rows)),
        lower_bound=lower_bound,
        reuse_count=int(tl.reused_switches.sum()),
        delta_avoided=float(tl.delta * tl.reused_switches.sum()),
    )
    return TimelineTable(
        rows=rows, horizon=horizon, delta=tl.delta,
        attribution=attribution, timeline=tl,
    )


@dataclass
class ScenarioAttribution:
    """Per-period timeline tables + aggregate attribution for one report."""

    scenario: str
    solver: str
    tables: list[TimelineTable]               # stateless pass, trace order
    online_tables: list[TimelineTable] = field(default_factory=list)
    tol: float = 1e-9

    def check(self) -> None:
        """Assert the attribution identity on every period (both passes)."""
        for t, table in enumerate(self.tables + self.online_tables):
            try:
                table.attribution.check(self.tol)
            except AssertionError as exc:
                raise AssertionError(f"period {t}: {exc}") from None

    @staticmethod
    def _aggregate(tables: list[TimelineTable]) -> dict[str, Any]:
        att = [t.attribution for t in tables]
        total = sum(a.s * a.makespan for a in att)
        lbs = np.array([a.lower_bound for a in att])
        gaps = np.array([a.lb_gap for a in att])
        finite = np.isfinite(gaps)
        utils = np.concatenate([t.utilization for t in tables]) if tables else np.array([])
        return {
            "periods": len(att),
            "total_makespan": float(sum(a.makespan for a in att)),
            "transmission": float(sum(a.transmission for a in att)),
            "delta_paid": float(sum(a.delta_paid for a in att)),
            "delta_avoided": float(sum(a.delta_avoided for a in att)),
            "idle": float(sum(a.idle for a in att)),
            "reuse_count": int(sum(a.reuse_count for a in att)),
            "transmission_share": (
                float(sum(a.transmission for a in att) / total) if total > 0 else 0.0
            ),
            "delta_share": (
                float(sum(a.delta_paid for a in att) / total) if total > 0 else 0.0
            ),
            "idle_share": (
                float(sum(a.idle for a in att) / total) if total > 0 else 0.0
            ),
            "util_mean": float(utils.mean()) if len(utils) else 0.0,
            "util_min": float(utils.min()) if len(utils) else 0.0,
            "total_lb": float(lbs[finite].sum()) if finite.any() else float("nan"),
            "total_lb_gap": float(gaps[finite].sum()) if finite.any() else float("nan"),
            "gap_from_transmission": float(
                sum(a.gap_from_transmission for a in att if np.isfinite(a.lb_gap))
            ),
            "gap_from_delta": float(sum(a.gap_from_delta for a in att)),
            "gap_from_idle": float(sum(a.gap_from_idle for a in att)),
            "max_identity_residual": (
                float(max(abs(a.identity_residual) for a in att)) if att else 0.0
            ),
        }

    def summary(self) -> dict[str, Any]:
        """Flat aggregate row; online keys appear when the report was online."""
        row = {"scenario": self.scenario, "solver": self.solver}
        row.update(self._aggregate(self.tables))
        if self.online_tables:
            online = self._aggregate(self.online_tables)
            row.update({f"online_{k}": v for k, v in online.items()})
        return row


def attribute_scenario(report, *, tol: float | None = None) -> ScenarioAttribution:
    """Time-expand every period of a ``ScenarioReport`` and check the identity.

    For an ``OnlineReport`` the online pass is expanded too: the
    installed-configuration chain is replayed (exactly as the runner
    replayed it), so online timelines start from each period's carried
    switch state and their horizons are the credit-aware makespans.

    ``tol`` bounds the identity residual and the horizon-vs-reported
    makespan agreement; ``None`` resolves per backend (1e-9 host / 1e-4
    float32 device), matching the validation tolerances everywhere else.
    """
    if tol is None:
        backends = {r.backend for r in report.reports}
        tol = 1e-4 if "jax" in backends else 1e-9
    tables: list[TimelineTable] = []
    for t, rep in enumerate(report.reports):
        table = timeline_table(rep)
        table.attribution.check(tol)
        reported = float(rep.makespan)
        if abs(table.horizon - reported) > tol * max(1.0, reported):
            raise AssertionError(
                f"period {t}: timeline horizon {table.horizon} disagrees "
                f"with reported makespan {reported}"
            )
        tables.append(table)

    online_tables: list[TimelineTable] = []
    online_periods = getattr(report, "online_periods", None)
    if online_periods:
        from ..online import SwitchState, advance_installed, reuse_marks

        installed: list[np.ndarray | None] = [None] * report.spec.s
        for t, p in enumerate(online_periods):
            table = timeline_table(
                p.schedule,
                installed=installed,
                lower_bound=float(report.reports[t].lower_bound),
            )
            table.attribution.check(tol)
            reported = float(p.makespan)
            if abs(table.horizon - reported) > 1e-6 * max(1.0, reported):
                raise AssertionError(
                    f"online period {t}: timeline horizon {table.horizon} "
                    f"disagrees with credit-aware makespan {reported}"
                )
            paid = float(p.delta_paid)
            if abs(table.attribution.delta_paid - paid) > tol * max(1.0, paid):
                raise AssertionError(
                    f"online period {t}: timeline delta paid "
                    f"{table.attribution.delta_paid} != accounted {paid}"
                )
            online_tables.append(table)
            state = SwitchState(installed=installed)
            marks = reuse_marks(p.schedule, state)
            installed = advance_installed(p.schedule, state, marks)
    return ScenarioAttribution(
        scenario=report.scenario,
        solver=report.solver,
        tables=tables,
        online_tables=online_tables,
        tol=tol,
    )
