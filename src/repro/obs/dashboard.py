"""Terminal dashboard: ``python -m repro.obs.dashboard <scenario>``.

Runs one registered scenario, time-expands every period, checks the
attribution identity, and renders the per-switch occupancy strips plus
the LB-gap breakdown in the terminal. ``--html`` additionally writes the
Gantt report; ``--trace`` records the run through the span tracer and
writes Chrome trace-event JSON (open it at https://ui.perfetto.dev).
"""

from __future__ import annotations

import argparse
import sys

from .html import save_html
from .timeline_table import attribute_scenario
from .trace import get_tracer


def _fmt(v: float) -> str:
    return f"{v:.4f}"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.dashboard",
        description="Per-switch timeline + makespan attribution for a scenario.",
    )
    ap.add_argument("scenario", help="registered scenario name (e.g. gpt, moe)")
    ap.add_argument("--solver", default="spectra", help="registry solver name")
    ap.add_argument("--n", type=int, default=None, help="override port count")
    ap.add_argument(
        "--periods", type=int, default=None, help="override trace length"
    )
    ap.add_argument(
        "--online", action="store_true",
        help="also run the stateful online pass (reuse credit timelines)",
    )
    ap.add_argument(
        "--width", type=int, default=72, help="timeline strip width (chars)"
    )
    ap.add_argument(
        "--max-periods", type=int, default=3,
        help="render at most this many period strips (attribution covers all)",
    )
    ap.add_argument("--html", metavar="PATH", help="write the HTML Gantt report")
    ap.add_argument(
        "--trace", metavar="PATH",
        help="record a span trace and write Chrome trace-event JSON",
    )
    args = ap.parse_args(argv)

    tracer = get_tracer()
    if args.trace:
        tracer.enable()

    from ..scenarios import run_scenario  # defer: registry import is heavy

    overrides = {}
    if args.n is not None:
        overrides["n"] = args.n
    if args.periods is not None:
        overrides["periods"] = args.periods
    report = run_scenario(
        args.scenario, solver=args.solver, online=args.online, **overrides
    )
    att = attribute_scenario(report)
    att.check()

    agg = att.summary()
    print(f"{att.scenario} · {att.solver} — {agg['periods']} periods")
    print(
        f"  switch-time shares: serve {agg['transmission_share']:.1%}  "
        f"δ {agg['delta_share']:.1%}  idle {agg['idle_share']:.1%}  "
        f"(util mean {agg['util_mean']:.1%}, min {agg['util_min']:.1%})"
    )
    print(
        f"  LB gap {_fmt(agg['total_lb_gap'])} = "
        f"imbalance {_fmt(agg['gap_from_transmission'])} "
        f"+ δ {_fmt(agg['gap_from_delta'])} "
        f"+ idle {_fmt(agg['gap_from_idle'])}"
    )
    for label, tables in (("period", att.tables), ("online", att.online_tables)):
        for t, table in enumerate(tables[: args.max_periods]):
            a = table.attribution
            print(
                f"\n{label} {t}: makespan {_fmt(a.makespan)}  "
                f"LB {_fmt(a.lower_bound)}  "
                f"δ paid {_fmt(a.delta_paid)}"
                + (f"  reuse {a.reuse_count}" if a.reuse_count else "")
            )
            print(table.render_ascii(args.width))
        hidden = len(tables) - args.max_periods
        if hidden > 0:
            print(f"\n({hidden} more {label} strips hidden; --max-periods)")
    if att.online_tables:
        online = {
            k.removeprefix("online_"): v
            for k, v in agg.items()
            if k.startswith("online_")
        }
        print(
            f"\nonline pass: reuse {online['reuse_count']}  "
            f"δ avoided {_fmt(online['delta_avoided'])}  "
            f"δ paid {_fmt(online['delta_paid'])}"
        )

    if args.html:
        path = save_html(att, args.html)
        print(f"\nwrote HTML report: {path}")
    if args.trace:
        path = tracer.save(args.trace)
        spans = len(tracer.spans())
        print(f"wrote Chrome trace ({spans} spans): {path}")
        print("  open at https://ui.perfetto.dev")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
