"""Two-tier schedule cache keyed by support pattern and quantized weights.

Phase-cycling traffic (MoE routing that revisits a small set of expert
assignments, periodic collective phases) re-presents the same demand
*structure* every few periods. Decomposition is the expensive stage of the
pipeline, and its output is reusable in two grades:

- **Exact tier** — key = (support pattern, weights quantized to a relative
  grid). A hit returns the stored ``ParallelSchedule`` verbatim after a
  coverage validation against the live matrix (tolerance = one quantization
  step, which same-key matrices satisfy by construction). Zero solve work.
- **Support tier** — key = support pattern only. A hit replays the stored
  permutations: ``refine_greedy`` *starting from the stored weights* tops
  them up to cover the live matrix (starting from the stored alphas rather
  than zero is load-bearing — re-refining overlapping permutations from
  zero over-provisions badly), then LPT + EQUALIZE rebuild the schedule.
  A quality gate rejects the replay when its total weight exceeds the
  stored fresh-solve efficiency by more than ``ratio_slack``, so a stale
  structure can never silently serve a bloated schedule.

This is the host-side generalization of the device-side support cache in
``core.jaxopt.online_jax`` (same key, same gates); the server consults it
before dispatching to the device, so cache hits cost microseconds and
never occupy the accelerator.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from ..core.decompose import Decomposition, refine_greedy
from ..core.equalize import equalize
from ..core.schedule import ParallelSchedule, schedule_lpt


def _max_line_sum(D: np.ndarray) -> float:
    """max row/col sum — the total-weight lower bound of any cover."""
    return float(max(D.sum(axis=1).max(), D.sum(axis=0).max(), 0.0))


def support_key(D: np.ndarray) -> bytes:
    """Canonical bytes key for the boolean support pattern of ``D``."""
    S = np.asarray(D) > 0
    return S.shape[0].to_bytes(4, "little") + np.packbits(S).tobytes()


def _quant_scale(D: np.ndarray, quant_rel: float) -> float:
    """Quantization step, itself snapped to a coarse log2 grid of D's max.

    Snapping the step keeps near-identical matrices (multiplicative drift
    well under one grid cell) on the *same* grid; without it every matrix
    would define its own step and exact-tier keys would never collide.
    """
    m = float(np.asarray(D).max())
    if m <= 0:
        return quant_rel
    snapped = 2.0 ** (round(4.0 * np.log2(m)) / 4.0)
    return quant_rel * snapped


def exact_key(D: np.ndarray, quant_rel: float) -> tuple[bytes, bytes]:
    D = np.asarray(D, dtype=np.float64)
    step = _quant_scale(D, quant_rel)
    q = np.round(D / step).astype(np.int64)
    return support_key(D), q.tobytes()


@dataclass
class CacheResult:
    """A schedule served from the cache instead of the solver."""

    schedule: ParallelSchedule
    makespan: float
    num_configs: int
    tier: str  # "exact" | "support"


@dataclass
class _SupportEntry:
    perms: list[np.ndarray]
    alphas: list[float]
    ratio: float  # fresh-solve total_weight / max line sum — quality ref


@dataclass
class CacheStats:
    hits_exact: int = 0
    hits_support: int = 0
    misses: int = 0
    inserts: int = 0
    rejected_quality: int = 0

    @property
    def hits(self) -> int:
        return self.hits_exact + self.hits_support

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else float("nan")


class ScheduleCache:
    """Host-side two-tier schedule cache (exact + support pattern).

    ``lookup`` returns a ``CacheResult`` or None; ``insert`` records a
    fresh solve's decomposition (and full schedule for the exact tier).
    Both tiers are FIFO-bounded at ``capacity`` entries; re-inserting an
    existing key updates it in place without consuming a slot.
    """

    def __init__(
        self,
        capacity: int = 64,
        *,
        quant_rel: float = 1e-3,
        ratio_slack: float = 0.1,
    ) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.quant_rel = float(quant_rel)
        self.ratio_slack = float(ratio_slack)
        self._exact: OrderedDict[tuple, tuple[ParallelSchedule, float]] = (
            OrderedDict()
        )
        self._support: OrderedDict[bytes, _SupportEntry] = OrderedDict()
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._support)

    def lookup(
        self,
        D: np.ndarray,
        s: int,
        delta: float,
        *,
        do_equalize: bool = True,
        merge_aware: bool = False,
    ) -> CacheResult | None:
        D = np.asarray(D, dtype=np.float64)
        ek = exact_key(D, self.quant_rel)
        hit = self._exact.get(ek)
        if hit is not None:
            sched, step = hit
            if sched.delta == float(delta) and sched.s == s:
                try:
                    sched.validate(D, tol=1.01 * step + 1e-9)
                except AssertionError:
                    pass
                else:
                    self.stats.hits_exact += 1
                    return CacheResult(
                        schedule=sched,
                        makespan=sched.makespan(),
                        num_configs=sched.num_configs(),
                        tier="exact",
                    )
        entry = self._support.get(support_key(D))
        if entry is not None:
            res = self._replay(D, s, delta, entry, do_equalize, merge_aware)
            if res is not None:
                self.stats.hits_support += 1
                return res
        self.stats.misses += 1
        return None

    def _replay(
        self,
        D: np.ndarray,
        s: int,
        delta: float,
        entry: _SupportEntry,
        do_equalize: bool,
        merge_aware: bool,
    ) -> CacheResult | None:
        alphas = refine_greedy(D, entry.alphas, entry.perms)
        dec = Decomposition(
            perms=[p for p, a in zip(entry.perms, alphas) if a > 0],
            alphas=[a for a in alphas if a > 0],
        )
        tol = 1e-9 * max(float(D.max()), 1.0)
        if not dec.covers(D, tol=tol):
            return None  # pragma: no cover - same support always replays
        line = _max_line_sum(D)
        ratio = dec.total_weight() / line if line > 0 else 1.0
        if ratio > entry.ratio * (1.0 + self.ratio_slack):
            self.stats.rejected_quality += 1
            return None
        sched = schedule_lpt(dec, s, float(delta))
        if do_equalize:
            sched = equalize(sched, merge_aware=merge_aware)
        return CacheResult(
            schedule=sched,
            makespan=sched.makespan(),
            num_configs=sched.num_configs(),
            tier="support",
        )

    def insert(
        self,
        D: np.ndarray,
        schedule: ParallelSchedule,
        decomposition: Decomposition | None = None,
    ) -> None:
        """Record a fresh solve. The decomposition defaults to the union of
        the schedule's per-switch (perm, weight) lists — always available,
        even for lazily-materialized device schedules."""
        D = np.asarray(D, dtype=np.float64)
        if decomposition is None:
            perms: list[np.ndarray] = []
            alphas: list[float] = []
            for sw in schedule.switches:
                perms.extend(np.asarray(p) for p in sw.perms)
                alphas.extend(float(a) for a in sw.alphas)
            decomposition = Decomposition(perms=perms, alphas=alphas)
        line = _max_line_sum(D)
        ratio = (
            decomposition.total_weight() / line if line > 0 else 1.0
        )
        ek = exact_key(D, self.quant_rel)
        step = _quant_scale(D, self.quant_rel)
        self._put(self._exact, ek, (schedule, step))
        self._put(
            self._support,
            support_key(D),
            _SupportEntry(
                perms=[np.asarray(p) for p in decomposition.perms],
                alphas=[float(a) for a in decomposition.alphas],
                ratio=ratio,
            ),
        )
        self.stats.inserts += 1

    def _put(self, store: OrderedDict, key, value) -> None:
        if key in store:
            store[key] = value  # update in place, keep FIFO position
            return
        while len(store) >= self.capacity:
            store.popitem(last=False)
        store[key] = value
