"""Always-on schedule server: async double-buffered dispatch + install.

The serving loop a fabric controller actually runs is a pipeline with two
stations: *solve* period t+1 (device) and *install* period t (program the
OCSes and wait for the switch ACK — ``install_latency_s``, modeled here as
a sleep since it is pure I/O from the host's perspective). A synchronous
controller pays solve + install every cycle; this server overlaps them:

    dispatch(batch t+1)      # enqueue the fused device call — returns
                             # immediately (JAX dispatches asynchronously)
    install(batch t)         # collect t's results, program switches; the
                             # install wait runs concurrently with t+1's
                             # device solve
    inflight = batch t+1

so the steady-state cycle costs max(solve, install) instead of their sum.
There is no ``jax.block_until_ready`` anywhere in the handoff — the only
synchronization is ``PendingBatch.collect()`` reading the result buffers.
``mode="sync"`` is the deterministic fallback (identical results, serial
timing), used automatically when the JAX dispatch path is unavailable.

Before dispatching, each admitted request consults the host
``ScheduleCache`` — phase-cycling traffic is served from the cache in
microseconds without occupying the device. DEGRADED requests (over-rate
tenants, see ``admission``) are grouped into their own dispatches and
solved without EQUALIZE; their schedules are *not* inserted into the
cache, so degraded quality never leaks into admitted traffic. The queue
drains round-robin across tenants.
"""

from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field

import numpy as np

from ..api import SolveOptions, SolveReport, solve_many
from ..obs.metrics import ServeMetrics
from ..obs.trace import get_tracer
from .admission import ADMIT, SHED, AdmissionController
from .cache import CacheResult, ScheduleCache

try:
    from ..api.jax_backend import PendingBatch, dispatch_many_jax
except Exception:  # pragma: no cover - jax missing
    PendingBatch = None  # type: ignore[assignment]
    dispatch_many_jax = None


@dataclass
class _Request:
    ticket: int
    tenant: str
    D: np.ndarray
    submit_t: float
    degraded: bool


@dataclass
class _Inflight:
    """One dispatched batch: device work plus its cache-served siblings."""

    device_reqs: list[_Request]
    pending: "PendingBatch | None"  # None → sync-fallback solve at install
    cached: list[tuple[_Request, CacheResult]]
    degraded: bool
    dispatch_t: float


@dataclass
class ServeResult:
    """What a client gets back for one ticket."""

    ticket: int
    tenant: str
    source: str  # "device" | "cache:exact" | "cache:support"
    makespan: float
    num_configs: int
    degraded: bool
    report: SolveReport | None
    timings: dict[str, float] = field(default_factory=dict)


class ScheduleServer:
    """Multi-tenant scheduling service with admission, cache, and SLOs.

    ``submit`` returns ``(ticket, verdict)`` — SHED tickets are dropped
    (the client keeps its previous schedule); everything else lands in a
    per-tenant queue. ``step`` runs one double-buffer cycle; ``drain``
    runs until idle. Completed work appears in ``results[ticket]``.
    """

    def __init__(
        self,
        s: int,
        delta: float,
        *,
        mode: str = "async",
        solver: str = "spectra_jax",
        options: SolveOptions | None = None,
        install_latency_s: float = 0.0,
        max_batch: int = 8,
        admission: AdmissionController | None = None,
        cache: ScheduleCache | None = None,
        metrics: ServeMetrics | None = None,
    ) -> None:
        if mode not in ("async", "sync"):
            raise ValueError(f"mode must be 'async' or 'sync', got {mode!r}")
        self.s = int(s)
        self.delta = float(delta)
        self.solver = solver
        self.options = options or SolveOptions()
        self.install_latency_s = float(install_latency_s)
        self.max_batch = int(max_batch)
        self.admission = admission
        self.cache = cache
        self.metrics = metrics or ServeMetrics()
        use_jax = solver == "spectra_jax" and dispatch_many_jax is not None
        # Async needs the dispatch/collect split of the JAX backend; other
        # solvers fall back to the deterministic synchronous path.
        self.mode = mode if use_jax else "sync"
        self._use_jax = use_jax
        self._queues: "OrderedDict[str, deque[_Request]]" = OrderedDict()
        self._rr = 0
        self._inflight: _Inflight | None = None
        self._next_ticket = 0
        self.results: dict[int, ServeResult] = {}
        self.shed_tickets: list[int] = []
        self._degraded_options = SolveOptions(
            validate=self.options.validate,
            validate_tol=self.options.validate_tol,
            compute_lb=self.options.compute_lb,
            extra={**self.options.extra, "equalize": False},
        )

    # ------------------------------------------------------------- intake
    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    @property
    def inflight(self) -> bool:
        return self._inflight is not None

    def has_work(self) -> bool:
        return len(self) > 0 or self.inflight

    def submit(
        self, tenant: str, D: np.ndarray, now: float | None = None
    ) -> tuple[int, str]:
        """Admit one demand matrix; returns (ticket, verdict)."""
        D = np.asarray(D, dtype=np.float64)
        if D.ndim != 2 or D.shape[0] != D.shape[1]:
            raise ValueError(f"demand matrix must be square, got {D.shape}")
        ticket = self._next_ticket
        self._next_ticket += 1
        if now is None:
            now = time.perf_counter()
        verdict = (
            self.admission.admit(tenant, len(self), now)
            if self.admission is not None
            else ADMIT
        )
        self.metrics.count_verdict(verdict)
        if verdict == SHED:
            self.shed_tickets.append(ticket)
            return ticket, verdict
        self._queues.setdefault(tenant, deque()).append(
            _Request(
                ticket=ticket,
                tenant=tenant,
                D=D,
                submit_t=time.perf_counter(),
                degraded=verdict != ADMIT,
            )
        )
        return ticket, verdict

    # ------------------------------------------------------------ serving
    def _next_batch(self) -> list[_Request]:
        """Round-robin across tenants; one (shape, degraded) group/batch.

        Only a tenant's *head* request can join (per-tenant FIFO); the
        first head taken defines the group, and one full rotation collects
        matching heads up to ``max_batch``.
        """
        tenants = list(self._queues.keys())
        k = len(tenants)
        batch: list[_Request] = []
        group: tuple[tuple[int, ...], bool] | None = None
        progress = True
        # One head per tenant per rotation — a chatty tenant's backlog
        # can top a batch up, but never before every tenant's head.
        while progress and len(batch) < self.max_batch:
            progress = False
            for i in range(k):
                if len(batch) >= self.max_batch:
                    break
                t = tenants[(self._rr + i) % k]
                q = self._queues[t]
                if not q:
                    continue
                head = q[0]
                sig = (head.D.shape, head.degraded)
                if group is None:
                    group = sig
                if sig != group:
                    continue
                batch.append(q.popleft())
                progress = True
        if k:
            self._rr = (self._rr + 1) % k
        for t in tenants:
            if not self._queues[t]:
                del self._queues[t]
        return batch

    def _dispatch(self, batch: list[_Request]) -> _Inflight:
        tracer = get_tracer()
        dispatch_span = tracer.span(
            "serve.dispatch",
            {"batch": len(batch)} if tracer.enabled else None,
        )
        with dispatch_span:
            return self._dispatch_inner(batch)

    def _dispatch_inner(self, batch: list[_Request]) -> _Inflight:
        degraded = batch[0].degraded
        cached: list[tuple[_Request, CacheResult]] = []
        device: list[_Request] = []
        for req in batch:
            hit = None
            if self.cache is not None and not degraded:
                hit = self.cache.lookup(
                    req.D,
                    self.s,
                    self.delta,
                    do_equalize=bool(self.options.extra.get("equalize", True)),
                    merge_aware=bool(
                        self.options.extra.get("merge_aware", False)
                    ),
                )
                if hit is None:
                    self.metrics.cache_miss += 1
                elif hit.tier == "exact":
                    self.metrics.cache_hit_exact += 1
                else:
                    self.metrics.cache_hit_support += 1
            if hit is not None:
                cached.append((req, hit))
            else:
                device.append(req)
        options = self._degraded_options if degraded else self.options
        pending = None
        if device and self._use_jax:
            pending = dispatch_many_jax(
                np.stack([r.D for r in device]), self.s, self.delta, options
            )
        return _Inflight(
            device_reqs=device,
            pending=pending,
            cached=cached,
            degraded=degraded,
            dispatch_t=time.perf_counter(),
        )

    def _install(self, flight: _Inflight) -> None:
        """Collect the flight's results and program the switches.

        The install wait (OCS programming + ACK) is host-side I/O — the
        sleep releases the core, so in async mode the *next* flight's
        device solve proceeds underneath it.
        """
        tracer = get_tracer()
        install_span = tracer.span(
            "serve.install",
            {"device": len(flight.device_reqs), "cached": len(flight.cached)}
            if tracer.enabled
            else None,
        )
        with install_span:
            self._install_inner(flight)

    def _install_inner(self, flight: _Inflight) -> None:
        reports: list[SolveReport] = []
        if flight.pending is not None:
            reports = flight.pending.collect()
        elif flight.device_reqs:
            options = (
                self._degraded_options if flight.degraded else self.options
            )
            reports = solve_many(
                [r.D for r in flight.device_reqs],
                self.s,
                self.delta,
                solver=self.solver,
                options=options,
            )
        collect_t = time.perf_counter()
        device_s = collect_t - flight.dispatch_t
        if self.install_latency_s > 0:
            time.sleep(self.install_latency_s)
        done_t = time.perf_counter()
        install_s = done_t - collect_t
        self.metrics.observe("install", install_s)
        self.metrics.batches += 1

        for req, rep in zip(flight.device_reqs, reports):
            if self.cache is not None and not flight.degraded:
                self.cache.insert(req.D, rep.schedule, rep.decomposition)
            self._record(
                req, done_t, device_s,
                source="device", makespan=rep.makespan,
                num_configs=rep.num_configs, report=rep,
            )
        for req, hit in flight.cached:
            self._record(
                req, done_t, device_s=0.0,
                source=f"cache:{hit.tier}", makespan=hit.makespan,
                num_configs=hit.num_configs, report=None,
            )

    def _record(
        self,
        req: _Request,
        done_t: float,
        device_s: float,
        *,
        source: str,
        makespan: float,
        num_configs: int,
        report: SolveReport | None,
    ) -> None:
        queue_wait = max(0.0, done_t - req.submit_t - device_s
                         - self.install_latency_s)
        timings = {
            "queue_wait_s": queue_wait,
            "device_s": device_s,
            "e2e_s": done_t - req.submit_t,
        }
        self.metrics.observe("queue_wait", queue_wait)
        self.metrics.observe("device", device_s)
        self.metrics.observe("e2e", timings["e2e_s"])
        self.metrics.schedules += 1
        self.results[req.ticket] = ServeResult(
            ticket=req.ticket,
            tenant=req.tenant,
            source=source,
            makespan=float(makespan),
            num_configs=int(num_configs),
            degraded=req.degraded,
            report=report,
            timings=timings,
        )

    def step(self) -> bool:
        """One serving cycle; returns False when there was nothing to do.

        Async: dispatch the next batch *first*, then install the previous
        one (its install wait overlaps the new batch's device solve).
        Sync: dispatch and install back-to-back.
        """
        batch = self._next_batch()
        if not batch and self._inflight is None:
            return False
        if self.mode == "sync":
            if batch:
                self._install(self._dispatch(batch))
            return True
        flight = self._dispatch(batch) if batch else None
        if self._inflight is not None:
            self._install(self._inflight)
        self._inflight = flight
        return True

    def drain(self) -> dict[int, ServeResult]:
        """Serve until queue and pipeline are empty; returns all results."""
        while self.has_work():
            self.step()
        return self.results
