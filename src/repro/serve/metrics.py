"""SLO observability for the scheduling control plane.

The implementation moved to ``repro.obs.metrics`` so serving, scenarios,
and benchmarks share one metrics vocabulary; this module re-exports the
serving-facing names unchanged for compatibility. Import from
``repro.obs`` for new code.
"""

from __future__ import annotations

from ..obs.metrics import STAGES, LatencyHistogram, ServeMetrics

__all__ = ["STAGES", "LatencyHistogram", "ServeMetrics"]
