"""SLO observability for the scheduling control plane.

Serving a fabric means promising *when* schedules arrive, not just that
they are optimal — so the control plane records per-stage latencies
(submit→dispatch queue wait, device solve, install) as log-spaced
histograms cheap enough to keep always-on, plus the counters an operator
alarms on: admission verdicts (admitted / degraded / shed), cache tier
hits, and sustained schedules/sec. Everything exports as a plain dict so
``benchmarks/bench_serve.py`` can write it straight to JSON and CI can
gate on the numbers.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field


class LatencyHistogram:
    """Fixed log-spaced latency histogram (seconds).

    Bins span ``lo``..``hi`` with ``per_decade`` geometric bins per decade;
    observations clamp into the edge bins, so no sample is ever dropped.
    Quantiles interpolate within the winning bin (geometric), which is
    accurate to one bin width — plenty for p50/p99 SLO gating — while
    ``observe`` stays O(1) with no sample retention.
    """

    def __init__(
        self,
        lo: float = 1e-6,
        hi: float = 100.0,
        per_decade: int = 8,
    ) -> None:
        if not (0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got {lo}, {hi}")
        self.lo = float(lo)
        self.hi = float(hi)
        decades = math.log10(hi / lo)
        self._nbins = max(1, int(math.ceil(decades * per_decade)))
        self._scale = self._nbins / math.log(hi / lo)
        self._counts = [0] * self._nbins
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, seconds: float) -> None:
        x = float(seconds)
        self.count += 1
        self.sum += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)
        if x <= self.lo:
            b = 0
        elif x >= self.hi:
            b = self._nbins - 1
        else:
            b = min(int(self._scale * math.log(x / self.lo)), self._nbins - 1)
        self._counts[b] += 1

    def _edge(self, b: int) -> float:
        return self.lo * math.exp(b / self._scale)

    def percentile(self, p: float) -> float:
        """p in [0, 100]; NaN when empty. Clamped to the observed min/max."""
        if self.count == 0:
            return math.nan
        target = p / 100.0 * self.count
        cum = 0
        for b, c in enumerate(self._counts):
            cum += c
            if cum >= target:
                # Geometric midpoint-ish interpolation inside the bin.
                frac = 1.0 if c == 0 else 1.0 - (cum - target) / c
                val = self._edge(b) * math.exp(frac / self._scale)
                return min(max(val, self.min), self.max)
        return self.max  # pragma: no cover - cum always reaches count

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def export(self) -> dict:
        return {
            "count": self.count,
            "mean_s": self.mean,
            "min_s": self.min if self.count else math.nan,
            "max_s": self.max if self.count else math.nan,
            "p50_s": self.percentile(50),
            "p90_s": self.percentile(90),
            "p99_s": self.percentile(99),
        }


# The per-request pipeline stages the server times. "queue_wait" is
# submit→dispatch, "device" is dispatch→results-collected, "install" is the
# OCS programming/ACK latency per installed batch, "e2e" is submit→installed.
STAGES = ("queue_wait", "device", "install", "e2e")


@dataclass
class ServeMetrics:
    """Always-on counters + stage histograms for one server instance."""

    stages: dict[str, LatencyHistogram] = field(
        default_factory=lambda: {name: LatencyHistogram() for name in STAGES}
    )
    admitted: int = 0
    degraded: int = 0
    shed: int = 0
    cache_hit_exact: int = 0
    cache_hit_support: int = 0
    cache_miss: int = 0
    batches: int = 0
    schedules: int = 0
    _t0: float = field(default_factory=time.perf_counter)

    def observe(self, stage: str, seconds: float) -> None:
        self.stages[stage].observe(seconds)

    def count_verdict(self, verdict: str) -> None:
        if verdict == "ADMIT":
            self.admitted += 1
        elif verdict == "DEGRADED":
            self.degraded += 1
        elif verdict == "SHED":
            self.shed += 1
        else:
            raise ValueError(f"unknown admission verdict {verdict!r}")

    @property
    def cache_hits(self) -> int:
        return self.cache_hit_exact + self.cache_hit_support

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_miss
        return self.cache_hits / total if total else math.nan

    @property
    def elapsed_s(self) -> float:
        return time.perf_counter() - self._t0

    @property
    def schedules_per_sec(self) -> float:
        dt = self.elapsed_s
        return self.schedules / dt if dt > 0 else math.nan

    def export(self) -> dict:
        """JSON-safe snapshot: counters, rates, and per-stage histograms."""
        return {
            "admitted": self.admitted,
            "degraded": self.degraded,
            "shed": self.shed,
            "cache_hit_exact": self.cache_hit_exact,
            "cache_hit_support": self.cache_hit_support,
            "cache_miss": self.cache_miss,
            "cache_hit_rate": self.cache_hit_rate,
            "batches": self.batches,
            "schedules": self.schedules,
            "elapsed_s": self.elapsed_s,
            "schedules_per_sec": self.schedules_per_sec,
            "stages": {k: h.export() for k, h in self.stages.items()},
        }
