"""Per-tenant stateful sessions for the scheduling control plane.

A tenant here is one training job (or one pod's collective group): its
demand evolves period to period, so its switch state — installed
configurations, warm-start permutations, auction prices, and the
device-side support-pattern cache — must persist *per tenant*, never
shared. ``TenantSession`` wraps the stateful ``OnlineSession`` with the
serving knobs threaded through ``SolveOptions.extra`` (``cache_size`` for
the device cache carried in the scan state, ``warm_prices`` for auction
price reuse) and keeps the per-tenant reuse accounting the metrics layer
reports.

``SessionManager`` owns the tenant → session map and drains pending
per-tenant demands in round-robin order, so one tenant submitting a burst
of periods cannot starve the rest — the fairness half of admission
control, applied to the stateful path. Sessions with different fabric
sizes n coexist (ragged shape buckets): each session's state is its own,
and the device recompiles once per distinct (n, s) as usual.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from ..api import SolveOptions, SolveReport
from .engine import OnlineSession


def _online_options(
    base: SolveOptions,
    *,
    cache_size: int,
    warm_prices: bool,
) -> SolveOptions:
    extra = dict(base.extra)
    extra.setdefault("cache_size", int(cache_size))
    extra.setdefault("warm_prices", bool(warm_prices))
    return SolveOptions(
        validate=base.validate,
        validate_tol=base.validate_tol,
        compute_lb=base.compute_lb,
        extra=extra,
    )


@dataclass
class TenantSession:
    """One tenant's always-on scheduling session.

    Thin stateful wrapper: ``step`` schedules one controller period
    against the carried state; ``stats`` summarizes how much of the work
    was served from reuse (warm decompositions, device cache hits, δ
    avoided) — the quantities the serving metrics export per tenant.
    """

    tenant: str
    s: int
    delta: float
    solver: str = "spectra_online_jax"
    cache_size: int = 8
    warm_prices: bool = False
    options: SolveOptions = field(default_factory=SolveOptions)

    def __post_init__(self) -> None:
        self._session = OnlineSession(
            s=self.s,
            delta=self.delta,
            solver=self.solver,
            options=_online_options(
                self.options,
                cache_size=self.cache_size,
                warm_prices=self.warm_prices,
            ),
        )
        self.pending: deque[np.ndarray] = deque()

    def __len__(self) -> int:
        return len(self._session)

    @property
    def reports(self) -> list[SolveReport]:
        return self._session.reports

    @property
    def state(self):
        return self._session.state

    def step(self, D: np.ndarray) -> SolveReport:
        return self._session.step(D)

    def stats(self) -> dict:
        reps = self.reports
        n = len(reps)
        warm = sum(bool(r.extras.get("warm", False)) for r in reps)
        cache = sum(bool(r.extras.get("cache_hit", False)) for r in reps)
        return {
            "tenant": self.tenant,
            "periods": n,
            "warm": warm,
            "warm_rate": warm / n if n else float("nan"),
            "device_cache_hits": cache,
            "device_cache_hit_rate": cache / n if n else float("nan"),
            "delta_avoided": self._session.total_delta_avoided,
        }


class SessionManager:
    """Tenant → session registry with round-robin fair draining.

    ``submit`` queues one period of demand for a tenant (opening its
    session on first sight); ``drain_round`` serves at most one queued
    period per tenant, cycling from wherever the previous round stopped,
    and returns the ``(tenant, report)`` pairs served. Stateful periods
    are inherently sequential per tenant, so fairness — not batching — is
    the scheduling lever on this path.
    """

    def __init__(
        self,
        s: int,
        delta: float,
        *,
        solver: str = "spectra_online_jax",
        cache_size: int = 8,
        warm_prices: bool = False,
        options: SolveOptions | None = None,
    ) -> None:
        self.s = int(s)
        self.delta = float(delta)
        self.solver = solver
        self.cache_size = int(cache_size)
        self.warm_prices = bool(warm_prices)
        self.options = options or SolveOptions()
        self.sessions: dict[str, TenantSession] = {}
        self._order: list[str] = []
        self._rr = 0

    def session(self, tenant: str) -> TenantSession:
        sess = self.sessions.get(tenant)
        if sess is None:
            sess = TenantSession(
                tenant=tenant,
                s=self.s,
                delta=self.delta,
                solver=self.solver,
                cache_size=self.cache_size,
                warm_prices=self.warm_prices,
                options=self.options,
            )
            self.sessions[tenant] = sess
            self._order.append(tenant)
        return sess

    def submit(self, tenant: str, D: np.ndarray) -> None:
        self.session(tenant).pending.append(np.asarray(D, dtype=np.float64))

    @property
    def backlog(self) -> int:
        return sum(len(s.pending) for s in self.sessions.values())

    def drain_round(self) -> list[tuple[str, SolveReport]]:
        served: list[tuple[str, SolveReport]] = []
        k = len(self._order)
        for i in range(k):
            tenant = self._order[(self._rr + i) % k]
            sess = self.sessions[tenant]
            if sess.pending:
                served.append((tenant, sess.step(sess.pending.popleft())))
        self._rr = (self._rr + 1) % k if k else 0
        return served

    def drain(self) -> list[tuple[str, SolveReport]]:
        """Drain every queued period, one fair round at a time."""
        out: list[tuple[str, SolveReport]] = []
        while self.backlog:
            out.extend(self.drain_round())
        return out

    def stats(self) -> dict:
        return {t: s.stats() for t, s in self.sessions.items()}
