"""Batched decode engine over the model zoo's cache machinery.

Fixed-slot batched serving: a batch of same-length prompts is prefilled by
cache replay (decode_step per position — simple and correct; a production
server would add a fused prefill that emits the KV cache directly, noted
in EXPERIMENTS.md §Perf), then greedy/temperature decoding for
``max_new_tokens``. All steps run under a single jitted serve_step with a
donated cache.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class GenerationResult:
    tokens: np.ndarray       # (B, prompt + generated)
    prompt_len: int
    steps: int


class DecodeEngine:
    def __init__(self, model, params, *, max_len: int = 512):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._step = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t), donate_argnums=(1,)
        )

    def generate(
        self,
        prompts: np.ndarray,  # (B, S0) int32, same length per batch
        max_new_tokens: int,
        *,
        temperature: float = 0.0,
        seed: int = 0,
        enc_out=None,
    ) -> GenerationResult:
        B, S0 = prompts.shape
        total = S0 + max_new_tokens
        if total > self.max_len:
            raise ValueError(f"{total} exceeds engine max_len {self.max_len}")
        cache = self.model.init_cache(self.params, B, self.max_len,
                                      enc_out=enc_out)
        toks = jnp.asarray(prompts, jnp.int32)
        logits = None
        for t in range(S0):  # prefill by replay
            logits, cache = self._step(self.params, cache, toks[:, t : t + 1])
        out = [toks]
        key = jax.random.PRNGKey(seed)
        nxt = None
        for i in range(max_new_tokens):
            if nxt is not None:
                logits, cache = self._step(self.params, cache, nxt)
            lg = logits[:, -1]
            if temperature > 0:
                key, k = jax.random.split(key)
                nxt = jax.random.categorical(k, lg / temperature)[:, None]
            else:
                nxt = lg.argmax(-1)[:, None]
            nxt = nxt.astype(jnp.int32)
            out.append(nxt)
        tokens = np.asarray(jnp.concatenate(out, axis=1))
        return GenerationResult(tokens=tokens, prompt_len=S0,
                                steps=S0 + max_new_tokens)
