"""Serving engines: batched LLM decode + batched OCS solver service.

``DecodeEngine`` — fixed-slot batched LLM serving: a batch of same-length
prompts is prefilled by cache replay (decode_step per position — simple and
correct; a production server would add a fused prefill that emits the KV
cache directly, noted in EXPERIMENTS.md §Perf), then greedy/temperature
decoding for ``max_new_tokens``. All steps run under a single jitted
serve_step with a donated cache.

``SolverService`` — the scheduling half of the serving story: clients submit
demand matrices (one per pod/job per controller period) or whole
``repro.scenarios`` demand traces (``submit_trace``: a training run's
(T, n, n) stack, one ticket per period), the service groups same-shape
instances and drains them through the unified ``repro.api.solve_many``. On
the JAX backend each group runs the *fused* DECOMPOSE→SCHEDULE→EQUALIZE
pipeline in one vmapped device call (host schedules materialize lazily per
ticket); numpy solvers loop, optionally across worker processes.
``open_session`` switches to *stateful* (online) mode: switch
configurations carry across calls, matching rounds are served δ-free, and
decompositions warm-start from the previous period.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..api import SolveOptions, SolveReport, solve_many


@dataclass
class GenerationResult:
    tokens: np.ndarray       # (B, prompt + generated)
    prompt_len: int
    steps: int


class DecodeEngine:
    def __init__(self, model, params, *, max_len: int = 512):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._step = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t), donate_argnums=(1,)
        )

    def generate(
        self,
        prompts: np.ndarray,  # (B, S0) int32, same length per batch
        max_new_tokens: int,
        *,
        temperature: float = 0.0,
        seed: int = 0,
        enc_out=None,
    ) -> GenerationResult:
        B, S0 = prompts.shape
        total = S0 + max_new_tokens
        if total > self.max_len:
            raise ValueError(f"{total} exceeds engine max_len {self.max_len}")
        cache = self.model.init_cache(self.params, B, self.max_len,
                                      enc_out=enc_out)
        toks = jnp.asarray(prompts, jnp.int32)
        logits = None
        for t in range(S0):  # prefill by replay
            logits, cache = self._step(self.params, cache, toks[:, t : t + 1])
        out = [toks]
        key = jax.random.PRNGKey(seed)
        nxt = None
        for i in range(max_new_tokens):
            if nxt is not None:
                logits, cache = self._step(self.params, cache, nxt)
            lg = logits[:, -1]
            if temperature > 0:
                key, k = jax.random.split(key)
                nxt = jax.random.categorical(k, lg / temperature)[:, None]
            else:
                nxt = lg.argmax(-1)[:, None]
            nxt = nxt.astype(jnp.int32)
            out.append(nxt)
        tokens = np.asarray(jnp.concatenate(out, axis=1))
        return GenerationResult(tokens=tokens, prompt_len=S0,
                                steps=S0 + max_new_tokens)


@dataclass
class SolverService:
    """Queue-and-drain scheduling service over the unified solver API.

    ``submit`` enqueues a demand matrix and returns a ticket; ``flush``
    solves everything queued — batching same-shape matrices into one
    ``solve_many`` call each (on the JAX backend: one fused
    decompose/schedule/equalize device call per group) — and returns
    ``{ticket: SolveReport}``.
    """

    s: int
    delta: float
    solver: str = "spectra"
    options: SolveOptions = field(default_factory=SolveOptions)
    processes: int | None = None

    def __post_init__(self) -> None:
        self._queue: list[tuple[int, np.ndarray]] = []
        self._next_ticket = 0

    def __len__(self) -> int:
        return len(self._queue)

    def submit(self, D: np.ndarray) -> int:
        D = np.asarray(D, dtype=np.float64)
        if D.ndim != 2 or D.shape[0] != D.shape[1]:
            raise ValueError(f"demand matrix must be square, got {D.shape}")
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queue.append((ticket, D))
        return ticket

    def submit_trace(self, trace) -> list[int]:
        """Enqueue a whole training run: one ticket per controller period.

        ``trace`` is a ``repro.scenarios.DemandTrace`` (or anything with a
        ``.demands`` stack, or a raw ``(T, n, n)`` array). All periods of a
        trace share one shape, so a subsequent ``flush`` drains them — plus
        anything else queued at that shape — through a single batched
        ``solve_many`` group (one fused device call on the JAX backend).

        The service's ``delta`` is in demand-time units, so byte-denominated
        traces are rejected: normalize first (``trace.normalized()`` /
        ``run_scenario``) rather than mixing byte magnitudes with a
        units-denominated δ.
        """
        spec = getattr(trace, "spec", None)
        if spec is not None and getattr(spec, "units", "demand") == "bytes":
            raise ValueError(
                "trace is denominated in bytes; normalize it to demand units "
                "(DemandTrace.normalized or run_scenario) before submitting"
            )
        if getattr(trace, "varying_delta", False):
            # The service solves every ticket at its single scalar delta; a
            # per-period delta_schedule would be silently flattened to it.
            raise ValueError(
                "trace carries a per-period delta_schedule but the service "
                "solves at one delta; use repro.scenarios.run_scenario (or "
                "solve_many with a per-instance delta vector) instead"
            )
        demands = np.asarray(getattr(trace, "demands", trace), dtype=np.float64)
        if demands.ndim != 3 or demands.shape[1] != demands.shape[2]:
            raise ValueError(
                f"trace must be a (T, n, n) demand stack, got {demands.shape}"
            )
        return [self.submit(D) for D in demands]

    def flush(self) -> dict[int, SolveReport]:
        if not self._queue:
            return {}
        pending, self._queue = self._queue, []
        try:
            # solve_many shape-buckets ragged submissions itself (one fused
            # device call per distinct shape on the JAX backend) and returns
            # reports in submission order.
            reports = solve_many(
                [D for _, D in pending],
                self.s,
                self.delta,
                solver=self.solver,
                options=self.options,
                processes=self.processes,
            )
        except Exception:
            # One bad matrix must not drop the other pods' requests. Nothing
            # from this flush has been delivered, so every submission goes
            # back on the queue to be re-solved by the next flush.
            self._queue = list(pending) + self._queue
            raise
        return {ticket: rep for (ticket, _), rep in zip(pending, reports)}

    def open_session(self, *, solver: str | None = None) -> "OnlineSession":
        """Open a *stateful* scheduling session (online cross-period mode).

        Unlike ``submit``/``flush`` — which treats every matrix as an
        independent instance — a session carries the switch state between
        calls: each ``step`` pays no δ for configurations left installed by
        the previous one, and warm-starts its decomposition from it. Periods
        are inherently sequential (state threads through), so a session
        solves per call rather than batching.

        ``solver`` defaults to the online variant of the service's solver
        (``spectra → spectra_online``, ``spectra_jax →
        spectra_online_jax``); any registered ``spectra_online*`` name is
        accepted.
        """
        if solver is None:
            solver = {
                "spectra": "spectra_online",
                "spectra_jax": "spectra_online_jax",
            }.get(self.solver, "spectra_online")
        return OnlineSession(
            s=self.s, delta=self.delta, solver=solver, options=self.options
        )


@dataclass
class OnlineSession:
    """A stateful solver session: one controller period per ``step``.

    Thin wrapper over the ``spectra_online[_jax]`` registry solvers that
    threads ``SolveOptions.extra["online"]`` automatically. ``reports``
    keeps the per-period history; ``total_delta_avoided`` totals the reuse
    credit earned so far.
    """

    s: int
    delta: float
    solver: str = "spectra_online"
    options: SolveOptions = field(default_factory=SolveOptions)

    def __post_init__(self) -> None:
        self._state = None
        self.reports: list[SolveReport] = []

    def __len__(self) -> int:
        return len(self.reports)

    @property
    def state(self):
        """The carried switch state (None before the first step)."""
        return self._state

    @property
    def total_delta_avoided(self) -> float:
        return float(
            sum(r.extras.get("delta_avoided", 0.0) for r in self.reports)
        )

    def step(self, D: np.ndarray) -> SolveReport:
        """Schedule one period against the carried state and advance it."""
        from ..api import Problem, solve

        D = np.asarray(D, dtype=np.float64)
        extra = dict(self.options.extra)
        extra["online"] = self._state
        options = SolveOptions(
            validate=self.options.validate,
            validate_tol=self.options.validate_tol,
            compute_lb=self.options.compute_lb,
            extra=extra,
        )
        report = solve(
            Problem(D, self.s, self.delta), solver=self.solver, options=options
        )
        self._state = report.extras["online_state"]
        self.reports.append(report)
        return report

    def run(self, trace) -> list[SolveReport]:
        """Step through a whole trace (``DemandTrace`` or (T, n, n) array).

        The session solves every period at its single scalar ``delta`` in
        demand units, so — exactly like ``SolverService.submit_trace`` —
        byte-denominated traces and per-period ``delta_schedule`` traces are
        rejected with a clear error rather than silently mis-priced.
        """
        spec = getattr(trace, "spec", None)
        if spec is not None and getattr(spec, "units", "demand") == "bytes":
            raise ValueError(
                "trace is denominated in bytes; normalize it to demand units "
                "(DemandTrace.normalized or run_scenario) before stepping a "
                "session through it"
            )
        if getattr(trace, "varying_delta", False):
            raise ValueError(
                "trace carries a per-period delta_schedule but the session "
                "solves at one delta; use repro.scenarios.run_scenario(..., "
                "online=True) instead"
            )
        demands = np.asarray(
            getattr(trace, "demands", trace), dtype=np.float64
        )
        if demands.ndim != 3 or demands.shape[1] != demands.shape[2]:
            raise ValueError(
                f"trace must be a (T, n, n) demand stack, got {demands.shape}"
            )
        return [self.step(D) for D in demands]
