"""Open-loop load generation for the scheduling control plane.

Serving benchmarks need *open-loop* arrivals — requests land on the
server at the times a Poisson process dictates, whether or not the server
has kept up — because closed-loop drivers (submit, wait, repeat) hide
queueing collapse: an overloaded closed-loop server just slows the
client down, while an open-loop one exposes the growing queue, the p99,
and the shed verdicts. CISCO/operator traffic studies and every serving
benchmark (e.g. the LLM serving literature) use open-loop for exactly
this reason.

A ``TenantLoad`` is one tenant's Poisson arrival rate plus the scenario
family its demand matrices are drawn from (``moe_phases`` gives the
phase-cycling traffic the schedule cache serves; ``uniform`` /
``permutations`` give cache-hostile fresh structure). ``make_workload``
merges the tenants' arrival processes into one time-ordered request
list; ``run_open_loop`` replays it against a ``ScheduleServer`` in real
time — submitting strictly by the arrival clock, pumping the server's
double-buffered loop in between — and returns the server's metrics.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Mapping

import numpy as np

from ..scenarios.registry import get_family
from ..scenarios.spec import TrafficSpec


@dataclass(frozen=True)
class TenantLoad:
    """One tenant's offered load: Poisson ``rate`` req/s of ``family``."""

    tenant: str
    rate: float  # mean arrivals per second
    n: int
    family: str = "moe_phases"
    seed: int = 0
    params: Mapping[str, object] = field(default_factory=dict)


@dataclass(frozen=True)
class Arrival:
    t: float  # seconds from workload start
    tenant: str
    D: np.ndarray


def poisson_arrivals(
    rate: float, duration: float, rng: np.random.Generator
) -> np.ndarray:
    """Arrival times of a Poisson(rate) process on [0, duration)."""
    if rate <= 0 or duration <= 0:
        return np.empty((0,))
    # Exponential gaps; draw with headroom, then trim to the horizon.
    est = max(8, int(rate * duration * 2 + 10))
    gaps = rng.exponential(1.0 / rate, size=est)
    times = np.cumsum(gaps)
    while times[-1] < duration:  # pragma: no cover - headroom almost always enough
        more = np.cumsum(rng.exponential(1.0 / rate, size=est)) + times[-1]
        times = np.concatenate([times, more])
    return times[times < duration]


def make_workload(
    tenants: list[TenantLoad],
    duration: float,
    *,
    s: int = 4,
    delta: float = 0.01,
    seed: int = 0,
) -> list[Arrival]:
    """Merge per-tenant Poisson processes into one time-ordered workload.

    The k-th arrival of a tenant carries that tenant's period-k demand
    matrix from its scenario family, so phase-cycling families cycle at
    the tenant's own arrival cadence — exactly the traffic a per-tenant
    schedule cache should serve.
    """
    arrivals: list[Arrival] = []
    for i, tl in enumerate(tenants):
        rng = np.random.default_rng(seed * 1009 + 31 * i + tl.seed)
        times = poisson_arrivals(tl.rate, duration, rng)
        spec = TrafficSpec(
            family=tl.family,
            n=tl.n,
            s=s,
            delta=delta,
            periods=max(1, len(times)),
            seed=tl.seed,
            params=dict(tl.params),
        )
        fam = get_family(tl.family)
        for k, t in enumerate(times):
            demand_rng = np.random.default_rng(
                (seed * 1009 + 31 * i + tl.seed) * 100003 + k
            )
            D, _meta = fam(spec, k, demand_rng)
            arrivals.append(Arrival(t=float(t), tenant=tl.tenant, D=D))
    arrivals.sort(key=lambda a: a.t)
    return arrivals


def tiny_profile(n: int = 8, rate: float = 40.0) -> list[TenantLoad]:
    """CI-sized single-shape profile: one cache-friendly phase-cycling
    tenant plus one cache-hostile tenant at the same n."""
    return [
        TenantLoad("moe-a", rate=rate * 0.6, n=n, family="moe_phases",
                   seed=1, params={"phases": 2}),
        TenantLoad("adhoc", rate=rate * 0.4, n=n, family="uniform", seed=2),
    ]


def mixed_profile(
    n_small: int = 8, n_large: int = 16, rate: float = 30.0
) -> list[TenantLoad]:
    """Mixed-tenant profile with ragged shapes (n_small and n_large)."""
    return [
        TenantLoad("moe-a", rate=rate * 0.4, n=n_small, family="moe_phases",
                   seed=1, params={"phases": 2}),
        TenantLoad("moe-b", rate=rate * 0.3, n=n_large, family="moe_phases",
                   seed=2, params={"phases": 3}),
        TenantLoad("adhoc", rate=rate * 0.3, n=n_small, family="uniform",
                   seed=3),
    ]


def run_open_loop(server, workload: list[Arrival]) -> dict:
    """Replay a workload against a server in real (wall-clock) time.

    Submits each arrival no earlier than its timestamp, pumping the
    server's serving loop whenever there is work and sleeping to the next
    arrival when there is not; drains the pipeline after the last
    arrival. Returns ``server.metrics.export()``.
    """
    t0 = time.perf_counter()
    i = 0
    while i < len(workload) or server.has_work():
        now = time.perf_counter() - t0
        while i < len(workload) and workload[i].t <= now:
            a = workload[i]
            server.submit(a.tenant, a.D, now=now)
            i += 1
        if server.has_work():
            server.step()
        elif i < len(workload):
            time.sleep(min(0.05, max(0.0, workload[i].t - now)))
    return server.metrics.export()


def submit_all(server, workload: list[Arrival]) -> None:
    """Burst-submit a workload (virtual arrival clock, no pacing).

    Used by overload tests: arrival timestamps feed the admission
    controller's token buckets, but nothing waits — the queue bound and
    shed verdicts are exercised immediately.
    """
    for a in workload:
        server.submit(a.tenant, a.D, now=a.t)
