"""Admission control for the scheduling control plane.

A fabric controller is a shared service: one chatty tenant (a job whose
traffic phase-shifts every period) must not starve the others, and a
backlog must surface as an explicit verdict the client can act on rather
than unbounded queueing delay. The policy here is the standard two-knob
one:

- **Bounded queue** — when the server's queue is at ``max_queue``, new
  work is ``SHED`` (client retries next period with its stale schedule;
  for an OCS that is always safe — the previous circuits stay up).
- **Per-tenant token buckets** — each tenant earns ``rate`` submissions
  per second up to a ``burst`` ceiling. An empty bucket does *not* drop
  the request; it returns ``DEGRADED``: the server still schedules it but
  in the cheaper no-EQUALIZE tier, so over-rate tenants pay the quality
  cost of their own burstiness instead of inflating everyone's latency.

Verdicts are plain strings (``"ADMIT" | "DEGRADED" | "SHED"``) so they
serialize into metrics and reports without an enum dance. Time is passed
in explicitly (``now``) — the server uses a monotonic clock, tests use a
virtual one; the controller never reads a wall clock itself.
"""

from __future__ import annotations

from dataclasses import dataclass, field

ADMIT = "ADMIT"
DEGRADED = "DEGRADED"
SHED = "SHED"
VERDICTS = (ADMIT, DEGRADED, SHED)


@dataclass
class TokenBucket:
    """Classic token bucket: ``rate`` tokens/sec, capacity ``burst``.

    Starts full. ``try_take`` refills lazily from the elapsed time, then
    takes one token if available. Deterministic given the ``now`` values
    passed in; never reads a clock.
    """

    rate: float
    burst: float
    tokens: float = field(default=-1.0)
    _last: float = field(default=None)  # type: ignore[assignment]

    def __post_init__(self) -> None:
        if self.rate < 0 or self.burst <= 0:
            raise ValueError(
                f"need rate >= 0 and burst > 0, got {self.rate}, {self.burst}"
            )
        if self.tokens < 0:
            self.tokens = float(self.burst)

    def _refill(self, now: float) -> None:
        if self._last is None:
            self._last = now
            return
        dt = max(0.0, now - self._last)
        self._last = now
        self.tokens = min(self.burst, self.tokens + dt * self.rate)

    def try_take(self, now: float, amount: float = 1.0) -> bool:
        self._refill(now)
        if self.tokens >= amount:
            self.tokens -= amount
            return True
        return False


@dataclass
class AdmissionController:
    """Queue-bound + per-tenant-rate admission policy.

    ``admit(tenant, queue_depth, now)`` returns a verdict string. Shedding
    is checked first (a full queue is a server-wide condition; burning a
    tenant's token for work that is dropped anyway would double-charge
    it), then the tenant's bucket decides ADMIT vs DEGRADED. Buckets are
    created lazily per tenant with the shared ``rate``/``burst`` defaults;
    ``set_tenant_rate`` pins a tenant-specific one.
    """

    rate: float = 100.0
    burst: float = 20.0
    max_queue: int = 64
    buckets: dict[str, TokenBucket] = field(default_factory=dict)

    def bucket(self, tenant: str) -> TokenBucket:
        b = self.buckets.get(tenant)
        if b is None:
            b = self.buckets[tenant] = TokenBucket(self.rate, self.burst)
        return b

    def set_tenant_rate(self, tenant: str, rate: float, burst: float) -> None:
        self.buckets[tenant] = TokenBucket(rate, burst)

    def admit(self, tenant: str, queue_depth: int, now: float) -> str:
        if queue_depth >= self.max_queue:
            return SHED
        if not self.bucket(tenant).try_take(now):
            return DEGRADED
        return ADMIT
