"""Parse collective ops + byte counts out of compiled (SPMD) HLO text.

The compiled module is per-partition, so parsed tensor shapes are per-chip
shards. Wire bytes per chip use standard ring-algorithm factors:

    all-reduce          2·(g−1)/g · operand
    all-gather          (g−1)/g · result
    reduce-scatter      (g−1)/g · operand
    all-to-all          (g−1)/g · operand
    collective-permute  1 · operand
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


@dataclass
class CollectiveStats:
    ops: dict = field(default_factory=lambda: defaultdict(int))
    operand_bytes: dict = field(default_factory=lambda: defaultdict(int))
    wire_bytes: dict = field(default_factory=lambda: defaultdict(float))

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def as_dict(self) -> dict:
        return {
            "ops": dict(self.ops),
            "operand_bytes": dict(self.operand_bytes),
            "wire_bytes": {k: float(v) for k, v in self.wire_bytes.items()},
            "total_operand_bytes": self.total_operand_bytes,
            "total_wire_bytes": self.total_wire_bytes,
        }


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:  # [num_groups, group_size] iota form
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        members = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(members), 1)
    return default


_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[\w\[\],{}]+))\s+("
    + "|".join(_COLLECTIVES)
    + r")(-start|-done)?\("
)


def parse_collectives(hlo_text: str, default_group: int = 2) -> CollectiveStats:
    """Sum collective bytes over a compiled HLO text module (per-chip view).

    In optimized HLO the result type precedes the op name and operands are
    bare ``%names``, so byte counts derive from the *largest* result shape
    (for async tuple results that is the full gathered/reduced tensor; for
    reduce-scatter the operand-shaped tuple member). Wire factors then apply
    uniformly to that max shape.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not ls or "=" not in ls:
            continue
        m_op = _OP_RE.search(ls)
        if not m_op:
            continue
        op = m_op.group(2)
        if m_op.group(3) == "-done":  # async pair: count the -start only
            continue
        shapes = [_shape_bytes(m) for m in _SHAPE_RE.finditer(m_op.group(1))]
        if not shapes:
            continue
        max_bytes = max(shapes)
        g = _group_size(ls, default_group)
        if op == "all-reduce":
            wire = 2.0 * (g - 1) / g * max_bytes
        elif op in ("all-gather", "reduce-scatter", "all-to-all",
                    "ragged-all-to-all"):
            wire = (g - 1) / g * max_bytes
        else:  # collective-permute
            wire = float(max_bytes)
        stats.ops[op] += 1
        stats.operand_bytes[op] += max_bytes
        stats.wire_bytes[op] += wire
    return stats
