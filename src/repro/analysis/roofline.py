"""Three-term roofline from the dry-run's compiled artifact (spec §Roofline).

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = wire_bytes_per_chip / link_bw

(The compiled SPMD module is per-partition, so cost_analysis and the HLO
parse are already per-chip; the spec's "/ chips" is folded in.)

MODEL_FLOPS = 6·N_active·tokens (+ exact attention-matmul FLOPs, windowed
where the arch is windowed); useful_ratio = MODEL_FLOPS_per_chip/HLO_FLOPs
catches remat/redundancy waste. roofline_fraction = ideal compute time on
MODEL_FLOPS over the dominant term — the headline score per cell.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass

import jax

from ..configs.base import ModelConfig, ShapeCfg
from .hlo import parse_collectives

TPU_V5E = {
    "peak_flops_bf16": 197e12,  # per chip
    "hbm_bw": 819e9,            # B/s per chip
    "ici_bw": 50e9,             # B/s per link
}


def count_params(params_shape) -> tuple[int, int]:
    """(total, routed-expert-only) parameter counts from the shape pytree."""
    total, expert = 0, 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        names = [getattr(e, "key", None) for e in path]
        if any(isinstance(k, str) and k.startswith("we_") for k in names):
            expert += n
    return total, expert


def model_flops(cfg: ModelConfig, shape: ShapeCfg, params_shape) -> float:
    """6·N_active·D (+ attention score/PV matmuls), global per step."""
    total, expert = count_params(params_shape)
    n_active = total - expert
    if cfg.moe is not None and expert:
        n_active += expert * cfg.moe.top_k / cfg.moe.num_experts
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    flops = mult * n_active * tokens

    # Attention matmuls (QK^T + PV): 4·B·Hq·dh·Σ_q kv_len(q) per layer (fwd);
    # ×3 for train (fwd+bwd). Σ_q kv: S²/2 causal-global, S·w local, S for
    # a single decode query.
    has_attn = cfg.family in ("dense", "moe", "vlm", "audio", "hybrid")
    if has_attn:
        dh, Hq = cfg.resolved_head_dim, cfg.num_heads
        B, S = shape.global_batch, shape.seq_len
        fwd_mult = 3.0 if shape.kind == "train" else 1.0
        per = (cfg.pattern_local + cfg.pattern_global) if cfg.pattern_local else 1
        n_local = (
            cfg.num_layers * cfg.pattern_local // per if cfg.pattern_local else 0
        )
        n_global = cfg.num_layers + cfg.encoder_layers - n_local
        if cfg.family == "hybrid" and cfg.attn_every:
            n_local, n_global = 0, cfg.num_layers // cfg.attn_every
        w = min(cfg.window or S, S)
        if shape.kind == "decode":
            sum_kv_global, sum_kv_local = float(S), float(w)
        else:
            sum_kv_global, sum_kv_local = S * S / 2.0, float(S) * w
        flops += fwd_mult * 4 * B * Hq * dh * (
            n_global * sum_kv_global + n_local * sum_kv_local
        )
    return flops


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    wire_bytes_per_chip: float
    useful_ratio: float
    roofline_fraction: float
    collectives: dict

    def as_dict(self):
        return asdict(self)


def analyze(compiled, cfg: ModelConfig, shape: ShapeCfg, n_chips: int,
            params_shape, hw: dict = TPU_V5E) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byte_keys = [k for k in cost if k.startswith("bytes accessed")]
    hlo_bytes = max(float(cost[k]) for k in byte_keys) if byte_keys else 0.0
    stats = parse_collectives(compiled.as_text())

    compute_s = flops / hw["peak_flops_bf16"]
    memory_s = hlo_bytes / hw["hbm_bw"]
    collective_s = stats.total_wire_bytes / hw["ici_bw"]
    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, params_shape)
    mf_per_chip = mf / n_chips
    ideal_s = mf_per_chip / hw["peak_flops_bf16"]
    bound = max(terms.values())
    return Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=mf,
        hlo_flops_per_chip=flops,
        hlo_bytes_per_chip=hlo_bytes,
        wire_bytes_per_chip=stats.total_wire_bytes,
        useful_ratio=(mf_per_chip / flops) if flops else 0.0,
        roofline_fraction=(ideal_s / bound) if bound > 0 else 0.0,
        collectives=stats.as_dict(),
    )
