"""SSD chunked-scan Pallas kernel vs exact sequential oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan.ops import ssd_decode_step, ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


def rand_inputs(rng, BH, S, P, N, dtype=jnp.float32):
    xd = jnp.asarray(rng.standard_normal((BH, S, P)), dtype)
    # log-decays in (-0.5, 0): realistic exp(Δ·A) values
    loga = jnp.asarray(-0.5 * rng.random((BH, S)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((BH, S, N)) / np.sqrt(N), dtype)
    C = jnp.asarray(rng.standard_normal((BH, S, N)) / np.sqrt(N), dtype)
    return xd, loga, B, C


@pytest.mark.parametrize(
    "BH,S,P,N",
    [(2, 64, 16, 8), (1, 128, 32, 16), (3, 96, 8, 4), (2, 33, 16, 8)],
)
def test_ssd_matches_ref(BH, S, P, N):
    rng = np.random.default_rng(0)
    xd, loga, B, C = rand_inputs(rng, BH, S, P, N)
    y, hT = ssd_scan(xd, loga, B, C, impl="pallas", interpret=True)
    y_ref, hT_ref = ssd_ref(xd, loga, B, C)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(hT), np.array(hT_ref), rtol=1e-4, atol=1e-4)


def test_ssd_with_initial_state():
    rng = np.random.default_rng(1)
    xd, loga, B, C = rand_inputs(rng, 2, 64, 8, 4)
    h0 = jnp.asarray(rng.standard_normal((2, 4, 8)), jnp.float32)
    y, hT = ssd_scan(xd, loga, B, C, h0, impl="pallas", interpret=True)
    y_ref, hT_ref = ssd_ref(xd, loga, B, C, h0)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(hT), np.array(hT_ref), rtol=1e-4, atol=1e-4)


def test_ssd_chunked_equals_two_halves():
    """Streaming consistency: scan(S) == scan(S/2) ∘ scan(S/2)."""
    rng = np.random.default_rng(2)
    xd, loga, B, C = rand_inputs(rng, 1, 128, 8, 4)
    y_full, hT_full = ssd_scan(xd, loga, B, C, impl="pallas", interpret=True)
    y1, h1 = ssd_scan(xd[:, :64], loga[:, :64], B[:, :64], C[:, :64],
                      impl="pallas", interpret=True)
    y2, h2 = ssd_scan(xd[:, 64:], loga[:, 64:], B[:, 64:], C[:, 64:], h1,
                      impl="pallas", interpret=True)
    np.testing.assert_allclose(
        np.array(jnp.concatenate([y1, y2], axis=1)), np.array(y_full),
        rtol=1e-4, atol=1e-4,
    )
    np.testing.assert_allclose(np.array(h2), np.array(hT_full), rtol=1e-4, atol=1e-4)


def test_ssd_decode_step_matches_scan():
    rng = np.random.default_rng(3)
    xd, loga, B, C = rand_inputs(rng, 2, 16, 8, 4)
    _, hT = ssd_scan(xd, loga, B, C, impl="pallas", interpret=True)
    h = jnp.zeros((2, 4, 8), jnp.float32)
    for t in range(16):
        h, y = ssd_decode_step(h, xd[:, t], loga[:, t], B[:, t], C[:, t])
    np.testing.assert_allclose(np.array(h), np.array(hT), rtol=1e-4, atol=1e-4)


def test_ssd_gradients_flow():
    rng = np.random.default_rng(4)
    xd, loga, B, C = rand_inputs(rng, 1, 32, 8, 4)

    def loss(impl):
        def f(xd, loga, B, C):
            y, _ = ssd_scan(xd, loga, B, C, impl=impl, interpret=True)
            return (y ** 2).sum()
        return f

    g_p = jax.grad(loss("pallas"), argnums=(0, 1, 2, 3))(xd, loga, B, C)
    g_r = jax.grad(loss("reference"), argnums=(0, 1, 2, 3))(xd, loga, B, C)
    for a, b in zip(g_p, g_r):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-3, atol=1e-3)
