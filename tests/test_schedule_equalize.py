"""SCHEDULE (LPT), EQUALIZE, improved schedulers, event simulator."""

import numpy as np
import pytest

from repro.core import (
    Decomposition,
    decompose,
    equalize,
    local_search,
    schedule_lpt,
    schedule_wrap,
    spectra,
)
from repro.fabric.simulator import simulate

FIG2 = np.array([
    [0.6, 0.3, 0, 0.1],
    [0, 0.61, 0.39, 0],
    [0, 0.09, 0.61, 0.3],
    [0.4, 0, 0, 0.6],
])


def toy_dec(alphas):
    n = len(alphas)
    perms = [np.roll(np.arange(4), i % 4) for i in range(n)]
    return Decomposition(perms=perms, alphas=list(alphas))


def test_lpt_example_from_paper():
    # α = (0.61, 0.3, 0.1), s=2, δ=0.01 → loads (0.62, 0.42), makespan 0.62.
    dec = toy_dec([0.61, 0.3, 0.1])
    sched = schedule_lpt(dec, 2, 0.01)
    loads = sorted(sched.loads(), reverse=True)
    assert loads == pytest.approx([0.62, 0.42])
    assert sched.makespan() == pytest.approx(0.62)


def test_equalize_example_from_paper():
    dec = toy_dec([0.61, 0.3, 0.1])
    sched = schedule_lpt(dec, 2, 0.01)
    sched = equalize(sched)
    # µ = (0.62 + 0.42 + 0.01)/2 = 0.525 on both switches.
    assert sched.makespan() == pytest.approx(0.525)
    assert sched.loads() == pytest.approx([0.525, 0.525])


def test_equalize_never_increases_makespan():
    rng = np.random.default_rng(0)
    for s in (2, 3, 4, 8):
        for _ in range(5):
            dec = toy_dec(rng.random(rng.integers(1, 12)))
            before = schedule_lpt(dec, s, 0.02)
            m0 = before.makespan()
            after = equalize(schedule_lpt(dec, s, 0.02))
            assert after.makespan() <= m0 + 1e-12


def test_equalize_preserves_coverage():
    rng = np.random.default_rng(1)
    D = rng.random((8, 8)) * (rng.random((8, 8)) < 0.4)
    D[0, 0] = 1.0
    res = spectra(D, 3, 0.01)  # validates internally
    rep = simulate(res.schedule, D)
    assert rep.demand_met


def test_equalize_spread_within_delta_or_unsplittable():
    dec = toy_dec([1.0, 0.9, 0.8, 0.2, 0.1])
    delta = 0.01
    sched = equalize(schedule_lpt(dec, 2, delta))
    loads = sched.loads()
    h_max, h_min = loads.argmax(), loads.argmin()
    gap = loads[h_max] - loads[h_min]
    longest = max(sched.switches[h_max].alphas)
    needed = (gap - delta) / 2
    assert gap <= delta + 1e-12 or longest <= needed + 1e-12


def test_merge_aware_equalize_not_worse():
    rng = np.random.default_rng(2)
    for _ in range(5):
        alphas = rng.random(10)
        dec = toy_dec(alphas)
        plain = equalize(schedule_lpt(dec, 4, 0.05)).makespan()
        merged = equalize(schedule_lpt(dec, 4, 0.05), merge_aware=True).makespan()
        assert merged <= plain + 1e-12


def test_single_switch_schedule():
    dec = toy_dec([0.5, 0.3])
    sched = equalize(schedule_lpt(dec, 1, 0.1))
    assert sched.makespan() == pytest.approx(0.5 + 0.3 + 0.2)


def test_local_search_not_worse():
    rng = np.random.default_rng(3)
    for _ in range(5):
        dec = toy_dec(rng.random(9))
        base = schedule_lpt(dec, 3, 0.02)
        m0 = base.makespan()
        ls = local_search(schedule_lpt(dec, 3, 0.02))
        assert ls.makespan() <= m0 + 1e-12


def test_wrap_schedule_covers_and_bounded():
    rng = np.random.default_rng(4)
    D = rng.random((10, 10)) * (rng.random((10, 10)) < 0.5)
    D[0, 1] = 2.0
    dec = decompose(D)
    sched = schedule_wrap(dec, 3, 0.05)
    sched.validate(D)
    total = sum(dec.alphas) + 0.05 * dec.k
    assert sched.makespan() >= total / 3 - 1e-9


def test_simulator_catches_shortfall():
    dec = toy_dec([0.1])
    sched = schedule_lpt(dec, 2, 0.01)
    D = np.zeros((4, 4))
    D[0, 0] = 5.0  # not covered
    rep = simulate(sched, D)
    assert not rep.demand_met
    assert rep.max_shortfall > 4.0
