"""Device matcher registry: optimality vs scipy, both kernel paths, repair.

The optimality property the subsystem rests on: with the n-aware ε-schedule
scaled down to ``eps_final``, every registered matcher's assignment is
within ``n·eps_final`` of ``scipy.optimize.linear_sum_assignment`` — exact
for integer weights (``n·eps_final < 1`` at these sizes, since the
ulp-floored ``eps_final ≈ wmax·2⁻²²``). Runs both the jnp reference and the
Pallas ``use_kernel`` top-2 paths.
"""

import numpy as np
import pytest
from scipy.optimize import linear_sum_assignment

from repro.core.decompose import Decomposition, decompose, degree
from repro.core.jaxopt.decompose_jax import decompose_jax, to_decomposition
from repro.core.jaxopt.matching import (
    MATCHERS,
    get_matcher,
    list_matchers,
    match_auction,
    register_matcher,
)

jnp = pytest.importorskip("jax.numpy")

ALL_MATCHERS = sorted(MATCHERS)


def _optimal(W):
    ri, ci = linear_sum_assignment(W, maximize=True)
    return W[ri, ci].sum()


def _matched_weight(W, perm):
    perm = np.asarray(perm)
    n = W.shape[0]
    assert len(np.unique(perm)) == n, "matcher returned a non-permutation"
    return W[np.arange(n), perm].sum()


# ------------------------------------------------------------- optimality

@pytest.mark.parametrize("matcher", ALL_MATCHERS)
@pytest.mark.parametrize("n", [8, 16, 33, 64])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_matcher_exact_on_random_integers(matcher, n, seed):
    rng = np.random.default_rng(seed)
    W = rng.integers(0, 1000, (n, n)).astype(np.float32)
    perm, conv = get_matcher(matcher)(jnp.asarray(W))
    assert bool(conv)
    # n·eps_final < 1 here, so integer weights are matched exactly.
    assert _matched_weight(W, perm) == _optimal(W)


@pytest.mark.parametrize("matcher", ALL_MATCHERS)
@pytest.mark.parametrize("n", [16, 33, 64])
@pytest.mark.parametrize("density", [0.1, 0.3])
def test_matcher_near_optimal_on_sparse_floats(matcher, n, density):
    rng = np.random.default_rng(n * 10 + int(density * 10))
    W = (rng.random((n, n)) * (rng.random((n, n)) < density)).astype(np.float32)
    perm, conv = get_matcher(matcher)(jnp.asarray(W))
    assert bool(conv)
    opt = _optimal(W)
    assert _matched_weight(W, perm) >= opt - max(1e-3 * opt, 1e-6)


@pytest.mark.parametrize("matcher", ALL_MATCHERS)
@pytest.mark.parametrize("n", [16, 64])
def test_matcher_kernel_path_matches_reference(matcher, n):
    rng = np.random.default_rng(n)
    W = rng.integers(0, 500, (n, n)).astype(np.float32)
    fn = get_matcher(matcher)
    p_ref, conv_ref = fn(jnp.asarray(W), use_kernel=False)
    p_kern, conv_kern = fn(jnp.asarray(W), use_kernel=True)
    assert bool(conv_ref) and bool(conv_kern)
    # Both paths must reach the same (optimal) weight; tie-breaks may differ.
    opt = _optimal(W)
    assert _matched_weight(W, p_ref) == opt
    assert _matched_weight(W, p_kern) == opt


def test_matcher_large_sparse_with_coverage_bonus():
    # The regime that broke the fixed 8-phase schedule: n=100, sparse
    # support, node-coverage M-bonus folded into the weights (prices climb
    # to ~wmax, where a too-small ε is below the float32 ulp and livelocks).
    from repro.traffic.workloads import benchmark_workload

    D = benchmark_workload(rng=np.random.default_rng(0))
    S = D > 0
    row_deg, col_deg = S.sum(1), S.sum(0)
    k = max(row_deg.max(), col_deg.max())
    M = np.maximum(D, 0.0).max(axis=1).sum() + 1.0
    bonus = M * ((row_deg == k)[:, None].astype(float) + (col_deg == k)[None, :])
    W = (np.maximum(D, 0.0) + np.where(S, bonus, 0.0)).astype(np.float32)
    opt = _optimal(W)
    for matcher in ALL_MATCHERS:
        perm, conv = get_matcher(matcher)(jnp.asarray(W))
        assert bool(conv), matcher
        got = _matched_weight(W, perm)
        assert got >= opt - 1e-4 * opt, matcher


# --------------------------------------------------------------- registry

def test_registry_round_trip_and_errors():
    assert {"auction", "auction_fr"} <= set(list_matchers())
    with pytest.raises(KeyError, match="unknown matcher"):
        get_matcher("hungarian")
    with pytest.raises(ValueError, match="already registered"):
        register_matcher("auction", match_auction)
    register_matcher("auction2", match_auction)
    try:
        assert get_matcher("auction2") is match_auction
    finally:
        del MATCHERS["auction2"]


def test_unconverged_matcher_still_returns_a_permutation():
    # Starve the iteration budget: converged=False must come with a valid
    # (greedily completed) permutation, never -1 sentinels that would
    # corrupt downstream gathers.
    rng = np.random.default_rng(0)
    W = rng.random((24, 24)).astype(np.float32)
    perm, conv = match_auction(jnp.asarray(W), num_phases=2, max_iters=1)
    assert not bool(conv)
    perm = np.asarray(perm)
    assert len(np.unique(perm)) == 24
    assert (perm >= 0).all()


# -------------------------------------------------- decompose integration

@pytest.mark.parametrize("matcher", ALL_MATCHERS)
def test_decompose_jax_matcher_choice(matcher):
    rng = np.random.default_rng(4)
    n = 16
    D = (rng.random((n, n)) * (rng.random((n, n)) < 0.3)).astype(np.float32)
    D[0, 1] = 0.9
    dec = decompose_jax(jnp.asarray(D), matcher=matcher)
    assert bool(dec.converged)
    assert int(dec.k) == degree(D)
    assert to_decomposition(dec).covers(D, tol=1e-5)


def test_decompose_jax_unknown_matcher():
    with pytest.raises(KeyError, match="unknown matcher"):
        decompose_jax(jnp.zeros((4, 4)), matcher="nope")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_repair_shrinks_weight_and_keeps_coverage(seed):
    rng = np.random.default_rng(seed)
    n = 20
    D = (rng.random((n, n)) * (rng.random((n, n)) < 0.4)).astype(np.float32)
    D[1, 2] = 1.0
    plain = decompose_jax(jnp.asarray(D))
    repaired = decompose_jax(jnp.asarray(D), repair_rounds=2)
    dp, dr = to_decomposition(plain), to_decomposition(repaired)
    assert dp.covers(D, tol=1e-4) and dr.covers(D, tol=1e-4)
    # The local search only ever removes over-provisioned mass, and dropped
    # zero-α rounds can only shrink k.
    assert dr.total_weight() <= dp.total_weight() + 1e-5
    assert dr.k <= dp.k
    # Repaired alphas are compacted: every surviving round carries weight.
    assert all(a > 0 for a in dr.alphas)
    # Host reference: repair can only help the covered total, never break it.
    host = decompose(np.asarray(D, np.float64))
    assert dr.total_weight() <= host.total_weight() * 1.05 + 1e-6


def test_repair_noop_on_tight_decompositions():
    # Demand that IS a weighted permutation decomposes tightly (k=1, zero
    # slack): repair must change nothing (guard for the repair sweep's
    # slack accounting — it may only remove genuinely over-provisioned mass).
    rng = np.random.default_rng(7)
    n = 12
    D = np.zeros((n, n))
    D[np.arange(n), rng.permutation(n)] = 0.7
    plain = decompose_jax(jnp.asarray(D, jnp.float32))
    repaired = decompose_jax(jnp.asarray(D, jnp.float32), repair_rounds=3)
    assert int(plain.k) == int(repaired.k) == 1
    np.testing.assert_allclose(
        np.asarray(plain.alphas), np.asarray(repaired.alphas), atol=1e-6
    )
