"""Chunked (training/dry-run) impls vs oracles, + remat equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ref import mha_chunked, mha_ref
from repro.kernels.ssd_scan.ops import ssd_chunked
from repro.kernels.ssd_scan.ref import ssd_ref


@pytest.mark.parametrize("Sq,Sk,window", [(64, 64, None), (64, 64, 16),
                                          (32, 96, None), (128, 128, 24)])
def test_mha_chunked_matches_ref(Sq, Sk, window):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 4, Sq, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, Sk, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 2, Sk, 16)), jnp.float32)
    out = mha_chunked(q, k, v, causal=True, window=window, block_q=16)
    ref = mha_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.array(out), np.array(ref), rtol=2e-5, atol=2e-5)


def test_mha_chunked_grads():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 2, 32, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 32, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 32, 8)), jnp.float32)
    g1 = jax.grad(lambda q: (mha_chunked(q, k, v, block_q=8) ** 2).sum())(q)
    g2 = jax.grad(lambda q: (mha_ref(q, k, v) ** 2).sum())(q)
    np.testing.assert_allclose(np.array(g1), np.array(g2), rtol=1e-4, atol=1e-4)


def test_ssd_chunked_matches_ref():
    rng = np.random.default_rng(2)
    xd = jnp.asarray(rng.standard_normal((2, 96, 16)), jnp.float32)
    loga = jnp.asarray(-0.4 * rng.random((2, 96)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((2, 96, 8)) * 0.3, jnp.float32)
    C = jnp.asarray(rng.standard_normal((2, 96, 8)) * 0.3, jnp.float32)
    h0 = jnp.zeros((2, 8, 16), jnp.float32)
    y, hT = ssd_chunked(xd, loga, B, C, h0)
    y_ref, hT_ref = ssd_ref(xd, loga, B, C, h0)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(hT), np.array(hT_ref), rtol=1e-4, atol=1e-4)


def test_remat_same_loss_and_grads():
    from repro.configs.registry import ARCHS
    from repro.models.registry import build_model, concrete_inputs
    from repro.configs.base import ShapeCfg

    cfg = ARCHS["granite-3-8b"].reduced()
    shape = ShapeCfg("s", 32, 2, "train")
    batch = concrete_inputs(cfg, shape)
    m0 = build_model(cfg, remat=False, attn_impl="chunked")
    m1 = build_model(cfg, remat=True, attn_impl="chunked")
    params = m0.init(jax.random.PRNGKey(0))
    l0, g0 = jax.value_and_grad(lambda p: m0.loss(p, batch)[0])(params)
    l1, g1 = jax.value_and_grad(lambda p: m1.loss(p, batch)[0])(params)
    assert float(l0) == pytest.approx(float(l1), rel=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-5, atol=1e-6)
