"""Serving control plane: admission, cache, metrics, double-buffered loop.

Fast lane:
  * latency histograms and metrics export (the SLO observables);
  * token buckets and admission verdicts (ADMIT / DEGRADED / SHED),
    per-tenant isolation, bounded queue;
  * the two-tier schedule cache: exact hits return the stored schedule,
    support hits replay stored permutations onto drifted weights with a
    coverage guarantee, the quality gate rejects inefficient replays,
    FIFO capacity eviction;
  * server mechanics on the host solver: round-robin tenant fairness,
    degraded dispatch grouping (no EQUALIZE, never cached), shed
    accounting, cache-integrated serving;
  * sync/async result identity on the JAX dispatch path;
  * per-tenant stateful sessions and fair draining.

Slow lane (acceptance, mirrored with headroom by the CI serve-slo gate):
  * async double-buffering ≥ 1.3× the synchronous loop with install
    latency calibrated to the measured solve time;
  * ≥ 70% cache hit rate serving phase-cycling MoE traffic;
  * under 2× overload the queue stays bounded and SHED verdicts appear.
"""

import math
import time

import numpy as np
import pytest

from repro.api import Problem, SolveOptions, solve
from repro.serve.admission import (
    ADMIT,
    DEGRADED,
    SHED,
    AdmissionController,
    TokenBucket,
)
from repro.serve.cache import CacheResult, ScheduleCache
from repro.serve.loadgen import (
    Arrival,
    make_workload,
    mixed_profile,
    poisson_arrivals,
    submit_all,
    tiny_profile,
)
from repro.serve.metrics import STAGES, LatencyHistogram, ServeMetrics
from repro.serve.server import ScheduleServer
from repro.serve.sessions import SessionManager, TenantSession

_FAST = SolveOptions(validate=False, compute_lb=False)


def _perm_demand(n, rng, k=3):
    D = np.zeros((n, n))
    sigma = rng.permutation(n)
    for j in range(k):
        D[np.arange(n), np.roll(sigma, j)] = rng.random(n) + 0.2
    return D


# ------------------------------------------------------------------ metrics


def test_latency_histogram_percentiles_and_export():
    h = LatencyHistogram()
    assert math.isnan(h.percentile(50))
    for x in [1e-3] * 90 + [0.1] * 10:
        h.observe(x)
    assert h.count == 100
    assert h.percentile(50) == pytest.approx(1e-3, rel=0.35)
    assert h.percentile(99) == pytest.approx(0.1, rel=0.35)
    # Observations beyond the bin range clamp, never drop.
    h.observe(1e-9)
    h.observe(1e9)
    assert h.count == 102
    exp = h.export()
    assert exp["count"] == 102 and exp["max_s"] == 1e9
    assert exp["p50_s"] <= exp["p90_s"] <= exp["p99_s"]


def test_serve_metrics_counters_and_export():
    m = ServeMetrics()
    for v in (ADMIT, ADMIT, DEGRADED, SHED):
        m.count_verdict(v)
    with pytest.raises(ValueError):
        m.count_verdict("MAYBE")
    m.cache_hit_exact += 2
    m.cache_hit_support += 1
    m.cache_miss += 1
    m.schedules += 4
    m.observe("device", 0.01)
    exp = m.export()
    assert exp["admitted"] == 2 and exp["degraded"] == 1 and exp["shed"] == 1
    assert exp["cache_hit_rate"] == pytest.approx(0.75)
    assert exp["schedules_per_sec"] > 0
    assert set(exp["stages"]) == set(STAGES)


# ---------------------------------------------------------------- admission


def test_token_bucket_burst_and_refill():
    b = TokenBucket(rate=10.0, burst=2.0)
    assert b.try_take(0.0) and b.try_take(0.0)
    assert not b.try_take(0.0)  # burst exhausted
    assert b.try_take(0.1)      # 1 token refilled after 100ms
    assert not b.try_take(0.1)
    b2 = TokenBucket(rate=1.0, burst=2.0)
    b2.try_take(0.0), b2.try_take(0.0)
    assert b2.try_take(100.0)   # refill caps at burst
    assert b2.try_take(100.0)
    assert not b2.try_take(100.0)


def test_admission_verdicts_and_tenant_isolation():
    ac = AdmissionController(rate=10.0, burst=2.0, max_queue=4)
    assert [ac.admit("a", 0, 0.0) for _ in range(3)] == [
        ADMIT, ADMIT, DEGRADED,
    ]
    # Tenant b has its own bucket — a's exhaustion doesn't degrade b.
    assert ac.admit("b", 0, 0.0) == ADMIT
    # A full queue sheds regardless of tokens (and burns none).
    before = ac.bucket("b").tokens
    assert ac.admit("b", 4, 0.0) == SHED
    assert ac.bucket("b").tokens == before
    # Refill restores ADMIT.
    assert ac.admit("a", 0, 1.0) == ADMIT
    ac.set_tenant_rate("vip", rate=1000.0, burst=100.0)
    assert all(ac.admit("vip", 0, 0.0) == ADMIT for _ in range(50))


# -------------------------------------------------------------------- cache


def test_cache_exact_and_support_tiers():
    rng = np.random.default_rng(0)
    D = _perm_demand(8, rng)
    rep = solve(Problem(D, 4, 0.01), solver="spectra")
    cache = ScheduleCache(capacity=8)
    assert cache.lookup(D, 4, 0.01) is None
    cache.insert(D, rep.schedule, rep.decomposition)

    r1 = cache.lookup(D, 4, 0.01)
    assert isinstance(r1, CacheResult) and r1.tier == "exact"
    assert r1.makespan == pytest.approx(rep.makespan)

    # 1% multiplicative drift: same support, new weights → support tier,
    # and the replayed schedule must still cover the live matrix.
    D2 = np.maximum(D * (1.0 + 0.01 * rng.standard_normal(D.shape)), 0.0)
    D2[D == 0] = 0.0
    r2 = cache.lookup(D2, 4, 0.01)
    assert r2 is not None and r2.tier == "support"
    r2.schedule.validate(D2, tol=1e-9 * D2.max())
    # Replay quality stays near the fresh solve.
    assert r2.makespan <= 1.1 * rep.makespan
    assert cache.stats.hits_exact == 1 and cache.stats.hits_support == 1
    assert cache.stats.misses == 1


def test_cache_quality_gate_rejects_overprovisioned_replay():
    """Same support, adversarially shifted weights: replaying the stored
    permutations over-provisions past the ratio gate → miss, not a bloated
    schedule."""
    # σ1=id and σ3 share cell (0,0); σ2 is disjoint from both.
    s1 = np.array([0, 1, 2])
    s2 = np.array([1, 2, 0])
    s3 = np.array([0, 2, 1])
    from repro.core.decompose import Decomposition
    from repro.core.schedule import schedule_lpt

    dec = Decomposition(perms=[s1, s2, s3], alphas=[1.0, 1.0, 1.0])
    D1 = dec.coverage(3)
    sched = schedule_lpt(dec, 2, 0.01)
    cache = ScheduleCache(capacity=4, ratio_slack=0.1)
    cache.insert(D1, sched, dec)

    # Load the σ2-only cells; replaying σ1/σ3's stored weights is now waste.
    D2 = np.full((3, 3), 0.0)
    D2[np.arange(3), s2] = 10.0
    D2[np.arange(3), s1] = 0.01
    D2[np.arange(3), s3] = 0.01
    D2[0, 0] = 0.02  # shared cell keeps the union support identical
    assert (D2 > 0).tolist() == (D1 > 0).tolist()
    assert cache.lookup(D2, 2, 0.01) is None
    assert cache.stats.rejected_quality == 1


def test_cache_fifo_capacity_and_update_in_place():
    rng = np.random.default_rng(5)
    cache = ScheduleCache(capacity=2)
    mats = [_perm_demand(6, np.random.default_rng(seed)) for seed in range(3)]
    reps = [solve(Problem(D, 2, 0.01), solver="spectra") for D in mats]
    for D, rep in zip(mats[:2], reps[:2]):
        cache.insert(D, rep.schedule, rep.decomposition)
    assert len(cache) == 2
    # Re-inserting an existing key updates in place (no eviction).
    cache.insert(mats[0], reps[0].schedule, reps[0].decomposition)
    assert len(cache) == 2
    assert cache.lookup(mats[0], 2, 0.01) is not None
    # A third distinct key evicts the oldest (FIFO).
    cache.insert(mats[2], reps[2].schedule, reps[2].decomposition)
    assert len(cache) == 2
    assert cache.lookup(mats[1], 2, 0.01) is not None  # newer key survives
    del rng


# ------------------------------------------------------------------- server


def test_server_round_robin_fairness_across_tenants():
    srv = ScheduleServer(2, 0.01, solver="spectra", options=_FAST,
                         max_batch=2)
    rng = np.random.default_rng(1)
    for _ in range(3):
        srv.submit("chatty", _perm_demand(6, rng))
    srv.submit("quiet", _perm_demand(6, rng))
    batch = srv._next_batch()
    # One rotation serves each tenant's head before chatty's backlog.
    assert [r.tenant for r in batch] == ["chatty", "quiet"]


def test_server_degraded_grouping_and_cache_exclusion():
    ac = AdmissionController(rate=0.001, burst=1.0, max_queue=64)
    cache = ScheduleCache(capacity=8)
    srv = ScheduleServer(2, 0.01, solver="spectra", options=_FAST,
                         admission=ac, cache=cache, max_batch=4)
    rng = np.random.default_rng(2)
    D = _perm_demand(6, rng)
    t1, v1 = srv.submit("a", D, now=0.0)
    t2, v2 = srv.submit("a", D, now=0.0)  # bucket empty → degraded
    assert (v1, v2) == (ADMIT, DEGRADED)
    srv.drain()
    r1, r2 = srv.results[t1], srv.results[t2]
    assert not r1.degraded and r2.degraded
    # Degraded dispatch skips EQUALIZE → its schedule can be no better.
    assert r2.makespan >= r1.makespan - 1e-12
    # Only the admitted solve was cached; the degraded one never is.
    assert cache.stats.inserts == 1
    # Degraded requests bypass the cache lookup too.
    assert cache.stats.hits == 0


def test_server_shed_bookkeeping_and_bounded_queue():
    ac = AdmissionController(rate=1000.0, burst=1000.0, max_queue=3)
    srv = ScheduleServer(2, 0.01, solver="spectra", options=_FAST,
                         admission=ac)
    rng = np.random.default_rng(3)
    verdicts = [
        srv.submit("a", _perm_demand(6, rng), now=0.0)[1] for _ in range(8)
    ]
    assert verdicts.count(SHED) == 5 and len(srv) == 3
    srv.drain()
    assert len(srv.results) == 3 and len(srv.shed_tickets) == 5
    assert srv.metrics.shed == 5
    assert set(srv.results) | set(srv.shed_tickets) == set(range(8))


def test_server_serves_repeats_from_cache():
    cache = ScheduleCache(capacity=8)
    srv = ScheduleServer(2, 0.01, solver="spectra", options=_FAST,
                         cache=cache)
    D = _perm_demand(6, np.random.default_rng(4))
    t1, _ = srv.submit("a", D)
    srv.drain()
    t2, _ = srv.submit("a", D)
    srv.drain()
    assert srv.results[t1].source == "device"
    assert srv.results[t2].source == "cache:exact"
    assert srv.results[t2].makespan == pytest.approx(
        srv.results[t1].makespan
    )
    assert srv.metrics.cache_hit_exact == 1


def test_sync_async_identical_results_on_jax_path():
    pytest.importorskip("jax")
    wl = make_workload(tiny_profile(n=8, rate=30.0), duration=0.3, seed=7,
                       s=2, delta=0.01)
    assert wl, "profile produced no arrivals"
    outs = {}
    for mode in ("sync", "async"):
        srv = ScheduleServer(2, 0.01, mode=mode, solver="spectra_jax",
                             options=_FAST, max_batch=4)
        assert srv.mode == mode  # jax path available → async honored
        submit_all(srv, wl)
        res = srv.drain()
        outs[mode] = sorted(
            (r.ticket, round(r.makespan, 5)) for r in res.values()
        )
    assert outs["sync"] == outs["async"]


def test_server_non_jax_solver_falls_back_to_sync():
    srv = ScheduleServer(2, 0.01, mode="async", solver="spectra",
                         options=_FAST)
    assert srv.mode == "sync"
    with pytest.raises(ValueError):
        ScheduleServer(2, 0.01, mode="overlapped")
    with pytest.raises(ValueError):
        srv.submit("a", np.zeros((3, 4)))


# ----------------------------------------------------------------- loadgen


def test_poisson_arrivals_and_workload_shape():
    rng = np.random.default_rng(0)
    times = poisson_arrivals(100.0, 2.0, rng)
    assert (np.diff(times) > 0).all() and times[-1] < 2.0
    assert len(times) == pytest.approx(200, rel=0.35)
    wl = make_workload(mixed_profile(), duration=0.5, seed=1)
    assert all(isinstance(a, Arrival) for a in wl)
    assert all(a.t <= b.t for a, b in zip(wl, wl[1:]))
    shapes = {a.D.shape for a in wl}
    assert shapes == {(8, 8), (16, 16)}  # ragged tenants
    # Same seed → identical workload (deterministic benches).
    wl2 = make_workload(mixed_profile(), duration=0.5, seed=1)
    assert [(a.t, a.tenant) for a in wl] == [(a.t, a.tenant) for a in wl2]


# ---------------------------------------------------------------- sessions


def test_tenant_sessions_round_robin_and_stats():
    mgr = SessionManager(2, 0.01, solver="spectra_online")
    rng = np.random.default_rng(6)
    D = _perm_demand(8, rng)
    for t in range(3):
        mgr.submit("a", D * (1.0 + 0.001 * t))
    mgr.submit("b", _perm_demand(8, rng))
    assert mgr.backlog == 4
    first = mgr.drain_round()
    assert [t for t, _ in first] == ["a", "b"]  # one period each, fair
    rest = mgr.drain()
    assert mgr.backlog == 0 and len(rest) == 2
    st = mgr.stats()
    assert st["a"]["periods"] == 3 and st["b"]["periods"] == 1
    # Identical support period-over-period → warm reuse for tenant a.
    assert st["a"]["warm"] >= 1
    assert isinstance(mgr.session("a"), TenantSession)
    # Sessions carry state: later periods pay less δ than stateless.
    reps = mgr.sessions["a"].reports
    assert all(r.extras["online"] for r in reps)


# -------------------------------------------------------- slow acceptance


@pytest.mark.slow
def test_async_double_buffering_speedup():
    """With install latency calibrated to the measured device solve time,
    the double-buffered loop must beat the synchronous loop ≥ 1.3×
    (ideal is ~2×: cycle max(S, L) vs S + L with L ≈ S)."""
    pytest.importorskip("jax")
    from repro.api.jax_backend import dispatch_many_jax

    n, B, batches = 16, 4, 4
    rng = np.random.default_rng(0)
    mats = [_perm_demand(n, rng, k=4) for _ in range(B * batches)]

    # Warm the compile cache at exactly the serving shape, then measure
    # the steady-state per-batch solve time.
    warm = dispatch_many_jax(np.stack(mats[:B]), 4, 0.01, _FAST)
    warm.collect()
    t0 = time.perf_counter()
    dispatch_many_jax(np.stack(mats[:B]), 4, 0.01, _FAST).collect()
    solve_s = time.perf_counter() - t0
    install = max(solve_s, 0.01)

    def run(mode):
        srv = ScheduleServer(
            4, 0.01, mode=mode, solver="spectra_jax", options=_FAST,
            install_latency_s=install, max_batch=B,
        )
        for i, D in enumerate(mats):
            srv.submit(f"t{i % 2}", D)
        t0 = time.perf_counter()
        srv.drain()
        dt = time.perf_counter() - t0
        assert len(srv.results) == len(mats)
        return dt

    sync_s = run("sync")
    async_s = run("async")
    assert async_s * 1.3 <= sync_s, (
        f"double-buffering speedup {sync_s / async_s:.2f}x < 1.3x "
        f"(solve {solve_s * 1e3:.1f}ms, install {install * 1e3:.1f}ms)"
    )


@pytest.mark.slow
def test_cache_hit_rate_on_phase_cycling_traffic():
    """Serving the phase-cycling MoE profile, ≥ 70% of admitted requests
    must come from the schedule cache (exact or support tier)."""
    pytest.importorskip("jax")
    wl = make_workload(tiny_profile(n=8, rate=60.0), duration=0.6, seed=3)
    cache = ScheduleCache(capacity=32)
    srv = ScheduleServer(4, 0.01, mode="async", solver="spectra_jax",
                         options=_FAST, cache=cache, max_batch=4)
    submit_all(srv, wl)
    srv.drain()
    m = srv.metrics
    assert m.schedules == len(wl)
    assert m.cache_hit_rate >= 0.70, m.export()
    # Cached schedules really are schedules: spot-check coverage.
    hits = [r for r in srv.results.values() if r.source.startswith("cache")]
    assert hits and all(np.isfinite(r.makespan) for r in hits)


@pytest.mark.slow
def test_overload_sheds_and_keeps_queue_bounded():
    """2× overload: offered rate double the profile the queue is sized
    for. The queue must never exceed max_queue and SHED must appear."""
    pytest.importorskip("jax")
    wl = make_workload(tiny_profile(n=8, rate=120.0), duration=0.5, seed=5)
    ac = AdmissionController(rate=1000.0, burst=1000.0, max_queue=8)
    srv = ScheduleServer(4, 0.01, mode="async", solver="spectra_jax",
                         options=_FAST, admission=ac, max_batch=4)
    max_depth = 0
    for i, a in enumerate(wl):
        srv.submit(a.tenant, a.D, now=a.t)
        max_depth = max(max_depth, len(srv))
        # Overloaded serving: one cycle (≤ max_batch schedules) per 12
        # arrivals — offered load exceeds drain capacity 2-3×.
        if i % 12 == 11:
            srv.step()
    srv.drain()
    assert max_depth <= 8
    assert srv.metrics.shed > 0
    assert len(srv.results) + len(srv.shed_tickets) == len(wl)
    # Every served request still produced a real schedule.
    assert all(np.isfinite(r.makespan) for r in srv.results.values())
