"""Scenario & trace API: registry round-trips, determinism, batched runs.

Fast lane (the CI ``scenarios-smoke`` job runs exactly this file under
``-m "not slow"``):

  * every registered scenario materializes a tiny (n=8, T=3) trace and runs
    end-to-end through ``run_scenario``;
  * traces are deterministic under a fixed seed, and — with seed 0 — period
    ``t`` reproduces exactly the matrix the fig benchmarks historically drew
    for ``seed=t`` (the fig6/fig9 reproduction guarantee);
  * ragged-n ``solve_many`` shape-bucketing returns order-preserving,
    host-parity results with device-computed lower bounds attached.

The ``slow`` test runs the three paper workloads (T=8 each) through the
fused ``spectra_jax`` path at paper scale and checks per-period makespans
and §IV bounds against per-instance host ``solve`` within 1e-4.
"""

import numpy as np
import pytest

from repro.api import Problem, SolveOptions, solve, solve_many
from repro.api.batch import shape_buckets
from repro.core import lower_bound
from repro.scenarios import (
    DemandTrace,
    TrafficSpec,
    get_scenario,
    list_scenarios,
    make_trace,
    register_scenario,
    run_scenario,
)
from repro.serve.engine import SolverService
from repro.traffic.workloads import benchmark_workload, gpt3b_workload, moe_workload

TINY = dict(n=8, periods=3)
_NO_VALIDATE = SolveOptions(validate=False)

# Device-vs-host makespan envelope on the benchmark workload. PR 3 measured
# 1.36x at n=100 (fixed 8-phase ε-schedule: float32 price livelock, matcher
# timeout, k inflated 16→20); the n-aware matcher schedule brought it to
# 1.00, so the tripwire is the acceptance bound, with float32/tie-break
# headroom.
DEVICE_QUALITY_TRIPWIRE = 1.10


# ---------------------------------------------------------------- registry

def test_registry_round_trip_every_scenario():
    names = list_scenarios()
    assert {"gpt", "moe", "benchmark", "collective_ring"} <= set(names)
    for name in names:
        sc = get_scenario(name)
        assert sc.name == name
        trace = make_trace(name, **TINY)
        assert trace.demands.shape == (3, 8, 8)
        assert np.isfinite(trace.demands).all()
        assert (trace.demands >= 0).all()
        assert len(trace.period_meta) == 3
        assert trace.spec.family == sc.spec.family


def test_unknown_scenario_and_duplicate_registration():
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("nope")
    spec = get_scenario("gpt").spec
    with pytest.raises(ValueError, match="already registered"):
        register_scenario("gpt", spec)


def test_spec_replace_merges_params():
    spec = TrafficSpec(family="benchmark", n=100, s=4, delta=0.01, periods=8)
    tiny = spec.replace(n=8, periods=3, m=4, noise=0.0)
    assert (tiny.n, tiny.periods) == (8, 3)
    assert tiny.params == {"m": 4, "noise": 0.0}
    assert spec.params == {}  # original untouched
    with pytest.raises(ValueError, match="units"):
        TrafficSpec(family="benchmark", n=8, s=2, delta=0.0, units="flops")


def test_trace_determinism_and_seed_sensitivity():
    a = make_trace("moe", **TINY)
    b = make_trace("moe", **TINY)
    c = make_trace("moe", seed=7, **TINY)
    assert np.array_equal(a.demands, b.demands)
    assert not np.array_equal(a.demands, c.demands)


def test_periods_reproduce_legacy_seeded_workloads():
    # The guarantee the fig6/fig9 ports rest on: with seed 0, period t is
    # exactly workload_fn(rng=np.random.default_rng(t)).
    tr = make_trace("benchmark", periods=2)
    for t in range(2):
        assert np.array_equal(
            tr.demands[t], benchmark_workload(rng=np.random.default_rng(t))
        )
    tr = make_trace("gpt", periods=2)
    for t in range(2):
        assert np.array_equal(
            tr.demands[t], gpt3b_workload(rng=np.random.default_rng(t))
        )
    tr = make_trace("moe", periods=1)
    assert np.array_equal(tr.demands[0], moe_workload(rng=np.random.default_rng(0)))


def test_knob_schedules_cycle_per_period():
    tr = make_trace("sparsity_sweep", n=20, periods=8)
    ms = [meta["m"] for meta in tr.period_meta]
    assert ms == [4, 8, 12, 16, 24, 32, 4, 8]  # cycles fig10's grid
    degrees = [(D > 0).sum(axis=1).max() for D in tr.demands]
    assert degrees[0] <= degrees[3]  # sparser period has lower degree
    # an explicit scalar override pins the knob even though the registered
    # spec carries m_schedule
    pinned = make_trace("sparsity_sweep", n=20, periods=3, m=4)
    assert [meta["m"] for meta in pinned.period_meta] == [4, 4, 4]


# ------------------------------------------------------------ run_scenario

def test_run_scenario_smoke_every_scenario_tiny():
    # The CI scenarios-smoke configuration: every registered scenario at
    # (n=8, T=3), simulated, through the host solver.
    for name in list_scenarios():
        rep = run_scenario(name, solver="spectra", simulate=True, **TINY)
        assert rep.scenario == name
        assert len(rep.periods) == 3 and len(rep.reports) == 3
        assert rep.num_shape_buckets == 1
        assert np.isfinite(rep.makespans).all()
        assert (rep.makespans >= 0).all()
        assert all(p.demand_met for p in rep.periods), name
        gaps = rep.gaps
        assert (gaps[np.isfinite(gaps)] >= 1.0 - 1e-9).all()
        if rep.spec.units == "bytes":
            assert np.isfinite(rep.cct_s).all() and rep.total_cct_s > 0
        else:
            assert np.isnan(rep.cct_s).all() and np.isnan(rep.total_cct_s)


def test_run_scenario_bytes_normalization():
    rep = run_scenario("collective_ring", **TINY)
    spec = rep.spec
    assert rep.unit_s > 0
    assert rep.delta_units == pytest.approx(spec.delta / rep.unit_s)
    # trace-global normalization: peak across ALL periods is exactly 1
    units, unit_s, _ = rep.trace.normalized()
    assert units.max() == pytest.approx(1.0)
    assert np.allclose(rep.cct_s, rep.makespans * rep.unit_s)


def test_run_scenario_accepts_materialized_trace():
    trace = make_trace("gpt", **TINY)
    rep = run_scenario(trace, solver="spectra", options=_NO_VALIDATE)
    assert rep.trace is trace
    with pytest.raises(TypeError, match="overrides"):
        run_scenario(trace, n=16)


def test_run_scenario_per_period_metadata_flows_through():
    rep = run_scenario("sparsity_sweep", n=20, periods=3, options=_NO_VALIDATE)
    assert [p.meta["m"] for p in rep.periods] == [4, 8, 12]


def test_all_zero_trace_normalizes_cleanly():
    spec = TrafficSpec(family="collectives", n=8, s=2, delta=1e-5, periods=2,
                       units="bytes", params={"wire_bytes": {}})
    trace = get_scenario("collective_ring").trace(
        n=8, periods=2, wire_bytes={}
    )
    assert trace.demands.max() == 0.0
    units, unit_s, delta_units = trace.normalized()
    assert unit_s == 0.0 and delta_units == 0.0
    rep = run_scenario(trace, solver="spectra")
    assert (rep.makespans == 0.0).all()
    assert rep.total_cct_s == 0.0
    assert spec.units == "bytes"  # spec itself valid too


# ------------------------------------------- ragged solve_many + device LB

def test_solve_many_shape_buckets_order_preserving():
    jax = pytest.importorskip("jax")
    del jax
    rng = np.random.default_rng(0)
    Ds = [
        benchmark_workload(n=8, m=4, num_big=1, rng=rng),
        benchmark_workload(n=12, m=4, num_big=1, rng=rng),
        benchmark_workload(n=8, m=4, num_big=1, rng=rng),
        benchmark_workload(n=12, m=4, num_big=1, rng=rng),
    ]
    buckets = shape_buckets([np.asarray(D) for D in Ds])
    assert {shape: idxs for shape, idxs in buckets.items()} == {
        (8, 8): [0, 2], (12, 12): [1, 3]
    }
    reports = solve_many(Ds, 2, 0.02, solver="spectra_jax")
    assert len(reports) == 4
    for D, rep in zip(Ds, reports):
        host = solve(Problem(D, 2, 0.02), solver="spectra",
                     options=_NO_VALIDATE)
        assert abs(rep.makespan - host.makespan) / host.makespan < 1e-4
        # instance really came from its own bucket's fused dispatch
        assert rep.extras["batched"] and rep.extras["batch_size"] == 2
        rep.schedule.validate(D, tol=1e-4)


def test_batched_reports_carry_device_lower_bounds():
    pytest.importorskip("jax")
    Ds = [benchmark_workload(n=8, m=4, num_big=1,
                             rng=np.random.default_rng(s)) for s in range(3)]
    reports = solve_many(Ds, 2, 0.02, solver="spectra_jax")
    for D, rep in zip(Ds, reports):
        host_lb = lower_bound(D, 2, 0.02)
        assert abs(rep.lower_bound - host_lb) / host_lb < 1e-4
        assert rep.optimality_gap >= 1.0 - 1e-4
    # compute_lb=False still suppresses the bound on the device path
    off = solve_many(Ds, 2, 0.02, solver="spectra_jax",
                     options=SolveOptions(validate=False, compute_lb=False))
    assert all(np.isnan(r.lower_bound) for r in off)
    # single-instance device solves keep the exact float64 host bound —
    # there is no per-instance loop to amortize away
    single = solve(Problem(Ds[0], 2, 0.02), solver="spectra_jax",
                   options=_NO_VALIDATE)
    assert single.lower_bound == lower_bound(Ds[0], 2, 0.02)


def test_run_scenario_device_solver_tiny():
    pytest.importorskip("jax")
    rep = run_scenario("benchmark", solver="spectra_jax", m=4, num_big=1,
                       simulate=True, **TINY)
    assert rep.num_shape_buckets == 1
    assert all(p.demand_met for p in rep.periods)
    assert all(r.extras.get("fused") for r in rep.reports)
    host = run_scenario("benchmark", solver="spectra", m=4, num_big=1, **TINY)
    rel = np.abs(rep.makespans - host.makespans) / host.makespans
    assert (rel < 1e-4).all()
    lb_rel = np.abs(rep.lower_bounds - host.lower_bounds) / host.lower_bounds
    assert (lb_rel < 1e-4).all()


# --------------------------------------------------- device quality gate

def test_device_quality_tripwire_n100_fast_lane():
    """Fast-lane version of the paper-scale quality envelope (CI
    ``matching-quality`` job): one period of the n=100 sparse benchmark
    through the fused device path must stay within DEVICE_QUALITY_TRIPWIRE
    of the exact host pipeline.

    PR 3 measured the fixed 8-phase ε-schedule at 1.36× here (the matcher
    livelocked below the float32 price ulp and timed out); the n-aware
    schedule restores parity, so the tripwire is tight. This is the only
    n=100 device solve in the fast lane — one compile + one auction sweep.
    """
    pytest.importorskip("jax")
    trace = make_trace("benchmark", periods=1)
    assert trace.n == 100
    rep = run_scenario(trace, solver="spectra_jax", options=_NO_VALIDATE,
                       quality_ref="spectra")
    assert rep.periods[0].ref_makespan > 0
    assert not rep.reports[0].extras["warnings"], rep.reports[0].extras
    assert rep.reports[0].extras["converged"]
    assert rep.max_quality_ratio <= DEVICE_QUALITY_TRIPWIRE, (
        f"device/host makespan ratio {rep.max_quality_ratio:.3f} exceeds "
        f"the {DEVICE_QUALITY_TRIPWIRE}x tripwire"
    )


def test_run_scenario_quality_ref_aggregates():
    rep = run_scenario("benchmark", solver="spectra", n=12, m=4, num_big=1,
                       periods=3, options=_NO_VALIDATE, quality_ref="spectra")
    # Same solver as reference: ratios are exactly 1.
    assert np.allclose(rep.quality_ratios, 1.0)
    assert rep.summary()["quality_ratio"] == pytest.approx(1.0)
    assert rep.summary()["quality_ref"] == "spectra"
    # Without a reference the aggregate stays NaN (and the key stays put).
    plain = run_scenario("benchmark", solver="spectra", n=12, m=4, num_big=1,
                         periods=2, options=_NO_VALIDATE)
    assert np.isnan(plain.summary()["quality_ratio"])
    assert np.isnan(plain.quality_ratios).all()


# ------------------------------------------------------------------ serve

def test_solver_service_accepts_traces():
    svc = SolverService(s=2, delta=0.01, solver="spectra",
                        options=_NO_VALIDATE)
    trace = make_trace("moe", n=8, periods=3, tokens_per_gpu=256)
    tickets = svc.submit_trace(trace)
    extra = svc.submit(trace.demands[0])  # plain matrices still mix in
    assert tickets == [0, 1, 2] and extra == 3 and len(svc) == 4
    out = svc.flush()
    assert set(out) == {0, 1, 2, 3}
    # same matrix → same schedule whether submitted via trace or directly
    assert out[0].makespan == pytest.approx(out[3].makespan)
    with pytest.raises(ValueError, match="demand stack"):
        svc.submit_trace(np.zeros((4, 3)))
    # byte-denominated traces must be normalized before submission: the
    # service's delta is in demand units, not seconds
    with pytest.raises(ValueError, match="denominated in bytes"):
        svc.submit_trace(make_trace("collective_ring", n=8, periods=2))


# ---------------------------------------------------- paper-scale (slow)

@pytest.mark.slow
def test_paper_workloads_device_trace_parity():
    """Acceptance: three paper workloads, T=8 each, fused device path.

    One ragged solve_many submission covers all 24 matrices — three shape
    buckets (n = 32/64/100), ONE fused device dispatch each. Batched
    makespans match per-instance ``solve`` on the same solver within 1e-4
    relative (submission-order preservation falls out of comparing against
    the matching instance) and device §IV bounds match the host bound
    within 1e-4. Against the numpy host pipeline the device result is a
    *quality* envelope, not an identity: the ε-scaling auction picks
    different matchings than Hungarian on the structured paper matrices.
    With the n-aware matcher ε-schedule (ulp-floored final ε, phase count
    grown with n) the measured envelope is ≈1.00 at every paper scale —
    the pre-refactor 1.36x at benchmark n=100 was the fixed schedule's
    float32 price livelock — so the tripwire is DEVICE_QUALITY_TRIPWIRE
    (also enforced per-push by the fast-lane n=100 gate above).
    """
    pytest.importorskip("jax")
    traces = {name: make_trace(name) for name in ("gpt", "moe", "benchmark")}
    assert all(tr.T == 8 for tr in traces.values())

    # Ragged submission across all three shapes at once.
    mats = [D for tr in traces.values() for D in tr.demands]
    assert len(shape_buckets([np.asarray(D) for D in mats])) == 3
    reports = solve_many(mats, 4, 0.01, solver="spectra_jax",
                         options=_NO_VALIDATE)
    assert all(r.extras["batch_size"] == 8 for r in reports)

    i = 0
    for name, tr in traces.items():
        for t, D in enumerate(tr.demands):
            rep = reports[i]; i += 1
            host = solve(Problem(D, 4, 0.01), solver="spectra",
                         options=_NO_VALIDATE)
            assert abs(rep.lower_bound - host.lower_bound) / host.lower_bound \
                < 1e-4, name
            # quality envelope (see DEVICE_QUALITY_TRIPWIRE)
            assert rep.makespan < host.makespan * DEVICE_QUALITY_TRIPWIRE, name
            assert rep.makespan >= rep.lower_bound * (1 - 1e-4)
            if t == 0:  # per-instance device solve (one jit + auction per n —
                # tens of seconds each at paper scale, so one probe per bucket)
                single = solve(Problem(D, 4, 0.01), solver="spectra_jax",
                               options=_NO_VALIDATE)
                rel = abs(rep.makespan - single.makespan) / single.makespan
                assert rel < 1e-4, (name, t)

    # Whole-trace runs reuse the same jit entries: one dispatch per bucket.
    for name, tr in traces.items():
        rep = run_scenario(tr, solver="spectra_jax", options=_NO_VALIDATE)
        assert rep.num_shape_buckets == 1
        assert all(r.extras.get("fused") and r.extras["batch_size"] == 8
                   for r in rep.reports)
        assert np.isfinite(rep.makespans).all()
