"""Batched decode engine: greedy generation, temperature, batch slots."""

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models.registry import build_model
from repro.serve.engine import DecodeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = ARCHS["granite-3-8b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, DecodeEngine(model, params, max_len=64)


def test_greedy_generation_shapes(engine):
    cfg, model, params, eng = engine
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (3, 8))
    res = eng.generate(prompts.astype(np.int32), max_new_tokens=6)
    assert res.tokens.shape == (3, 14)
    assert (res.tokens[:, :8] == prompts).all()
    assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab_size).all()


def test_greedy_is_deterministic(engine):
    cfg, model, params, eng = engine
    prompts = np.full((2, 4), 11, np.int32)
    a = eng.generate(prompts, 5).tokens
    b = eng.generate(prompts, 5).tokens
    np.testing.assert_array_equal(a, b)


def test_temperature_sampling_varies(engine):
    cfg, model, params, eng = engine
    prompts = np.full((2, 4), 11, np.int32)
    a = eng.generate(prompts, 12, temperature=1.5, seed=0).tokens
    b = eng.generate(prompts, 12, temperature=1.5, seed=1).tokens
    assert not np.array_equal(a, b)


def test_batch_entries_independent(engine):
    """Each batch slot's continuation depends only on its own prompt."""
    cfg, model, params, eng = engine
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, cfg.vocab_size, (1, 6)).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, (1, 6)).astype(np.int32)
    solo = eng.generate(p1, 4).tokens
    both = eng.generate(np.concatenate([p1, p2]), 4).tokens
    np.testing.assert_array_equal(solo[0], both[0])


def test_length_guard(engine):
    cfg, model, params, eng = engine
    with pytest.raises(ValueError):
        eng.generate(np.zeros((1, 60), np.int32), 10)
