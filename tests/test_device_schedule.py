"""Schedule IR + device EQUALIZE + fused e2e pipeline (ISSUE 2 coverage).

Contract:
  * ``DeviceSchedule`` round-trips a ``ParallelSchedule`` exactly;
  * device ``equalize_ir`` matches host ``core.equalize`` makespans within
    1e-4 on randomized instances (standard and merge-aware);
  * fused ``spectra_jax_e2e`` matches the host ``spectra`` pipeline within
    1e-4, and its batched vmap validates coverage per instance on
    ragged-``k`` stacks;
  * batched ``solve_many`` stays lazy until something touches a schedule.
"""

import copy

import numpy as np
import pytest

from repro.core import (
    decompose,
    equalize,
    ir_coverage,
    ir_loads,
    ir_makespan,
    ir_num_configs,
    ir_to_schedule,
    schedule_lpt,
    schedule_to_ir,
    spectra,
)
from repro.core.jaxopt import (
    decompose_jax,
    equalize_ir_jit,
    spectra_jax_e2e,
    spectra_jax_e2e_many,
    to_decomposition,
)
from repro.core.schedule_ir import DeviceSchedule, LazySchedule


def sparse_demand(rng, n, density=0.5):
    D = rng.random((n, n)) * (rng.random((n, n)) < density)
    if not (D > 0).any():
        D[rng.integers(n), rng.integers(n)] = 0.5
    return D


def _index_ir(ds: DeviceSchedule, b: int) -> DeviceSchedule:
    return DeviceSchedule(
        perms=np.asarray(ds.perms)[b],
        alphas=np.asarray(ds.alphas)[b],
        switch=np.asarray(ds.switch)[b],
        delta=float(np.asarray(ds.delta)[b]),
    )


# ---------------------------------------------------------------------------
# IR round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ir_roundtrip_preserves_schedule(seed):
    rng = np.random.default_rng(seed)
    n, s, delta = 9, 3, 0.02
    D = sparse_demand(rng, n)
    sched = equalize(schedule_lpt(decompose(D), s, delta))
    ds = schedule_to_ir(sched, n)
    assert ir_num_configs(ds) == sched.num_configs()
    assert ir_loads(ds, s) == pytest.approx(sched.loads())
    assert ir_makespan(ds, s) == pytest.approx(sched.makespan())
    np.testing.assert_allclose(ir_coverage(ds), sched.coverage(n))
    back = ir_to_schedule(ds, s)
    assert back.makespan() == pytest.approx(sched.makespan())
    assert sorted(back.loads()) == pytest.approx(sorted(sched.loads()))
    back.validate(D, tol=1e-9)


def test_ir_capacity_checks():
    rng = np.random.default_rng(3)
    sched = schedule_lpt(decompose(sparse_demand(rng, 6)), 2, 0.01)
    with pytest.raises(ValueError):
        schedule_to_ir(sched, 6, capacity=sched.num_configs() - 1)
    ds = schedule_to_ir(sched, 6, capacity=sched.num_configs())
    assert ir_num_configs(ds) == sched.num_configs()


# ---------------------------------------------------------------------------
# Device EQUALIZE vs host EQUALIZE
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("s", [2, 3, 4])
def test_equalize_device_matches_host(seed, s):
    rng = np.random.default_rng(seed)
    n, delta = 8, 0.02
    D = sparse_demand(rng, n, density=0.6)
    base = schedule_lpt(decompose(D), s, delta)
    host = equalize(copy.deepcopy(base))
    out, exhausted = equalize_ir_jit(schedule_to_ir(base, n), s)
    assert not bool(exhausted)
    dev = ir_to_schedule(out, s)
    rel = abs(dev.makespan() - host.makespan()) / max(host.makespan(), 1e-12)
    assert rel < 1e-4
    dev.validate(D, tol=1e-4)


@pytest.mark.parametrize("seed", range(4))
def test_equalize_device_merge_aware(seed):
    rng = np.random.default_rng(100 + seed)
    n, s, delta = 8, 3, 0.05
    D = sparse_demand(rng, n, density=0.6)
    base = schedule_lpt(decompose(D), s, delta)
    host_plain = equalize(copy.deepcopy(base)).makespan()
    host_merge = equalize(copy.deepcopy(base), merge_aware=True).makespan()
    out, _ = equalize_ir_jit(schedule_to_ir(base, n), s, merge_aware=True)
    dev = ir_to_schedule(out, s)
    # Merge-aware never loses to plain, and the device variant tracks the
    # host variant (same µ/τ arithmetic, same first-match merge rule).
    assert dev.makespan() <= host_plain + 1e-4
    rel = abs(dev.makespan() - host_merge) / max(host_merge, 1e-12)
    assert rel < 1e-4
    dev.validate(D, tol=1e-4)


def test_equalize_device_single_switch_noop():
    rng = np.random.default_rng(5)
    n = 6
    base = schedule_lpt(decompose(sparse_demand(rng, n)), 1, 0.01)
    ds = schedule_to_ir(base, n)
    out, exhausted = equalize_ir_jit(ds, 1)
    assert not bool(exhausted)
    assert ir_makespan(out, 1) == pytest.approx(base.makespan(), rel=1e-6)
    assert ir_num_configs(out) == base.num_configs()


def test_equalize_device_flags_slot_exhaustion():
    # Zero headroom: the very first split must report exhaustion, and the
    # truncated result must still be a valid cover (EQUALIZE only moves
    # weight, so stopping early never breaks Eq. 3).
    rng = np.random.default_rng(6)
    n, s, delta = 8, 3, 0.01
    D = sparse_demand(rng, n, density=0.7)
    base = schedule_lpt(decompose(D), s, delta)
    tight = schedule_to_ir(base, n, capacity=base.num_configs())
    out, exhausted = equalize_ir_jit(tight, s)
    if base.makespan() - min(base.loads()) > delta:  # a split was wanted
        assert bool(exhausted)
    dev = ir_to_schedule(out, s)
    dev.validate(D, tol=1e-4)
    assert dev.makespan() <= base.makespan() + 1e-5
    # With headroom the same instance converges and the flag stays clear.
    roomy, ok = equalize_ir_jit(schedule_to_ir(base, n), s)
    assert not bool(ok)
    # API surface: the flag lands in report extras.
    from repro.api import SolveOptions, solve_many

    reports = solve_many(
        np.stack([D]), s, delta, solver="spectra_jax",
        options=SolveOptions(validate=False, compute_lb=False),
    )
    assert reports[0].extras["eq_exhausted"] is False


def test_solve_many_host_finishes_exhausted_equalize():
    # extra_slots=0 forbids any device split; the backend must flag it and
    # finish EQUALIZE on the host so makespans still match the host pipeline.
    from repro.api import Problem, SolveOptions, solve, solve_many

    rng = np.random.default_rng(21)
    Ds = np.stack([sparse_demand(rng, 8, density=0.7) for _ in range(3)])
    s, delta = 3, 0.01
    reports = solve_many(
        Ds, s, delta, solver="spectra_jax",
        options=SolveOptions(extra={"extra_slots": 0}),
    )
    assert any(rep.extras["eq_exhausted"] for rep in reports)
    for b, rep in enumerate(reports):
        host = solve(Problem(Ds[b], s, delta), solver="spectra")
        rel = abs(rep.makespan - host.makespan) / max(host.makespan, 1e-12)
        assert rel < 1e-4
        if rep.extras["eq_exhausted"]:
            # Host finishing ran: reported metrics come from the finished
            # schedule, not the truncated device one.
            assert rep.makespan <= rep.extras["device_makespan"] + 1e-9
            assert rep.num_configs == rep.schedule.num_configs()


# ---------------------------------------------------------------------------
# Host merge-aware EQUALIZE: hashed lookup ≡ the original linear rescan
# ---------------------------------------------------------------------------

def _equalize_merge_reference(sched):
    """The pre-hashing implementation (np.array_equal rescan), as the oracle."""
    s, delta = sched.s, sched.delta
    loads = sched.loads()
    for _ in range(64 * (sched.num_configs() + s) + 64):
        h_max, h_min = int(np.argmax(loads)), int(np.argmin(loads))
        if loads[h_max] - loads[h_min] <= delta:
            break
        src = sched.switches[h_max]
        z = src.longest()
        if z < 0:
            break
        dst = sched.switches[h_min]
        merged = -1
        for j, p in enumerate(dst.perms):
            if np.array_equal(p, src.perms[z]):
                merged = j
                break
        setup = 0.0 if merged >= 0 else delta
        mu = (loads[h_max] + loads[h_min] + setup) / 2.0
        tau = loads[h_max] - mu
        if tau <= 0 or src.alphas[z] <= tau:
            break
        src.alphas[z] -= tau
        if merged >= 0:
            dst.alphas[merged] += tau
        else:
            dst.perms.append(src.perms[z].copy())
            dst.alphas.append(tau)
        loads[h_max] -= tau
        loads[h_min] += setup + tau
    return sched


@pytest.mark.parametrize("seed", range(5))
def test_host_merge_aware_hashing_matches_rescan(seed):
    rng = np.random.default_rng(seed)
    n, s, delta = 8, 3, 0.05
    D = sparse_demand(rng, n, density=0.7)
    base = schedule_lpt(decompose(D), s, delta)
    # Mixed perm dtypes (device int32 next to host int64) must hash alike,
    # exactly as np.array_equal treated them.
    for sw in base.switches:
        sw.perms = [
            p.astype(np.int32) if j % 2 else p for j, p in enumerate(sw.perms)
        ]
    ref = _equalize_merge_reference(copy.deepcopy(base))
    got = equalize(copy.deepcopy(base), merge_aware=True)
    assert got.makespan() == pytest.approx(ref.makespan(), rel=1e-12)
    assert sorted(got.loads()) == pytest.approx(sorted(ref.loads()))
    assert got.num_configs() == ref.num_configs()


# ---------------------------------------------------------------------------
# Fused e2e: device pipeline vs host pipeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_fused_e2e_matches_host_spectra(seed):
    rng = np.random.default_rng(seed)
    n, s, delta = 10, 3, 0.01
    D = sparse_demand(rng, n, density=0.5)
    host = spectra(D, s, delta)
    res = spectra_jax_e2e(D.astype(np.float32), s, np.float32(delta))
    rel = abs(float(res.makespan) - host.makespan) / max(host.makespan, 1e-12)
    assert rel < 1e-4
    sched = ir_to_schedule(res.schedule, s)
    assert sched.makespan() == pytest.approx(float(res.makespan), rel=1e-5)
    sched.validate(D, tol=1e-4)
    # Telemetry: LPT makespan (pre-EQUALIZE) is never better than the final.
    assert float(res.lpt_makespan) >= float(res.makespan) - 1e-5


def test_fused_e2e_batched_ragged_k_validates_per_instance():
    # Densities from near-empty to dense → very different k per lane; the
    # vmapped fused call must pad/mask correctly for every one of them.
    densities = (0.05, 0.2, 0.4, 0.6, 0.8, 1.0)
    n, s, delta = 8, 2, 0.01
    Ds = np.stack(
        [
            sparse_demand(np.random.default_rng(40 + i), n, density=d)
            for i, d in enumerate(densities)
        ]
    )
    res = spectra_jax_e2e_many(Ds.astype(np.float32), s, np.float32(delta))
    ks = np.asarray(res.dec.k)
    assert len(set(ks.tolist())) > 2  # genuinely ragged decomposition sizes
    for b in range(len(densities)):
        ds = _index_ir(res.schedule, b)
        sched = ir_to_schedule(ds, s)
        sched.validate(Ds[b], tol=1e-4)
        assert sched.makespan() == pytest.approx(
            float(np.asarray(res.makespan)[b]), rel=1e-5
        )


def test_fused_e2e_zero_demand():
    res = spectra_jax_e2e(np.zeros((6, 6), np.float32), 3, np.float32(0.01))
    assert float(res.makespan) == 0.0
    assert int(np.asarray(res.dec.k)) == 0
    assert ir_num_configs(res.schedule) == 0


def test_fused_e2e_no_equalize_matches_lpt():
    rng = np.random.default_rng(9)
    D = sparse_demand(rng, 10, density=0.5)
    res = spectra_jax_e2e(
        D.astype(np.float32), 3, np.float32(0.01), do_equalize=False
    )
    assert float(res.makespan) == pytest.approx(float(res.lpt_makespan), rel=1e-6)
    host = schedule_lpt(to_decomposition(res.dec), 3, 0.01)
    assert float(res.makespan) == pytest.approx(host.makespan(), rel=1e-5)


# ---------------------------------------------------------------------------
# DECOMPOSE regression: a round that newly covers nothing must get α = 0
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_decompose_jax_alphas_always_finite(seed):
    rng = np.random.default_rng(seed)
    n = 12
    # Adversarial shapes: very sparse, constant-valued, and single-line-heavy
    # supports — the cases where a matching can cross only already-covered
    # entries and the α = min-over-covered mask goes empty.
    mats = [
        sparse_demand(rng, n, density=0.08),
        (rng.random((n, n)) < 0.3).astype(np.float32) * 0.5,
        np.diag(rng.random(n)) + np.eye(n, k=1) * 0.25,
    ]
    for D in mats:
        dec = decompose_jax(np.asarray(D, np.float32))
        alphas = np.asarray(dec.alphas)
        assert np.isfinite(alphas).all()
        assert (alphas >= 0).all()
        host = to_decomposition(dec)
        assert host.covers(np.asarray(D), tol=1e-4)


# ---------------------------------------------------------------------------
# Lazy materialization through the API layer
# ---------------------------------------------------------------------------

def test_solve_many_stays_lazy_until_touched():
    from repro.api import SolveOptions, solve_many

    rng = np.random.default_rng(11)
    Ds = np.stack([sparse_demand(rng, 8) * 0.1 for _ in range(4)])
    reports = solve_many(
        Ds, 2, 0.01, solver="spectra_jax",
        options=SolveOptions(validate=False, compute_lb=False),
    )
    for rep in reports:
        assert isinstance(rep.schedule, LazySchedule)
        assert not rep.schedule.materialized
        assert rep.makespan == rep.extras["device_makespan"]
        assert rep.extras["fused"] and rep.extras["batched"]
        # The raw decomposition stays attached (as before the fusion).
        assert rep.decomposition is not None
        assert rep.decomposition.k == rep.extras["k"]
    # Touching one schedule materializes just that instance.
    m = reports[2].schedule.makespan()
    assert reports[2].schedule.materialized
    assert not reports[0].schedule.materialized
    assert m == pytest.approx(reports[2].makespan, rel=1e-4)
    reports[2].schedule.validate(Ds[2], tol=1e-4)


def test_solve_many_validation_materializes_and_agrees():
    from repro.api import Problem, solve, solve_many
    from repro.fabric.simulator import simulate

    rng = np.random.default_rng(12)
    Ds = np.stack([sparse_demand(rng, 8) * 0.2 for _ in range(3)])
    reports = solve_many(Ds, 2, 0.01, solver="spectra_jax")
    for b, rep in enumerate(reports):
        assert rep.validated and rep.schedule.materialized
        sim = simulate(rep, Ds[b], tol=1e-4)
        assert sim.demand_met
        assert sim.finish_time == pytest.approx(rep.makespan, rel=1e-6)
        host = solve(Problem(Ds[b], 2, 0.01), solver="spectra")
        rel = abs(rep.makespan - host.makespan) / max(host.makespan, 1e-12)
        assert rel < 1e-4


def test_pipeline_jax_equalizer_stage():
    from repro.api import EQUALIZERS, Pipeline, Problem

    assert "jax" in EQUALIZERS and "jax_merge_aware" in EQUALIZERS
    rng = np.random.default_rng(13)
    D = sparse_demand(rng, 10, density=0.5) * 0.1
    problem = Problem(D, 3, 0.01)
    via_jax = Pipeline(equalize="jax")(problem)
    assert via_jax.backend == "jax"  # device stage ⇒ float32 tolerance
    via_host = Pipeline()(problem)
    rel = abs(via_jax.makespan - via_host.makespan) / max(via_host.makespan, 1e-12)
    assert rel < 1e-4
    # Stage kwargs that work on the host equalizer work on the device one.
    capped = Pipeline(equalize="jax", equalize_kwargs={"max_iters": 2})(problem)
    assert capped.makespan >= via_jax.makespan - 1e-6


def test_solver_service_drains_through_fused_path():
    from repro.serve.engine import SolverService

    rng = np.random.default_rng(14)
    svc = SolverService(s=2, delta=0.01, solver="spectra_jax")
    mats = {}
    for n in (8, 8, 8, 6):
        D = sparse_demand(rng, n) * 0.1
        mats[svc.submit(D)] = D
    reports = svc.flush()
    assert set(reports) == set(mats)
    # The three 8×8 submissions went through one fused device call.
    sizes = [reports[t].extras.get("batch_size") for t in reports]
    assert sizes.count(3) == 3
    for ticket, D in mats.items():
        assert reports[ticket].extras.get("fused")
        reports[ticket].schedule.validate(D, tol=1e-4)
