"""Decode-with-cache must match the full forward pass (teacher forcing).

Covers every cache mechanism: dense KV, GQA, ring-buffer sliding window,
MoE, SSD state + conv state, hybrid shared-attn, M-RoPE, enc-dec cross-attn.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # per-arch decode replay compiles: ~2.5 min total

from repro.configs.base import ShapeCfg
from repro.configs.registry import ARCHS
from repro.models.registry import build_model, concrete_inputs

S = 24
SHAPE = ShapeCfg("dec_smoke", seq_len=S, global_batch=2, kind="train")

DECODE_ARCHS = [
    "granite-3-8b",      # dense GQA
    "gemma3-27b",        # sliding-window ring buffer + pattern
    "qwen3-moe-30b-a3b", # MoE
    "deepseek-moe-16b",  # MoE with shared experts
    "mamba2-2.7b",       # SSD + conv state
    "zamba2-1.2b",       # hybrid shared attention
    "qwen2-vl-2b",       # M-RoPE
    "whisper-tiny",      # enc-dec cross attention
]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    from dataclasses import replace

    cfg = ARCHS[arch].reduced()
    if cfg.moe:
        # Capacity-based MoE drops tokens under contention; the full forward
        # (T=B·S tokens) and decode (T=B tokens) see different contention.
        # For exact equivalence, give every expert full capacity.
        cfg = replace(
            cfg, moe=replace(cfg.moe, capacity_factor=cfg.moe.num_experts / cfg.moe.top_k)
        )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = concrete_inputs(cfg, SHAPE)
    tokens = batch["tokens"]

    full = model.apply(params, batch)["logits"]  # (B, S, V)

    enc_out = None
    if cfg.family == "audio":
        enc_out = model.encode(params, batch["frames"])
    cache = model.init_cache(params, batch_size=2, max_len=S, enc_out=enc_out)
    got = []
    for t in range(S):
        logits, cache = model.decode_step(params, cache, tokens[:, t : t + 1])
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)  # (B, S, V)

    if cfg.family == "vlm":
        # Decode replay has no patch embeddings; compare a pure-text batch.
        full = model.apply(params, {"tokens": tokens})["logits"]
    np.testing.assert_allclose(
        np.array(got), np.array(full), rtol=2e-3, atol=2e-3
    )


def test_gemma3_ring_buffer_cache_is_window_sized():
    cfg = ARCHS["gemma3-27b"].reduced()
    model = build_model(cfg)
    cache = model.init_cache(None, batch_size=1, max_len=S)
    # Local layers: cache length == window (< S); global layers: full length.
    local_len = cache["periods"][0]["k"].shape[3]
    global_len = cache["periods"][-1]["k"].shape[3]
    assert local_len == cfg.window < S or local_len == S
    assert global_len == S


def test_decode_greedy_generation_deterministic():
    cfg = ARCHS["granite-3-8b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))

    def gen(seed):
        cache = model.init_cache(params, 1, 16)
        tok = jnp.full((1, 1), 7, jnp.int32)
        out = []
        for _ in range(8):
            logits, cache = model.decode_step(params, cache, tok)
            tok = logits.argmax(-1).astype(jnp.int32)
            out.append(int(tok[0, 0]))
        return out

    assert gen(0) == gen(1)
