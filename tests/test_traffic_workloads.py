"""Traffic models, workload generators, and fabric normalization."""

import numpy as np
import pytest

from repro.core import degree
from repro.fabric.ocs import OCSFabric
from repro.traffic.collectives import (
    Placement,
    TrafficModel,
    add_noise,
    normalize_max_line,
    sinkhorn,
)
from repro.traffic.workloads import benchmark_workload, gpt3b_workload, moe_workload


def test_ring_allreduce_bytes():
    tm = TrafficModel(Placement(4, 1))
    tm.ring_allreduce([0, 1, 2, 3], 8.0)
    # each member sends 2*(g-1)/g*V = 12 bytes to its successor
    assert tm.demand_bytes[0, 1] == pytest.approx(12.0)
    assert tm.demand_bytes[3, 0] == pytest.approx(12.0)
    assert tm.demand_bytes.sum() == pytest.approx(48.0)


def test_allgather_half_of_allreduce():
    tm1 = TrafficModel(Placement(4, 1))
    tm1.ring_allgather([0, 1, 2, 3], 8.0)
    tm2 = TrafficModel(Placement(4, 1))
    tm2.ring_allreduce([0, 1, 2, 3], 8.0)
    assert tm1.demand_bytes.sum() * 2 == pytest.approx(tm2.demand_bytes.sum())


def test_all_to_all_uniform():
    tm = TrafficModel(Placement(4, 1))
    tm.all_to_all([0, 1, 2, 3], 16.0)
    off_diag = tm.demand_bytes[~np.eye(4, dtype=bool)]
    assert np.allclose(off_diag, 4.0)


def test_intra_rack_traffic_excluded():
    tm = TrafficModel(Placement(8, 4))  # 2 racks of 4 chips
    tm.p2p(0, 1, 100.0)  # same rack → invisible to the optical core
    tm.p2p(0, 5, 7.0)  # cross rack
    assert tm.demand_bytes.sum() == pytest.approx(7.0)
    assert tm.demand_bytes[0, 1] == pytest.approx(7.0)


def test_sinkhorn_doubly_stochastic():
    rng = np.random.default_rng(0)
    D = rng.random((16, 16)) * (rng.random((16, 16)) < 0.4) + np.eye(16) * 0.1
    S = sinkhorn(D)
    assert np.allclose(S.sum(1), 1.0, atol=1e-6)
    assert np.allclose(S.sum(0), 1.0, atol=1e-6)


def test_gpt_workload_characteristics():
    D = gpt3b_workload(rng=np.random.default_rng(0))
    assert D.shape == (32, 32)
    assert (D >= 0).all()
    # quite sparse, doubly stochastic (±noise), strongly skewed
    assert (D > 0).mean() < 0.5
    assert np.allclose(D.sum(1), 1.0, atol=0.05)
    nz = D[D > 0]
    assert nz.max() / np.median(nz) > 3.0  # skew


def test_moe_workload_characteristics():
    D = moe_workload(rng=np.random.default_rng(0))
    assert D.shape == (64, 64)
    assert np.all(D.diagonal() == 0)  # local expert stays on-GPU
    assert (D > 0).mean() > 0.9  # dense
    assert max(D.sum(1).max(), D.sum(0).max()) <= 1.0 + 1e-9  # sub-stochastic
    assert degree(D) >= 60


def test_benchmark_workload_structure():
    D = benchmark_workload(rng=np.random.default_rng(1))
    assert D.shape == (100, 100)
    assert degree(D) <= 16
    # 70/30 split between 4 big and 12 small flows
    assert D.sum() == pytest.approx(100.0, rel=0.05)


def test_benchmark_degree_is_usually_m():
    # Appendix: for n=100, k=16, P(degree=k) ≈ 1.
    hits = sum(
        degree(benchmark_workload(rng=np.random.default_rng(s), noise=0)) == 16
        for s in range(5)
    )
    assert hits >= 4


def test_ocs_fabric_seconds_conversion():
    from repro.core import spectra

    fabric = OCSFabric(num_switches=4, reconfig_delay_s=10e-6,
                       link_bandwidth_Bps=50e9)
    demand = np.zeros((8, 8))
    demand[0, 1] = 500e9  # 500 GB must flow rack0→rack1
    res, cct = fabric.schedule_bytes(demand)
    # EQUALIZE spreads the one 500 GB element over all 4 parallel OCSes
    # (each ToR has a link into every switch): 500GB/(4·50GB/s) + one δ.
    assert cct == pytest.approx(500e9 / (4 * 50e9) + 10e-6, rel=1e-5)
    assert res.makespan == pytest.approx(0.25 + 1e-6, rel=1e-5)


def test_schedule_bytes_all_zero_demand():
    # Regression: all-zero demand must flow through normalize → solve → CCT
    # with well-defined zeros everywhere, not NaN/∞ from the δ/unit_s math.
    from repro.fabric.simulator import simulate

    fabric = OCSFabric(num_switches=4, reconfig_delay_s=10e-6)
    zeros = np.zeros((8, 8))
    D, unit_s = fabric.normalize(zeros)
    assert unit_s == 0.0
    assert (D == 0).all()
    assert fabric.delta_units(unit_s) == 0.0
    res, cct = fabric.schedule_bytes(zeros)
    assert cct == 0.0
    assert res.makespan == 0.0
    assert res.num_configs == 0
    assert res.validated
    assert res.optimality_gap == 1.0  # degenerate 0/0 pins to 1.0
    sim = simulate(res, zeros)
    assert sim.demand_met and sim.finish_time == 0.0


def test_normalize_and_noise_helpers():
    rng = np.random.default_rng(0)
    D = rng.random((6, 6))
    N = normalize_max_line(D)
    assert max(N.sum(1).max(), N.sum(0).max()) == pytest.approx(1.0)
    noisy = add_noise(N, 0.01, rng)
    assert (noisy[N > 0] > 0).all()
