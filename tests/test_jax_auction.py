"""JAX auction solver + on-device decompose vs the exact numpy path."""

import jax.numpy as jnp
import numpy as np
import pytest
from scipy.optimize import linear_sum_assignment

from repro.core import degree, lower_bound, schedule_lpt, equalize
from repro.core.jaxopt.auction import auction_maximize, auction_maximize_batch
from repro.core.jaxopt.decompose_jax import (
    decompose_jax,
    lpt_schedule_jax,
    spectra_jax,
    to_decomposition,
)


@pytest.mark.parametrize("n", [4, 16, 33, 64])
@pytest.mark.parametrize("seed", [0, 1])
def test_auction_optimal_vs_jv(n, seed):
    rng = np.random.default_rng(seed)
    W = rng.integers(0, 1000, (n, n)).astype(np.float32)
    perm, conv = auction_maximize(jnp.array(W))
    assert bool(conv)
    perm = np.array(perm)
    assert len(np.unique(perm)) == n  # valid permutation
    ri, ci = linear_sum_assignment(W, maximize=True)
    opt = W[ri, ci].sum()
    got = W[np.arange(n), perm].sum()
    assert got >= opt - 1e-3 * abs(opt)


def test_auction_batched():
    rng = np.random.default_rng(0)
    Ws = rng.random((5, 24, 24)).astype(np.float32)
    perms, convs = auction_maximize_batch(jnp.array(Ws))
    assert bool(convs.all())
    for b in range(5):
        perm = np.array(perms[b])
        ri, ci = linear_sum_assignment(Ws[b], maximize=True)
        assert Ws[b][np.arange(24), perm].sum() >= Ws[b][ri, ci].sum() - 1e-3


def test_auction_with_pallas_kernel_path():
    rng = np.random.default_rng(1)
    W = rng.integers(0, 500, (32, 32)).astype(np.float32)
    p_plain, _ = auction_maximize(jnp.array(W), use_kernel=False)
    p_kern, conv = auction_maximize(jnp.array(W), use_kernel=True)
    assert bool(conv)
    v_plain = W[np.arange(32), np.array(p_plain)].sum()
    v_kern = W[np.arange(32), np.array(p_kern)].sum()
    assert v_kern == pytest.approx(v_plain, rel=1e-5)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_decompose_jax_invariants(seed):
    rng = np.random.default_rng(seed)
    n = 16
    D = (rng.random((n, n)) * (rng.random((n, n)) < 0.3)).astype(np.float32)
    D[0, 1] = 0.9
    dec = decompose_jax(jnp.array(D))
    assert bool(dec.converged)
    assert int(dec.k) == degree(D)
    host = to_decomposition(dec)
    assert host.covers(D, tol=1e-5)


def test_spectra_jax_end_to_end():
    rng = np.random.default_rng(3)
    n, s, delta = 16, 4, 0.01
    D = (rng.random((n, n)) * (rng.random((n, n)) < 0.4)).astype(np.float32)
    D[2, 3] = 1.0
    dec, assignment, loads, makespan = spectra_jax(jnp.array(D), s, delta)
    k = int(dec.k)
    # Real jobs all placed; padded rounds unplaced.
    a = np.array(assignment)
    assert (a[:k] >= 0).all() and (a[k:] == -1).all()
    # Device LPT agrees with host LPT makespan on the same decomposition.
    host = to_decomposition(dec)
    host_sched = schedule_lpt(host, s, delta)
    assert float(makespan) == pytest.approx(host_sched.makespan(), rel=1e-5)
    # Host EQUALIZE finishes the pipeline; result ≥ lower bound.
    final = equalize(host_sched)
    final.validate(D, tol=1e-5)
    assert final.makespan() >= lower_bound(D, s, delta) - 1e-6
