"""Data pipeline, optimizer schedules, gradient compression, HLO parser."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import parse_collectives
from repro.data.pipeline import DataConfig, TokenStream, make_stream
from repro.train.grad_compress import compress_topk, compression_ratio, init_error
from repro.train.optimizer import AdamW, cosine_schedule, warmup_stable_decay


def test_stream_deterministic_and_resumable():
    s = make_stream(1000, 32, 4, seed=7)
    a = s.next_batch(5)["tokens"]
    b = s.next_batch(5)["tokens"]
    np.testing.assert_array_equal(np.array(a), np.array(b))
    c = s.next_batch(6)["tokens"]
    assert not np.array_equal(np.array(a), np.array(c))


def test_stream_host_shards_disjoint_batches():
    cfg0 = DataConfig(1000, 16, 8, seed=1, num_hosts=2, host_id=0)
    cfg1 = DataConfig(1000, 16, 8, seed=1, num_hosts=2, host_id=1)
    a = TokenStream(cfg0).next_batch(3)["tokens"]
    b = TokenStream(cfg1).next_batch(3)["tokens"]
    assert a.shape == (4, 16) and b.shape == (4, 16)
    assert not np.array_equal(np.array(a), np.array(b))


def test_wsd_schedule_shape():
    lr = warmup_stable_decay(1.0, 1000, warmup=0.1, decay=0.2, floor=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(100)) == pytest.approx(1.0)
    assert float(lr(500)) == pytest.approx(1.0)  # stable phase
    assert float(lr(1000)) == pytest.approx(0.1)  # decayed to floor
    assert float(lr(900)) > float(lr(950)) > float(lr(1000))


def test_cosine_schedule_monotone_down_after_warmup():
    lr = cosine_schedule(1.0, 100, warmup=0.1)
    vals = [float(lr(s)) for s in range(10, 100, 10)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


def test_adamw_reduces_quadratic_loss():
    opt = AdamW(schedule=lambda s: 0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 0.05


def test_grad_compress_error_feedback_preserves_mass():
    rng = np.random.default_rng(0)
    grads = {"a": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    err = init_error(grads)
    sent_total = jnp.zeros_like(grads["a"])
    g_sum = jnp.zeros_like(grads["a"])
    for _ in range(30):
        sent, err = compress_topk(grads, err, frac=0.05)
        sent_total = sent_total + sent["a"]
        g_sum = g_sum + grads["a"]
        nz = float((sent["a"] != 0).mean())
        assert nz <= 0.08  # ~top-5% kept
    # Error feedback: cumulative sent ≈ cumulative gradient (residual bounded)
    resid = float(jnp.abs(g_sum - sent_total - err["a"]).max())
    assert resid < 1e-4


def test_compression_ratio_sane():
    grads = {"a": jnp.zeros((1000,)), "b": jnp.zeros((50, 50))}
    r = compression_ratio(grads, frac=0.05)
    assert 0.05 < r < 0.2  # ~10% payload (values+indices)


def test_hlo_parser_on_synthetic_module():
    txt = """
  %all-reduce.1 = f32[1024]{0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%sum
  %ag = bf16[64,256]{1,0} all-gather(%y), replica_groups={{0,1,2,3}}, dimensions={1}
  %all-gather-start.2 = (bf16[8,16]{1,0}, bf16[8,64]{1,0}) all-gather-start(%z), replica_groups=[4,4]<=[16]
  %all-gather-done.2 = bf16[8,64]{1,0} all-gather-done(%all-gather-start.2)
  %cp = f32[32]{0} collective-permute(%w), source_target_pairs={{0,1}}
"""
    stats = parse_collectives(txt)
    assert stats.ops["all-reduce"] == 1
    assert stats.ops["all-gather"] == 2  # plain + start (done skipped)
    assert stats.ops["collective-permute"] == 1
    # all-reduce: 2*(15/16)*4096B = 7680
    assert stats.wire_bytes["all-reduce"] == pytest.approx(2 * 15 / 16 * 4096)
    # plain AG: (3/4)*64*256*2 = 24576; start AG: (3/4)*8*64*2 = 768
    assert stats.wire_bytes["all-gather"] == pytest.approx(24576 + 768)
    assert stats.wire_bytes["collective-permute"] == pytest.approx(128)


def test_demand_from_collectives_shapes():
    from repro.traffic.hlo_traffic import demand_from_collectives

    D = demand_from_collectives(
        {"all-reduce": 1e9, "all-to-all": 5e8},
        n_chips=256, chips_per_rack=8,
    )
    assert D.shape == (32, 32)
    assert (D >= 0).all() and D.sum() > 0
    assert np.all(D.diagonal() == 0)
