"""Flash-attention Pallas kernel vs oracle: shape/dtype/mask sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import mha
from repro.kernels.flash_attention.ref import mha_ref


def rand_qkv(rng, B, Hq, Hkv, Sq, Sk, D, dtype=jnp.float32):
    q = jnp.asarray(rng.standard_normal((B, Hq, Sq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Sk, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Sk, D)), dtype)
    return q, k, v


@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Sk,D",
    [
        (1, 2, 2, 32, 32, 16),     # MHA square
        (2, 4, 2, 64, 64, 32),     # GQA 2:1
        (1, 8, 2, 16, 128, 64),    # GQA 4:1, decode-ish (Sq << Sk)
        (1, 3, 1, 24, 48, 8),      # MQA, odd shapes
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(B, Hq, Hkv, Sq, Sk, D, causal):
    rng = np.random.default_rng(0)
    q, k, v = rand_qkv(rng, B, Hq, Hkv, Sq, Sk, D)
    out = mha(q, k, v, causal=causal, impl="pallas", interpret=True)
    ref = mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.array(out), np.array(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [8, 32, 1024])
def test_sliding_window(window):
    rng = np.random.default_rng(1)
    q, k, v = rand_qkv(rng, 1, 2, 2, 64, 64, 16)
    out = mha(q, k, v, causal=True, window=window, impl="pallas", interpret=True)
    ref = mha_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.array(out), np.array(ref), rtol=2e-5, atol=2e-5)


def test_bf16_tolerance():
    rng = np.random.default_rng(2)
    q, k, v = rand_qkv(rng, 1, 2, 1, 32, 32, 32, dtype=jnp.bfloat16)
    out = mha(q, k, v, impl="pallas", interpret=True)
    ref = mha_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    )
    np.testing.assert_allclose(
        np.array(out, np.float32), np.array(ref), rtol=2e-2, atol=2e-2
    )


def test_decode_single_query():
    rng = np.random.default_rng(3)
    q, k, v = rand_qkv(rng, 2, 4, 4, 1, 96, 32)
    out = mha(q, k, v, causal=True, impl="pallas", interpret=True)
    ref = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.array(out), np.array(ref), rtol=2e-5, atol=2e-5)


def test_gradients_flow_through_hybrid():
    rng = np.random.default_rng(4)
    q, k, v = rand_qkv(rng, 1, 2, 1, 16, 16, 8)

    def loss_pallas(q, k, v):
        return (mha(q, k, v, impl="pallas", interpret=True) ** 2).sum()

    def loss_ref(q, k, v):
        return (mha(q, k, v, impl="reference") ** 2).sum()

    g_p = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    g_r = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_p, g_r):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-4, atol=1e-4)
