"""Hypothesis property-based tests for the SPECTRA system invariants.

Invariants under test, for arbitrary nonnegative demand matrices, switch
counts and reconfiguration delays:

  I1  decompose() emits exactly degree(D) permutations and covers D.
  I2  every pipeline's schedule covers D (Eq. 3), with nonnegative weights.
  I3  makespan ≥ lower_bound(D, s, δ)   (§IV soundness).
  I4  EQUALIZE never increases the makespan.
  I5  SPECTRA++ is never worse than paper-faithful SPECTRA.
  I6  the event-level simulator agrees with the analytic makespan.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

pytestmark = pytest.mark.slow  # hypothesis sweeps: long where hypothesis is installed
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    baseline_less,
    decompose,
    degree,
    lower_bound,
    spectra,
    spectra_pp,
)
from repro.fabric.simulator import simulate


@st.composite
def demand_matrices(draw, max_n=10):
    n = draw(st.integers(min_value=2, max_value=max_n))
    density = draw(st.floats(min_value=0.1, max_value=1.0))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    D = rng.random((n, n)) * (rng.random((n, n)) < density)
    if not (D > 0).any():
        D[rng.integers(n), rng.integers(n)] = rng.random() + 0.1
    return D


matrix_cases = st.tuples(
    demand_matrices(),
    st.integers(min_value=1, max_value=5),  # s
    st.floats(min_value=1e-4, max_value=0.5),  # delta
)


@settings(max_examples=40, deadline=None)
@given(demand_matrices())
def test_i1_decompose_exact_and_covers(D):
    dec = decompose(D)
    assert dec.k == degree(D)
    assert dec.covers(D)
    assert all(a >= 0 for a in dec.alphas)


@settings(max_examples=30, deadline=None)
@given(matrix_cases)
def test_i2_i3_i6_pipeline_invariants(case):
    D, s, delta = case
    res = spectra(D, s, delta)  # validate=True checks coverage (I2)
    assert res.makespan >= res.lower_bound - 1e-9  # I3
    rep = simulate(res.schedule, D)  # I6
    assert rep.demand_met
    assert abs(rep.finish_time - res.makespan) <= 1e-6 * max(1.0, res.makespan)


@settings(max_examples=30, deadline=None)
@given(matrix_cases)
def test_i4_equalize_never_hurts(case):
    D, s, delta = case
    with_eq = spectra(D, s, delta, do_equalize=True).makespan
    without = spectra(D, s, delta, do_equalize=False).makespan
    assert with_eq <= without + 1e-9


@settings(max_examples=25, deadline=None)
@given(matrix_cases)
def test_i5_spectra_pp_not_worse(case):
    D, s, delta = case
    base = spectra(D, s, delta).makespan
    pp = spectra_pp(D, s, delta).makespan
    assert pp <= base + 1e-9


@settings(max_examples=20, deadline=None)
@given(matrix_cases)
def test_baseline_covers_and_bounded_below(case):
    D, s, delta = case
    sched = baseline_less(D, s, delta)
    sched.validate(D)
    assert sched.makespan() >= lower_bound(D, s, delta) - 1e-9
