"""§IV lower bounds: formulas, dominance, and LB ≤ achieved makespan."""

import numpy as np
import pytest

from repro.core import lb_theorem1, lb_theorem2, lower_bound, spectra, spectra_pp


def test_theorem1_example():
    # Paper's example: doubly stochastic row with k_i=16 nonzeros, s=4:
    # LB = (1 + 16δ)/4 = 1/4 + 4δ.
    delta = 0.01
    assert lb_theorem1(1.0, 16, 4, delta) == pytest.approx(0.25 + 4 * delta)


def test_theorem1_small_k_uses_s():
    # k_i < s → the δ term is δ·s/s = δ (the "w/s + δ" branch).
    assert lb_theorem1(1.0, 2, 4, 0.1) == pytest.approx(1.0 / 4 + 0.1)


def test_theorem2_single_switch():
    # s=1, single element x: LB2 = δ + x.
    assert lb_theorem2(np.array([0.7]), 1, 0.05) == pytest.approx(0.75)


def test_theorem2_at_least_theorem1_when_applicable():
    rng = np.random.default_rng(0)
    for s in (2, 3, 4, 8):
        for _ in range(20):
            x = rng.random(s) + 0.01
            w = x.sum()
            lb1 = lb_theorem1(w, s, s, 0.02)
            lb2 = lb_theorem2(x, s, 0.02)
            assert lb2 >= lb1 - 1e-12


def test_theorem2_strictly_better_when_unequal():
    # Paper: strict when not all nonzero elements are equal.
    x = np.array([0.9, 0.05, 0.05])
    s, delta = 3, 0.01
    assert lb_theorem2(x, s, delta) > lb_theorem1(x.sum(), s, s, delta) + 1e-9


def test_theorem2_requires_s_elements():
    with pytest.raises(ValueError):
        lb_theorem2(np.array([1.0, 2.0]), 3, 0.1)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("s", [1, 2, 4])
def test_lb_below_spectra_makespan(seed, s):
    rng = np.random.default_rng(seed)
    n = 12
    D = rng.random((n, n)) * (rng.random((n, n)) < 0.35)
    D[0, 0] += 1.0
    delta = 10 ** rng.uniform(-3, -1)
    lb = lower_bound(D, s, delta)
    assert lb > 0
    for algo in (spectra, spectra_pp):
        res = algo(D, s, delta)
        assert res.makespan >= lb - 1e-9


def test_lb_zero_matrix():
    assert lower_bound(np.zeros((4, 4)), 2, 0.1) == 0.0
