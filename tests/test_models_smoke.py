"""Per-architecture smoke tests: reduced config, one forward + train step.

Each assigned architecture instantiates a REDUCED same-family config and
runs (a) a forward pass asserting output shape and finiteness, (b) one
gradient step asserting finite grads and a finite loss.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # compiles every registered arch: ~40 s

from repro.configs.base import ShapeCfg
from repro.configs.registry import ARCHS
from repro.models.registry import build_model, concrete_inputs

SMOKE_SHAPE = ShapeCfg("smoke", seq_len=32, global_batch=2, kind="train")

ALL_ARCHS = sorted(ARCHS.keys())


@pytest.fixture(scope="module")
def smoke_cache():
    return {}


def _setup(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = concrete_inputs(cfg, SMOKE_SHAPE)
    return cfg, model, params, batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, model, params, batch = _setup(arch)
    out = model.apply(params, batch)
    logits = out["logits"]
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_finite_grads(arch):
    cfg, model, params, batch = _setup(arch)

    def loss_fn(p):
        loss, _ = model.loss(p, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{arch}: bad grads"
    # Loss should be near ln(V) at init (uniform predictions).
    assert float(loss) < np.log(cfg.vocab_size) * 2.5


def test_moe_expert_load_stats():
    cfg = ARCHS["qwen3-moe-30b-a3b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = concrete_inputs(cfg, SMOKE_SHAPE)
    _, metrics = model.loss(params, batch)
    load = metrics["expert_load"]
    assert load.shape == (cfg.moe.num_experts,)
    # every routed (token, choice) pair lands on some expert, in every layer
    n_layers = cfg.num_layers
    assert float(load.sum()) == pytest.approx(
        2 * 32 * cfg.moe.top_k * n_layers, rel=1e-6
    )


def test_shared_attention_params_are_shared():
    """zamba2: the attention block params appear once, not per group."""
    cfg = ARCHS["zamba2-1.2b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert "shared_attn" in params
    assert params["shared_attn"]["wq"].ndim == 2  # unstacked (no group dim)
    n_groups = cfg.num_layers // cfg.attn_every
    assert params["groups"][0]["w_xz"].shape[0] == n_groups


def test_gemma3_pattern_split():
    cfg = ARCHS["gemma3-27b"]
    # 62 layers = 10 periods of (5 local + 1 global) + 2 remainder locals.
    from repro.models.lm import _layer_pattern

    period, n, rem = _layer_pattern(cfg)
    assert period == [True] * 5 + [False]
    assert n == 10 and rem == [True, True]
