"""Validate dry-run artifacts (when present) and the fabric tie-in.

These tests are skipped if the dry-run hasn't produced artifacts yet —
they gate the §Dry-run/§Roofline deliverables when it has.
"""

import json
from pathlib import Path

import numpy as np
import pytest

DRYRUN = Path(__file__).resolve().parents[1] / "benchmarks" / "out" / "dryrun"

artifacts = sorted(DRYRUN.glob("*__pod1.json")) if DRYRUN.exists() else []
pod2 = sorted(DRYRUN.glob("*__pod2.json")) if DRYRUN.exists() else []


@pytest.mark.skipif(not artifacts, reason="no dry-run artifacts yet")
def test_artifacts_have_roofline_terms():
    for p in artifacts:
        a = json.loads(p.read_text())
        r = a["roofline"]
        assert r["compute_s"] >= 0 and r["memory_s"] > 0
        assert r["dominant"] in ("compute", "memory", "collective")
        assert a["n_chips"] == 256


@pytest.mark.skipif(not pod2, reason="no multi-pod artifacts yet")
def test_multi_pod_artifacts_shard_the_pod_axis():
    for p in pod2:
        a = json.loads(p.read_text())
        assert a["n_chips"] == 512
        # multi-pod training cells must communicate across the pod axis
        if a["kind"] == "train":
            assert a["roofline"]["collectives"]["total_wire_bytes"] > 0


@pytest.mark.skipif(not artifacts, reason="no dry-run artifacts yet")
def test_train_cells_have_sane_useful_ratio():
    for p in artifacts:
        a = json.loads(p.read_text())
        if a["kind"] != "train" or not a["calibration"].get("applied"):
            continue
        u = a["roofline"]["useful_ratio"]
        assert 0.05 < u <= 1.6, f"{p.name}: useful_ratio {u}"


@pytest.mark.skipif(not artifacts, reason="no dry-run artifacts yet")
def test_fabric_scheduling_from_artifact():
    from repro.traffic.hlo_traffic import schedule_cell_demand

    train = [p for p in artifacts if json.loads(p.read_text())["kind"] == "train"]
    assert train
    art = json.loads(train[0].read_text())
    res, cct, D = schedule_cell_demand(art)
    assert D.shape == (32, 32)
    if D.max() > 0:
        assert cct > 0
        assert res.makespan >= res.lower_bound - 1e-9
