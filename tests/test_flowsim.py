"""Flow-level replay: conservation, invariants, rotor baselines, orderings.

Fast lane (the CI ``flowsim-smoke`` job runs exactly this file under
``-m "not slow"``):

  * every byte of demand is delivered — per flow and in aggregate — for
    SPECTRA and both rotor baselines on skewed and uniform traffic;
  * no switch has two serve windows up at one instant, and no flow
    finishes after the timeline's finish time;
  * the pure rotor's simulated makespan matches its closed form
    ``max_h |offsets_h| · cycles · (slot + δ)`` exactly;
  * with unbounded buffers and no indirection the flow-level finish
    agrees with the matrix-level simulator's finish to 1e-6;
  * the headline ordering from the RotorNet/Opus framing: SPECTRA beats
    rotor+VLB on p99 FCT on skewed AI traffic (gpt/moe), while on uniform
    all-to-all (n=32) rotor+VLB lands within 1.1× of SPECTRA.
"""

import numpy as np
import pytest

from repro.api import Problem, SolveOptions, list_solvers, solve
from repro.core.baselines import rotor_offsets, rotor_schedule
from repro.fabric.simulator import simulate
from repro.flowsim import (
    FabricBuffers,
    FlowSimOptions,
    FlowStats,
    flows_from_demand,
    simulate_flows,
    vlb_injections,
)
from repro.scenarios import make_trace, run_scenario
from repro.traffic.workloads import gpt3b_workload, moe_workload

_NO_LB = SolveOptions(compute_lb=False)


def _gpt_tiny() -> np.ndarray:
    return gpt3b_workload(noise=0.003, rng=np.random.default_rng(0),
                          tp=4, pp=2, dp=1)


def _uniform(n: int) -> np.ndarray:
    D = np.ones((n, n))
    np.fill_diagonal(D, 0.0)
    return D


def _replay(D, solver, **extra):
    rep = solve(
        Problem(D=D, s=4, delta=0.01), solver=solver,
        options=SolveOptions(compute_lb=False, extra=extra)
        if extra else _NO_LB,
    )
    return rep, simulate_flows(rep, D)


# ---------------------------------------------------------------------------
# Conservation and structural invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("solver", ["spectra", "rotor", "rotor_vlb"])
@pytest.mark.parametrize("traffic", ["gpt", "uniform"])
def test_bytes_conserved_per_flow_and_aggregate(solver, traffic):
    D = _gpt_tiny() if traffic == "gpt" else _uniform(8)
    _, fs = _replay(D, solver)
    assert fs.conserved and fs.port_ok
    # Per flow: delivered == size within tolerance, FCT stamped finite.
    np.testing.assert_allclose(fs.delivered, fs.flow_size, atol=1e-9)
    assert np.isfinite(fs.fct).all()
    # Aggregate: total delivered == total demand, nothing left in queues.
    assert fs.delivered_total == pytest.approx(float(D.sum()), abs=1e-9)
    assert fs.residual <= 1e-9 * fs.num_flows


@pytest.mark.parametrize("solver", ["spectra", "rotor", "rotor_vlb"])
def test_fct_bounded_by_finish(solver):
    D = _gpt_tiny()
    _, fs = _replay(D, solver)
    assert float(fs.fct.max()) <= fs.finish_time + 1e-9
    assert fs.cct == pytest.approx(float(fs.fct.max()))


def test_no_port_serves_two_flows_at_once():
    # Structural: the timeline never overlaps two windows on one switch,
    # and within a window sequential service means summed per-pair bytes
    # can't exceed the window's capacity.
    D = _gpt_tiny()
    rep, fs = _replay(D, "spectra")
    assert fs.port_ok
    from repro.fabric.timeline import build_timeline

    tl = build_timeline(rep)
    for h in range(tl.s):
        ws = sorted((w for w in tl.windows if w.switch == h),
                    key=lambda w: w.start)
        for prev, nxt in zip(ws, ws[1:]):
            assert nxt.start >= prev.end - 1e-12


def test_all_zero_demand():
    D = np.zeros((8, 8))
    _, fs = _replay(D, "rotor")
    assert fs.num_flows == 0 and fs.conserved
    assert fs.finish_time == 0.0 and fs.cct == 0.0
    assert np.isnan(fs.fct_stats.p99)  # empty sample → NaN stats


def test_finite_buffers_throttle_indirection():
    # buffer_limit=0 forbids parking bytes at intermediates: rotor_vlb's
    # undersized direct slots then cannot drain skewed demand.
    D = _gpt_tiny()
    rep = solve(Problem(D=D, s=4, delta=0.01), solver="rotor_vlb",
                options=_NO_LB)
    fs = simulate_flows(rep, D, options=FlowSimOptions(buffer_limit=0.0))
    assert not fs.conserved and fs.residual > 0
    assert fs.indirect_fraction == 0.0
    # Unbounded buffers: same schedule drains completely.
    assert simulate_flows(rep, D).conserved


# ---------------------------------------------------------------------------
# Agreement with the matrix-level simulator
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("solver", ["spectra", "spectra_pp", "rotor"])
def test_finish_agrees_with_matrix_simulator(solver):
    D = _gpt_tiny()
    rep = solve(Problem(D=D, s=4, delta=0.01), solver=solver, options=_NO_LB)
    fs = simulate_flows(
        rep, D, options=FlowSimOptions(indirection="none")
    )
    sim = simulate(rep, D)
    assert fs.finish_time == pytest.approx(sim.finish_time, abs=1e-6)


def test_reused_switches_always_array():
    # Satellite contract: SimReport.reused_switches is a per-switch bool
    # array even for stateless replay — all-False, never None.
    D = _gpt_tiny()
    rep = solve(Problem(D=D, s=4, delta=0.01), solver="spectra",
                options=_NO_LB)
    sim = simulate(rep, D)
    assert isinstance(sim.reused_switches, np.ndarray)
    assert sim.reused_switches.shape == (4,)
    assert sim.reused_switches.dtype == bool
    assert not sim.reused_switches.any()


# ---------------------------------------------------------------------------
# Rotor baselines
# ---------------------------------------------------------------------------

def test_rotor_makespan_matches_closed_form():
    n, s, delta = 8, 3, 0.01
    D = _uniform(n)
    rep = solve(Problem(D=D, s=s, delta=delta), solver="rotor",
                options=_NO_LB)
    slot = rep.extras["rotor"]["slot"]
    cycles = rep.extras["rotor"]["cycles"]
    expected = max(
        len(offs) for offs in rotor_offsets(n, s)
    ) * cycles * (slot + delta)
    assert rep.makespan == pytest.approx(expected, abs=1e-9)
    assert simulate(rep, D).finish_time == pytest.approx(expected, abs=1e-9)


def test_rotor_schedule_covers_demand_directly():
    D = _uniform(8)
    rep = solve(Problem(D=D, s=4, delta=0.01), solver="rotor")
    assert rep.validated  # Eq. 3 coverage holds for the pure rotor
    assert simulate(rep, D).demand_met


def test_rotor_vlb_skips_matrix_validation():
    D = _gpt_tiny()
    rep = solve(Problem(D=D, s=4, delta=0.01), solver="rotor_vlb")
    assert not rep.validated  # covers D only under indirection
    assert rep.extras["indirection"] == "vlb"
    assert rep.extras["warnings"]
    # The real validation: flow-level conservation (auto-enables VLB).
    fs = simulate_flows(rep, D)
    assert fs.extras["vlb"] and fs.conserved
    assert fs.indirect_fraction > 0  # skewed traffic actually detours


def test_rotor_cycles_knob():
    D = _uniform(8)
    r1 = solve(Problem(D=D, s=4, delta=0.01), solver="rotor", options=_NO_LB)
    r2 = solve(Problem(D=D, s=4, delta=0.01), solver="rotor",
               options=SolveOptions(compute_lb=False,
                                    extra={"rotor_cycles": 2}))
    assert r2.extras["rotor"]["cycles"] == 2
    # Finer slots, more δ rounds: strictly more reconfigurations.
    assert r2.num_configs == 2 * r1.num_configs
    assert simulate_flows(r2, D).conserved


# ---------------------------------------------------------------------------
# The headline orderings (ISSUE acceptance criteria)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("traffic", ["gpt", "moe"])
def test_spectra_beats_rotor_vlb_on_skewed_p99(traffic):
    if traffic == "gpt":
        D = _gpt_tiny()
    else:
        D = moe_workload(n=16, top_k=6, tokens_per_gpu=8192, skew=0.25,
                         rng=np.random.default_rng(0))
    _, fs_sp = _replay(D, "spectra")
    _, fs_rv = _replay(D, "rotor_vlb")
    assert fs_sp.conserved and fs_rv.conserved
    assert fs_sp.fct_stats.p99 < fs_rv.fct_stats.p99


def test_rotor_vlb_competitive_on_uniform():
    # The rotor's home turf: featureless all-to-all at the registered
    # evaluation size (n=32 — slot-granularity artifacts at tiny n inflate
    # the ratio). Demand-oblivious rotor+VLB must land within 1.1× of the
    # scheduled fabric's p99 FCT.
    D = _uniform(32)
    _, fs_sp = _replay(D, "spectra")
    _, fs_rv = _replay(D, "rotor_vlb")
    assert fs_sp.conserved and fs_rv.conserved
    assert fs_rv.fct_stats.p99 <= 1.1 * fs_sp.fct_stats.p99


# ---------------------------------------------------------------------------
# Components: options, stats, buffers, injection planner
# ---------------------------------------------------------------------------

def test_options_validation():
    with pytest.raises(ValueError):
        FlowSimOptions(line_rate=0.0)
    with pytest.raises(ValueError):
        FlowSimOptions(buffer_limit=-1.0)
    with pytest.raises(ValueError):
        FlowSimOptions(indirection="bogus")
    opts = FlowSimOptions.from_params({"buffer_limit": 2.0})
    assert opts.buffer_limit == 2.0 and opts.indirection == "auto"


def test_flow_stats_percentiles():
    stats = FlowStats.from_sample(np.arange(1.0, 101.0))
    assert stats.p50 == pytest.approx(50.5)
    assert stats.max == 100.0 and stats.count == 100
    empty = FlowStats.from_sample(np.array([]))
    assert np.isnan(empty.p50) and empty.count == 0


def test_flows_from_demand_includes_diagonal():
    D = np.array([[2.0, 1.0], [0.0, 3.0]])
    flows = flows_from_demand(D, tol=1e-12)
    pairs = {(f.src, f.dst): f.size for f in flows}
    assert pairs == {(0, 0): 2.0, (0, 1): 1.0, (1, 1): 3.0}


def test_buffers_respect_limit_and_staging():
    D = np.zeros((3, 3))
    D[0, 2] = 5.0
    buf = FabricBuffers(D, buffer_limit=1.0)
    assert buf.free_space(1) == 1.0
    buf.stage_arrival(1, 0, 2, 0.75)
    # Staged bytes count against the limit before the boundary commits.
    assert buf.free_space(1) == pytest.approx(0.25)
    assert not buf.relay_queue(1, 2)  # not forwardable until commit
    buf.commit()
    assert list(buf.relay_queue(1, 2)) == [0]
    assert buf.take_relay(1, 2, 0, 10.0) == pytest.approx(0.75)
    assert buf.free_space(1) == pytest.approx(1.0)


def test_vlb_injection_plan_skips_direct_and_self():
    D = np.zeros((4, 4))
    D[0, 1], D[0, 2], D[0, 3] = 5.0, 3.0, 1.0
    buf = FabricBuffers(D, buffer_limit=np.inf)
    # Window (0 → 2): never detour bytes already destined to 2 (they'd
    # ride direct) nor to the intermediate itself.
    plan = vlb_injections(buf, 0, 2, capacity=4.0)
    dests = [d for d, _ in plan]
    assert 2 not in dests and 0 not in dests
    assert dests[0] == 1  # heaviest VOQ first
    assert sum(x for _, x in plan) <= 4.0 + 1e-12


# ---------------------------------------------------------------------------
# Scenario-layer integration
# ---------------------------------------------------------------------------

def test_run_scenario_flowsim_every_solver():
    # Every registered solver flows through the same FlowSimReport.
    skip = {"spectra_jax"}  # device solver: covered by the slow test below
    for solver in list_solvers():
        if solver in skip:
            continue
        rep = run_scenario("uniform", solver=solver, flowsim=True,
                           n=8, periods=2, options=_NO_LB)
        fs = rep.flowsim_summary()
        assert fs["conserved"], solver
        assert np.isfinite(fs["fct_p99"]), solver
        assert len(rep.flowsim_reports) == 2
        row = rep.summary()
        assert row["conserved"] and "fct_p50" in row


def test_run_scenario_flowsim_off_by_default():
    rep = run_scenario("uniform", solver="spectra", n=8, periods=2,
                       options=_NO_LB)
    assert rep.flowsim_reports == [] and rep.flowsim_options is None
    assert "fct_p50" not in rep.summary()
    with pytest.raises(ValueError):
        rep.flowsim_summary()


def test_spec_flowsim_params_feed_options():
    trace = make_trace("uniform", n=8, periods=1,
                       flowsim_params={"indirection": "none"})
    rep = run_scenario(trace, solver="rotor_vlb", flowsim=True,
                       options=_NO_LB)
    assert rep.flowsim_options.indirection == "none"
    # VLB forced off: the undersized rotor_vlb slots can't drain skew-free
    # uniform demand... uniform IS drainable directly if slots cover it;
    # instead assert the option actually reached the engine.
    assert not rep.flowsim_reports[0].extras["vlb"]


@pytest.mark.slow
def test_scenario_ordering_full_size():
    # Trace-level acceptance at the registered evaluation sizes: SPECTRA
    # wins p99 on skewed gpt/moe; rotor_vlb within 1.1× on uniform n=32.
    for name in ("gpt", "moe"):
        sp = run_scenario(name, solver="spectra", flowsim=True,
                          periods=2, options=_NO_LB).flowsim_summary()
        rv = run_scenario(name, solver="rotor_vlb", flowsim=True,
                          periods=2, options=_NO_LB).flowsim_summary()
        assert sp["conserved"] and rv["conserved"]
        assert sp["fct_p99"] < rv["fct_p99"], name
    sp = run_scenario("uniform", solver="spectra", flowsim=True,
                      periods=2, options=_NO_LB).flowsim_summary()
    rv = run_scenario("uniform", solver="rotor_vlb", flowsim=True,
                      periods=2, options=_NO_LB).flowsim_summary()
    assert rv["fct_p99"] <= 1.1 * sp["fct_p99"]


@pytest.mark.slow
def test_run_scenario_flowsim_device_solver():
    pytest.importorskip("jax")
    rep = run_scenario("uniform", solver="spectra_jax", flowsim=True,
                       n=8, periods=2, options=_NO_LB)
    assert rep.flowsim_summary()["conserved"]


# ---------------------------------------------------------------------------
# Arrival processes (staggered releases)
# ---------------------------------------------------------------------------

def test_uniform_arrivals_accounting_exact_and_default_unchanged():
    """Staggered releases may lose capacity (conserved=False is legitimate
    at line_rate=1) but the byte accounting must stay an exact identity,
    and the default arrival="start" path must be byte-identical to the
    options-free replay."""
    D = _gpt_tiny()
    rep = solve(Problem(D=D, s=4, delta=0.01), solver="spectra",
                options=_NO_LB)
    base = simulate_flows(rep, D)
    explicit = simulate_flows(rep, D, options=FlowSimOptions(arrival="start"))
    np.testing.assert_array_equal(base.fct, explicit.fct)
    np.testing.assert_array_equal(base.delivered, explicit.delivered)
    assert base.residual == explicit.residual

    stag = simulate_flows(
        rep, D, options=FlowSimOptions(arrival="uniform", arrival_seed=7)
    )
    total = stag.flow_size.sum()
    # delivered + residual == total demand, to float identity.
    assert stag.delivered.sum() + stag.residual == pytest.approx(
        total, rel=1e-12
    )
    assert stag.extras["arrival"] == "uniform"
    assert stag.extras["releases"].shape == stag.fct.shape
    # Same seed → same releases → identical replay.
    again = simulate_flows(
        rep, D, options=FlowSimOptions(arrival="uniform", arrival_seed=7)
    )
    np.testing.assert_array_equal(stag.fct, again.fct)


def test_uniform_arrivals_complete_with_headroom_and_respect_release():
    """The completing case: on permutation-structured demand each pair's
    circuit is up for the whole horizon, so with line-rate headroom every
    staggered flow completes — and never before its release. (On general
    demand *any* finite schedule legitimately strands bytes released
    after their pair's last serve window; that is the arrival model's
    point, not a bug.) With ``arrival_span=0`` every release collapses to
    t=0 and the replay is byte-identical to the ``"start"`` path."""
    n = 8
    rng = np.random.default_rng(4)
    D = np.zeros((n, n))
    D[np.arange(n), rng.permutation(n)] = rng.random(n) + 0.2
    rep = solve(Problem(D=D, s=4, delta=0.01), solver="spectra",
                options=_NO_LB)
    r = simulate_flows(
        rep, D,
        options=FlowSimOptions(
            arrival="uniform", line_rate=4.0, arrival_seed=3
        ),
    )
    assert r.conserved
    assert r.completed == r.num_flows
    rel = r.extras["releases"]
    assert (r.fct >= rel - 1e-12).all()
    assert np.isfinite(r.fct).all() and (r.fct <= r.finish_time + 1e-9).all()

    Dg = _gpt_tiny()
    rep = solve(Problem(D=Dg, s=4, delta=0.01), solver="spectra",
                options=_NO_LB)
    start = simulate_flows(rep, Dg)
    span0 = simulate_flows(
        rep, Dg, options=FlowSimOptions(arrival="uniform", arrival_span=0.0)
    )
    assert span0.conserved
    np.testing.assert_array_equal(start.fct, span0.fct)
    np.testing.assert_array_equal(start.delivered, span0.delivered)
