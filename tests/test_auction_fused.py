"""Fused auction kernel: interpret-mode parity, optimality, API plumbing.

Parity contract: the Pallas kernel (``kernels.auction_fused.kernel``) and
the jnp reference (``ref.fused_auction_ref``) implement the *same* round
semantics with the same float evaluation order and the same first-index
tie-breaks, so interpret-mode runs on CPU must agree **bit-exactly** — on
the assignment AND on the learned prices — including on ragged shapes
where the kernel pads to lane-aligned 128-multiples and (above 256) tiles
columns in 128-wide blocks.

Optimality contract (slow lane): at n ∈ {256, 512}, ``auction_fused`` is
exact vs ``scipy.optimize.linear_sum_assignment`` on integer weights and
within n·eps_final on sparse floats — the same property the fast lane
asserts for every matcher at small n (test_matching_device.py).

Plumbing: ``REPRO_USE_KERNEL`` / ``SolveOptions.extra["use_kernel"]``
resolve through ``kernels.backend``; batched ``solve_many`` stays one
fused device dispatch per shape bucket.
"""

import os

import numpy as np
import pytest
from scipy.optimize import linear_sum_assignment

jax = pytest.importorskip("jax")
jnp = jax.numpy

from repro.core.jaxopt.matching import (  # noqa: E402
    _eps_schedule,
    default_matcher,
    get_matcher,
    match_auction_fused,
)
from repro.kernels.auction_fused import fused_auction, fused_auction_ref  # noqa: E402
from repro.kernels.backend import default_use_kernel, resolve_use_kernel  # noqa: E402


def _optimal(W):
    ri, ci = linear_sum_assignment(W, maximize=True)
    return W[ri, ci].sum()


def _matched_weight(W, perm):
    perm = np.asarray(perm)
    n = W.shape[0]
    assert len(np.unique(perm)) == n, "matcher returned a non-permutation"
    return W[np.arange(n), perm].sum()


def _perm_workload(n, k, rng, floor=0.05):
    D = np.zeros((n, n), dtype=np.float64)
    for _ in range(k):
        D[np.arange(n), rng.permutation(n)] += rng.random() + floor
    return D


def _bonus_weights(D):
    """DECOMPOSE-regime weights: positive demand plus node-coverage M-bonus."""
    S = D > 0
    rd, cd = S.sum(1), S.sum(0)
    k = max(rd.max(), cd.max())
    M = np.maximum(D, 0).max(axis=1).sum() + 1.0
    bonus = M * ((rd == k)[:, None].astype(float) + (cd == k)[None, :])
    return (np.maximum(D, 0) + np.where(S, bonus, 0)).astype(np.float32)


def _kernel_vs_ref(W, num_phases=8, max_iters=None):
    W = jnp.asarray(W, jnp.float32)
    n = W.shape[0]
    if max_iters is None:
        max_iters = max(2000, 60 * n)
    p0 = jnp.zeros((n,), jnp.float32)
    eps = _eps_schedule(W, num_phases)
    ker = fused_auction(W, p0, eps, max_iters=max_iters, use_kernel=True,
                        interpret=True)
    ref = fused_auction(W, p0, eps, max_iters=max_iters, use_kernel=False)
    return ker, ref


# ------------------------------------------------- interpret-mode parity

# 37/100 exercise ragged padding (n not a multiple of 128 or 8); 130 pads
# to 256 and, being ≥ 256 padded, runs the 128-wide column-tiled path.
@pytest.mark.parametrize("n", [5, 37, 100, 130])
def test_interpret_parity_random_ragged(n):
    rng = np.random.default_rng(n)
    W = rng.random((n, n)).astype(np.float32)
    (kr2c, kc2r, kp), (rr2c, rc2r, rp) = _kernel_vs_ref(W)
    np.testing.assert_array_equal(np.asarray(kr2c), np.asarray(rr2c))
    np.testing.assert_array_equal(np.asarray(kc2r), np.asarray(rc2r))
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(rp))


@pytest.mark.parametrize("n", [37, 64])
def test_interpret_parity_bonus_regime(n):
    rng = np.random.default_rng(7 * n)
    W = _bonus_weights(_perm_workload(n, 6, rng))
    (kr2c, _, kp), (rr2c, _, rp) = _kernel_vs_ref(W)
    np.testing.assert_array_equal(np.asarray(kr2c), np.asarray(rr2c))
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(rp))


def test_interpret_parity_under_vmap():
    rng = np.random.default_rng(3)
    n, B = 24, 3
    Ws = jnp.asarray(rng.random((B, n, n)), jnp.float32)

    def run(W, use_kernel):
        perm, conv = match_auction_fused(
            W, use_kernel=use_kernel, interpret=True if use_kernel else None
        )
        return perm, conv

    pk, ck = jax.vmap(lambda W: run(W, True))(Ws)
    pr, cr = jax.vmap(lambda W: run(W, False))(Ws)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    for b in range(B):
        W = np.asarray(Ws[b])
        assert bool(ck[b]) and bool(cr[b])
        assert _matched_weight(W, pk[b]) == pytest.approx(_optimal(W), rel=1e-5)


# ------------------------------------------------- matcher contract

def test_matcher_registered_and_autotuned():
    assert get_matcher("auction_fused") is match_auction_fused
    assert default_matcher(16) == "auction"
    assert default_matcher(100) == "auction_fr"
    assert default_matcher(129) == "auction_fused"
    assert default_matcher(512) == "auction_fused"


def test_warm_start_prices_round_trip():
    rng = np.random.default_rng(11)
    W = jnp.asarray(rng.random((40, 40)), jnp.float32)
    perm1, conv1, prices = match_auction_fused(W, with_prices=True)
    assert bool(conv1) and prices.shape == (40,)
    # Warm-started re-solve of the same instance: same optimum, converged.
    perm2, conv2 = match_auction_fused(W, prices0=prices)
    assert bool(conv2)
    Wn = np.asarray(W)
    assert _matched_weight(Wn, perm2) == pytest.approx(
        _matched_weight(Wn, perm1), rel=1e-5
    )


def test_greedy_completion_when_starved():
    # One round per phase can't finish the auction; the matcher must still
    # return a valid permutation (greedy completion) and report conv=False.
    rng = np.random.default_rng(5)
    W = jnp.asarray(rng.random((24, 24)), jnp.float32)
    perm, conv = match_auction_fused(W, max_iters=1)
    assert not bool(conv)
    assert sorted(np.asarray(perm).tolist()) == list(range(24))


# ------------------------------------------------- backend resolution

def test_resolve_use_kernel_env(monkeypatch):
    monkeypatch.delenv("REPRO_USE_KERNEL", raising=False)
    # No env, CPU test host → detection says False (TPU would say True).
    if jax.default_backend() != "tpu":
        assert default_use_kernel() is False
    monkeypatch.setenv("REPRO_USE_KERNEL", "1")
    assert resolve_use_kernel(None) is True
    monkeypatch.setenv("REPRO_USE_KERNEL", "0")
    assert resolve_use_kernel(None) is False
    # Explicit values always win over the env.
    monkeypatch.setenv("REPRO_USE_KERNEL", "1")
    assert resolve_use_kernel(False) is False
    assert resolve_use_kernel(True) is True


def test_env_kernel_path_through_solve_api(monkeypatch):
    from repro.api import Problem, SolveOptions, solve

    rng = np.random.default_rng(2)
    D = _perm_workload(16, 4, rng)
    monkeypatch.setenv("REPRO_USE_KERNEL", "1")
    rep = solve(
        Problem(D, s=2, delta=0.01),
        solver="spectra_jax",
        options=SolveOptions(extra={"matcher": "auction_fused"}),
    )
    assert rep.extras["use_kernel"] is True
    assert rep.extras["matcher"] == "auction_fused"
    monkeypatch.setenv("REPRO_USE_KERNEL", "0")
    rep_ref = solve(
        Problem(D, s=2, delta=0.01),
        solver="spectra_jax",
        options=SolveOptions(extra={"matcher": "auction_fused"}),
    )
    assert rep_ref.extras["use_kernel"] is False
    # Interpret-mode kernel and jnp ref share exact round semantics, so the
    # whole pipeline lands on the same makespan.
    assert rep.makespan == pytest.approx(rep_ref.makespan, rel=1e-6)


# ------------------------------------------------- dispatch counting

def _count_dispatches(monkeypatch, mats, s=2, delta=0.01, extra=None):
    import repro.api.jax_backend as jb
    from repro.api import SolveOptions, solve_many

    calls = []
    real = jb.spectra_jax_e2e_many

    def counting(Ds, *a, **kw):
        calls.append(tuple(np.asarray(Ds).shape))
        return real(Ds, *a, **kw)

    monkeypatch.setattr(jb, "spectra_jax_e2e_many", counting)
    reports = solve_many(
        mats, s, delta, solver="spectra_jax",
        options=SolveOptions(extra=extra or {}),
    )
    return calls, reports


def test_solve_many_one_dispatch_per_shape_bucket(monkeypatch):
    rng = np.random.default_rng(9)
    mats = [
        _perm_workload(16, 4, rng),
        _perm_workload(33, 4, rng),
        _perm_workload(16, 4, rng),
    ]
    calls, reports = _count_dispatches(monkeypatch, mats)
    # Two distinct n → exactly two fused dispatches, batch sizes 2 and 1.
    assert sorted(calls) == [(1, 33, 33), (2, 16, 16)]
    assert all(r.makespan > 0 for r in reports)


@pytest.mark.slow
def test_solve_many_n256_single_fused_dispatch(monkeypatch):
    rng = np.random.default_rng(10)
    mats = [_perm_workload(256, 4, rng) for _ in range(3)]
    calls, reports = _count_dispatches(monkeypatch, mats)
    assert calls == [(3, 256, 256)]
    # default_matcher(256) → the fused matcher, recorded in the report.
    assert all(r.extras["matcher"] == "auction_fused" for r in reports)
    assert all(r.makespan > 0 for r in reports)


# ------------------------------------------------- large-n optimality (slow)

@pytest.mark.slow
@pytest.mark.parametrize("n", [256, 512, 1024])
def test_fused_exact_on_random_integers_large(n):
    # Exact even at n=1024: eps_final = wmax·2⁻²² ≈ 2.4e-4 for wmax < 1000,
    # so n·eps_final ≈ 0.24 < 1, the integer-exactness threshold.
    rng = np.random.default_rng(n)
    W = rng.integers(0, 1000, (n, n)).astype(np.float32)
    perm, conv = match_auction_fused(jnp.asarray(W))
    assert bool(conv)
    assert _matched_weight(W, perm) == _optimal(W)


@pytest.mark.slow
@pytest.mark.parametrize("n", [256, 512])
def test_fused_near_optimal_on_sparse_floats_large(n):
    rng = np.random.default_rng(n + 1)
    W = (rng.random((n, n)) * (rng.random((n, n)) < 0.1)).astype(np.float32)
    perm, conv = match_auction_fused(jnp.asarray(W))
    assert bool(conv)
    opt = _optimal(W)
    got = _matched_weight(W, perm)
    # ε-scaling guarantee: within n·eps_final of optimal (eps_final is the
    # ulp-floored wmax·2⁻²² — tiny relative to these weights).
    assert got >= opt - n * float(W.max()) * 2.0**-22 - 1e-4 * opt


@pytest.mark.slow
@pytest.mark.parametrize("n,k", [(256, 16), (512, 16)])
def test_e2e_quality_vs_host_large(n, k):
    """Device pipeline with auction_fused stays within 1% of host SPECTRA."""
    from repro.api import Problem, SolveOptions, solve

    rng = np.random.default_rng(n)
    D = _perm_workload(n, k, rng)
    prob = Problem(D, s=4, delta=0.01)
    host = solve(prob, solver="spectra")
    dev = solve(
        prob,
        solver="spectra_jax",
        options=SolveOptions(extra={"matcher": "auction_fused"}),
    )
    assert dev.extras["matcher"] == "auction_fused"
    assert dev.makespan <= 1.01 * host.makespan


@pytest.mark.slow
def test_e2e_quality_vs_host_pod_1024():
    """n=1024 e2e tripwire — gated at the measured tie-break spread, not 1%.

    On the sum-of-8-permutations workload every constituent permutation has
    constant weight, so max-weight matchings are massively tie-rich. A
    round-by-round replay against scipy on identical weight matrices shows
    the fused auction's per-round deficit is EXACTLY 0.0 for all 8 rounds —
    the matcher is exactly optimal. The device/host makespan gap (measured
    1.111; 1.084 with repair_rounds=2, where repair plateaus) comes purely
    from host LSA and the auction picking *different* exactly-optimal
    matchings, whose residual spread the greedy REFINE then amortizes
    differently (device Σα 4.117 vs host 3.694, LB 3.358). Any matcher,
    including scipy itself with permuted input, shows the same spread.
    This gate is a regression tripwire for *matcher* quality at pod scale:
    a real optimality bug (deficit > 0 per round) would blow well past it.
    A tie-break-aware REFINE (bottleneck-spread-minimizing matching among
    the optimal set) is the principled fix — see ROADMAP.
    """
    from repro.api import Problem, SolveOptions, solve

    n, k = 1024, 8
    rng = np.random.default_rng(n)
    D = _perm_workload(n, k, rng)
    prob = Problem(D, s=4, delta=0.01)
    host = solve(prob, solver="spectra")
    dev = solve(
        prob,
        solver="spectra_jax",
        options=SolveOptions(extra={"matcher": "auction_fused"}),
    )
    assert dev.extras["matcher"] == "auction_fused"
    assert dev.makespan <= 1.15 * host.makespan


# ------------------------------------------------- warm-start round counts


def test_with_iters_arity_and_legacy_contract():
    """with_iters appends the round count; the default arity stays 2."""
    rng = np.random.default_rng(21)
    W = jnp.asarray(rng.random((24, 24)), jnp.float32)
    legacy = match_auction_fused(W, use_kernel=False)
    assert len(legacy) == 2
    perm, conv, iters = match_auction_fused(
        W, use_kernel=False, with_iters=True
    )
    assert bool(conv) and int(iters) > 0
    np.testing.assert_array_equal(np.asarray(perm), np.asarray(legacy[0]))
    # prices + iters together: iters comes after prices.
    out = match_auction_fused(
        W, use_kernel=False, with_prices=True, with_iters=True
    )
    assert len(out) == 4 and out[2].shape == (24,) and int(out[3]) > 0


def test_with_iters_kernel_path_reports_sentinel():
    """The Pallas kernel keeps its loop counter on-chip → -1 sentinel."""
    rng = np.random.default_rng(22)
    W = jnp.asarray(rng.random((16, 16)), jnp.float32)
    perm, conv, iters = match_auction_fused(
        W, use_kernel=True, interpret=True, with_iters=True
    )
    assert int(iters) == -1
    assert sorted(np.asarray(perm).tolist()) == list(range(16))


def test_warm_prices_converge_in_fewer_rounds_at_same_quality():
    """Cross-period price reuse: a warm start on a perturbed instance must
    bid strictly fewer rounds than a cold solve (it enters the ε schedule
    at the tail) while matching the cold solve's objective."""
    n = 32
    rng = np.random.default_rng(23)
    W1 = rng.random((n, n)).astype(np.float32)
    out = match_auction_fused(
        jnp.asarray(W1), use_kernel=False, with_prices=True, with_iters=True
    )
    prices = out[2]
    # Same traffic structure, 1% drift — the serving steady state.
    W2 = (W1 * (1.0 + 0.01 * rng.standard_normal((n, n)))).astype(np.float32)
    perm_c, conv_c, it_cold = match_auction_fused(
        jnp.asarray(W2), use_kernel=False, with_iters=True
    )
    perm_w, conv_w, it_warm = match_auction_fused(
        jnp.asarray(W2), use_kernel=False, prices0=prices, with_iters=True
    )
    assert bool(conv_c) and bool(conv_w)
    assert int(it_warm) < int(it_cold)
    assert _matched_weight(W2.astype(np.float64), perm_w) >= (
        _matched_weight(W2.astype(np.float64), perm_c) - 1e-3 * n
    )
