"""auction_bid + demand_accum kernels vs oracles: shape/dtype sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.auction_bid.ops import masked_row_top2
from repro.kernels.auction_bid.ref import masked_row_top2_ref
from repro.kernels.demand_accum.ops import demand_accum
from repro.kernels.demand_accum.ref import demand_accum_ref


@pytest.mark.parametrize("n,m", [(4, 4), (8, 128), (100, 100), (64, 257), (33, 65)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_auction_bid_kernel_sweep(n, m, dtype):
    rng = np.random.default_rng(n * 1000 + m)
    W = jnp.asarray(rng.standard_normal((n, m)) * 100, dtype)
    p = jnp.asarray(rng.standard_normal((m,)), dtype)
    v1, v2, j1 = masked_row_top2(W, p, interpret=True)
    r1, r2, rj = masked_row_top2_ref(W, p)
    np.testing.assert_allclose(np.array(v1), np.array(r1), rtol=1e-6)
    np.testing.assert_allclose(np.array(v2), np.array(r2), rtol=1e-6)
    assert np.array_equal(np.array(j1), np.array(rj))


def test_auction_bid_ties_prefer_any_argmax():
    W = jnp.zeros((4, 8), jnp.float32)
    p = jnp.zeros((8,), jnp.float32)
    v1, v2, j1 = masked_row_top2(W, p, interpret=True)
    assert np.allclose(np.array(v1), 0.0)
    assert np.allclose(np.array(v2), 0.0)
    assert ((np.array(j1) >= 0) & (np.array(j1) < 8)).all()


@pytest.mark.parametrize("T,n", [(16, 8), (100, 32), (513, 64), (2048, 128)])
def test_demand_accum_sweep(T, n):
    rng = np.random.default_rng(T + n)
    src = jnp.asarray(rng.integers(0, n, T), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, T), jnp.int32)
    w = jnp.asarray(rng.random(T), jnp.float32)
    D = demand_accum(src, dst, w, n=n, interpret=True)
    D_ref = demand_accum_ref(src, dst, w, n)
    np.testing.assert_allclose(np.array(D), np.array(D_ref), rtol=1e-5, atol=1e-5)


def test_demand_accum_duplicate_events_accumulate():
    src = jnp.asarray([1, 1, 1, 2], jnp.int32)
    dst = jnp.asarray([3, 3, 3, 0], jnp.int32)
    w = jnp.asarray([1.0, 2.0, 3.0, 5.0], jnp.float32)
    D = demand_accum(src, dst, w, n=4, interpret=True)
    assert float(D[1, 3]) == pytest.approx(6.0)
    assert float(D[2, 0]) == pytest.approx(5.0)
    assert float(np.array(D).sum()) == pytest.approx(11.0)
