"""Launcher CLIs run end-to-end (subprocess smoke tests)."""

import json
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
ENV = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
# Pin the platform in the hermetic child env (CPU unless the caller says
# otherwise): on hosts with libtpu installed but no TPU attached, an
# unpinned child hangs for minutes probing for accelerators.
ENV["JAX_PLATFORMS"] = os.environ.get("JAX_PLATFORMS", "cpu")


def run_cli(args, timeout=420):
    proc = subprocess.run(
        [sys.executable, "-m", *args],
        capture_output=True, text=True, timeout=timeout, env=ENV,
        cwd=str(REPO),
    )
    assert proc.returncode == 0, f"STDOUT:{proc.stdout}\nSTDERR:{proc.stderr}"
    return proc.stdout


def test_train_cli_with_ocs(tmp_path):
    out = run_cli([
        "repro.launch.train", "--arch", "granite-3-8b", "--steps", "12",
        "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--ocs-switches", "4", "--ocs-every", "5",
    ])
    rec = json.loads(out[out.index("{"):])
    assert rec["steps"] == 12
    assert rec["cct"], "OCS controller produced no CCT records"
    # a checkpoint was committed
    assert any(tmp_path.glob("step_*/_COMMITTED"))


def test_serve_cli():
    out = run_cli([
        "repro.launch.serve", "--arch", "minicpm-2b", "--batch", "2",
        "--prompt-len", "8", "--new-tokens", "8",
    ])
    assert "tok/s" in out


def test_perf_variants_reference_valid_kwargs():
    from repro.launch import perf

    import inspect

    from repro.launch.dryrun import run_cell

    valid = set(inspect.signature(run_cell).parameters)
    for name, kw in perf.VARIANTS.items():
        assert set(kw) <= valid, f"variant {name} has unknown kwargs"
