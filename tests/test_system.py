"""End-to-end behaviour tests for the paper's system.

Validates the headline claims of the paper on this repo's implementations:
SPECTRA covers D, beats the LESS-style BASELINE on all three workloads,
approaches the lower bound, and the full controller stack (workload →
decompose → schedule → equalize → event simulation → CCT seconds) holds
together.
"""

import numpy as np
import pytest

from repro.core import baseline_less, eclipse_decompose, lower_bound, spectra
from repro.fabric.ocs import OCSFabric
from repro.fabric.simulator import simulate
from repro.traffic.workloads import benchmark_workload, gpt3b_workload, moe_workload


@pytest.fixture(scope="module")
def workloads():
    rng = np.random.default_rng(0)
    return {
        "gpt": gpt3b_workload(rng=rng),
        "moe": moe_workload(rng=np.random.default_rng(0)),
        "benchmark": benchmark_workload(rng=np.random.default_rng(0)),
    }


@pytest.mark.parametrize("wname", ["gpt", "moe", "benchmark"])
@pytest.mark.parametrize("s,delta", [(2, 0.01), (4, 0.01), (4, 0.04)])
def test_spectra_beats_baseline_and_respects_lb(workloads, wname, s, delta):
    D = workloads[wname]
    res = spectra(D, s, delta)  # validates coverage internally
    bl = baseline_less(D, s, delta)
    bl.validate(D)
    assert res.makespan <= bl.makespan() + 1e-9, "SPECTRA worse than BASELINE"
    lb = lower_bound(D, s, delta)
    assert res.makespan >= lb - 1e-9
    # Near-optimality: the paper reports SPECTRA hugging the LB.
    assert res.makespan / lb < 1.35, f"gap too large: {res.makespan / lb}"


def test_paper_headline_ratios_directionally(workloads):
    """Average BASELINE/SPECTRA ratios ordered as the paper reports
    (benchmark 2.4x largest; GPT and MoE clearly > 1)."""
    ratios = {}
    for wname, D in workloads.items():
        rs = []
        for s in (2, 4):
            for delta in (1e-3, 1e-2, 1e-1):
                rs.append(
                    baseline_less(D, s, delta).makespan()
                    / spectra(D, s, delta).makespan
                )
        ratios[wname] = float(np.exp(np.mean(np.log(rs))))
    assert ratios["benchmark"] > ratios["gpt"] > 1.05
    assert ratios["moe"] > 1.05
    assert ratios["benchmark"] > 1.8


def test_event_simulation_agrees_everywhere(workloads):
    for D in workloads.values():
        res = spectra(D, 4, 0.02)
        rep = simulate(res.schedule, D)
        assert rep.demand_met
        assert rep.finish_time == pytest.approx(res.makespan, rel=1e-6)


def test_eclipse_variant_never_beats_spectra_much(workloads):
    """Paper: ECLIPSE-decompose variant is never better on these workloads."""
    D = workloads["moe"]
    delta = 0.01
    res = spectra(D, 4, delta)
    res_e = spectra(D, 4, delta,
                    decompose_fn=lambda M: eclipse_decompose(M, delta))
    assert res_e.makespan >= res.makespan * 0.98


def test_full_controller_stack_seconds():
    """Bytes in → seconds out, through normalization and δ conversion."""
    fabric = OCSFabric(num_switches=4, reconfig_delay_s=20e-6)
    D_bytes = moe_workload(rng=np.random.default_rng(1)) * 4e9
    res, cct = fabric.schedule_bytes(D_bytes)
    assert cct > 0
    # CCT must exceed the scaled lower bound.
    peak = D_bytes.max()
    unit_s = peak / fabric.link_bandwidth_Bps
    assert cct >= res.lower_bound * unit_s - 1e-12
