"""Multi-device distribution tests (subprocess: 8 fake CPU devices).

Run in a child process so the 8-device XLA flag never leaks into the rest
of the suite (the dry-run spec requires tests to see 1 device by default).
"""

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_child(body: str) -> str:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        # These tests fake 8 *CPU* devices; pin the platform so hosts with
        # libtpu installed but no TPU don't hang probing for accelerators.
        os.environ["JAX_PLATFORMS"] = "cpu"
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert len(jax.devices()) == 8
        """
    ) + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_sharded_train_step_matches_single_device():
    run_child("""
    from repro.configs.registry import ARCHS
    from repro.configs.base import ShapeCfg
    from repro.models.registry import build_model, concrete_inputs
    from repro.parallel.steps import make_train_step, make_optimizer
    from repro.parallel.sharding import param_shardings, batch_shardings
    from repro.launch.mesh import make_debug_mesh

    cfg = ARCHS["granite-3-8b"].reduced()
    shape = ShapeCfg("t", 32, 8, "train")
    batch = concrete_inputs(cfg, shape)
    model = build_model(cfg, attn_impl="chunked")
    opt = make_optimizer()
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    step = make_train_step(model, opt)

    # Single-device result.
    p1, o1, m1 = jax.jit(step)(params, opt_state, batch)

    # Sharded result on a 4×2 (data × model) mesh.
    mesh = make_debug_mesh(8, model=2)
    with mesh:
        ps = param_shardings(params, mesh)
        bs = batch_shardings(batch, mesh)
        params_s = jax.device_put(params, ps)
        opt_s = jax.device_put(opt_state, param_shardings(opt_state, mesh))
        batch_s = jax.device_put(batch, bs)
        p2, o2, m2 = jax.jit(step)(params_s, opt_s, batch_s)
    # Same float32 tolerance as the param check below: cross-device psum
    # ordering shifts the loss by a few 1e-3 relative on some CPU backends.
    assert np.allclose(float(m1["loss"]), float(m2["loss"]), rtol=5e-3), \
        (float(m1["loss"]), float(m2["loss"]))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.array(a, np.float32), np.array(b, np.float32),
            rtol=5e-3, atol=5e-3,
        )
    print("SHARDED_OK")
    """)


def test_moe_expert_parallel_lowering():
    run_child("""
    from repro.configs.registry import ARCHS
    from repro.configs.base import ShapeCfg
    from repro.parallel.steps import lower_cell
    from repro.launch.mesh import make_debug_mesh

    cfg = ARCHS["deepseek-moe-16b"].reduced()
    shape = ShapeCfg("t", 32, 8, "train")
    mesh = make_debug_mesh(8, model=4)  # 4-way EP over 8 experts
    lowered, meta = lower_cell(cfg, shape, mesh)
    compiled = lowered.compile()
    txt = compiled.as_text()
    assert ("all-to-all" in txt) or ("all-gather" in txt) or \
           ("all-reduce" in txt), "no collectives in EP lowering"
    print("EP_OK")
    """)


def test_elastic_restore_to_smaller_mesh():
    run_child("""
    import tempfile
    from repro.configs.registry import ARCHS
    from repro.configs.base import ShapeCfg
    from repro.models.registry import build_model, concrete_inputs
    from repro.parallel.steps import make_train_step, make_optimizer
    from repro.parallel.sharding import param_shardings, batch_shardings
    from repro.train.checkpoint import restore_checkpoint, save_checkpoint
    from repro.train.fault_tolerance import largest_mesh

    cfg = ARCHS["granite-3-8b"].reduced()
    shape = ShapeCfg("t", 32, 8, "train")
    batch = concrete_inputs(cfg, shape)
    model = build_model(cfg, attn_impl="chunked")
    opt = make_optimizer()
    step = make_train_step(model, opt)

    mesh8 = jax.make_mesh((4, 2), ("data", "model"))
    with mesh8:
        params = jax.device_put(
            model.init(jax.random.PRNGKey(0)),
            param_shardings(model.init(jax.random.PRNGKey(0)), mesh8),
        )
        opt_state = jax.device_put(
            opt.init(params), param_shardings(opt.init(params), mesh8)
        )
        p, o, m = jax.jit(step)(
            params, opt_state, jax.device_put(batch, batch_shardings(batch, mesh8))
        )
        loss8 = float(m["loss"])

    tmp = tempfile.mkdtemp()
    save_checkpoint(tmp, 1, {"params": p, "opt": o}, extra={"step": 1})

    # "Two nodes died": re-mesh to 6 devices → largest grid (3, 2).
    assert largest_mesh(6, prefer_model=2) == (3, 2)
    mesh6 = jax.sharding.Mesh(
        np.array(jax.devices()[:6]).reshape(3, 2), ("data", "model")
    )
    with mesh6:
        like = {"params": p, "opt": o}
        shard6 = {
            "params": param_shardings(p, mesh6),
            "opt": param_shardings(o, mesh6),
        }
        restored, extra = restore_checkpoint(tmp, like, shardings=shard6)
        assert extra["step"] == 1
        # One more step on the shrunken mesh must run and stay finite.
        batch6 = {"tokens": batch["tokens"][:6]}
        p2, o2, m2 = jax.jit(step)(
            restored["params"], restored["opt"],
            jax.device_put(batch6, batch_shardings(batch6, mesh6)),
        )
        assert np.isfinite(float(m2["loss"]))
    print("ELASTIC_OK")
    """)
