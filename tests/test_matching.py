"""Constrained MWM + Hungarian fallback correctness."""

import itertools

import numpy as np
import pytest

from repro.core.matching import (
    critical_lines,
    hungarian_min_cost,
    max_weight_perfect_matching,
    mwm_node_coverage,
    perm_matrix,
)


def brute_force_max(W):
    n = W.shape[0]
    best, best_perm = -np.inf, None
    for p in itertools.permutations(range(n)):
        v = W[np.arange(n), list(p)].sum()
        if v > best:
            best, best_perm = v, np.array(p)
    return best, best_perm


@pytest.mark.parametrize("n", [2, 3, 4, 5, 6])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_hungarian_matches_bruteforce(n, seed):
    rng = np.random.default_rng(seed)
    W = rng.random((n, n))
    best, _ = brute_force_max(W)
    perm = hungarian_min_cost(-W)
    assert np.isclose(W[np.arange(n), perm].sum(), best)


@pytest.mark.parametrize("n", [3, 8, 17, 32, 64])
def test_hungarian_matches_scipy(n):
    rng = np.random.default_rng(n)
    W = rng.random((n, n)) * rng.integers(1, 100)
    p_np = max_weight_perfect_matching(W, use_scipy=False)
    p_sp = max_weight_perfect_matching(W, use_scipy=True)
    v_np = W[np.arange(n), p_np].sum()
    v_sp = W[np.arange(n), p_sp].sum()
    assert np.isclose(v_np, v_sp)


def test_hungarian_negative_and_ties():
    W = np.array([[1.0, 1.0], [1.0, -5.0]])
    perm = max_weight_perfect_matching(W, use_scipy=False)
    assert W[np.arange(2), perm].sum() == pytest.approx(2.0)


@pytest.mark.parametrize("seed", range(8))
def test_node_coverage_constraint(seed):
    """Every critical line must be matched through an uncovered support edge."""
    rng = np.random.default_rng(seed)
    n = 12
    D = rng.random((n, n)) * (rng.random((n, n)) < 0.3)
    if not (D > 0).any():
        D[0, 0] = 1.0
    S = D > 0
    perm = mwm_node_coverage(D, S)  # raises internally if violated
    crit_r, crit_c, k = critical_lines(S)
    rows = np.arange(n)
    on_support = S[rows, perm]
    assert on_support[crit_r].all()


def test_perm_matrix_roundtrip():
    perm = np.array([2, 0, 1])
    P = perm_matrix(perm)
    assert P.sum() == 3
    assert (P.argmax(axis=1) == perm).all()


def test_empty_support_raises():
    with pytest.raises(ValueError):
        mwm_node_coverage(np.zeros((3, 3)), np.zeros((3, 3), bool))
