"""Checkpointing: atomicity, CRC, GC, async, restore mismatch errors."""

import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def tree_example(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
        "opt": {"mu": jnp.ones((8, 4)), "step": jnp.int32(7)},
    }


def test_roundtrip_exact(tmp_path):
    t = tree_example()
    save_checkpoint(tmp_path, 5, t, extra={"step": 5})
    restored, extra = restore_checkpoint(tmp_path, t)
    assert extra["step"] == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_latest_and_gc(tmp_path):
    t = tree_example()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, t, keep=2)
    assert latest_step(tmp_path) == 5
    committed = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(committed) == 2  # GC keeps last 2


def test_async_save(tmp_path):
    t = tree_example()
    th = save_checkpoint(tmp_path, 9, t, async_=True)
    assert isinstance(th, threading.Thread)
    th.join(timeout=30)
    assert latest_step(tmp_path) == 9


def test_uncommitted_checkpoint_ignored(tmp_path):
    t = tree_example()
    save_checkpoint(tmp_path, 3, t)
    # Simulate a crash mid-write: committed marker missing.
    broken = tmp_path / "step_000000009"
    broken.mkdir()
    (broken / "manifest.json").write_text("{}")
    assert latest_step(tmp_path) == 3


def test_crc_detects_corruption(tmp_path):
    t = tree_example()
    save_checkpoint(tmp_path, 1, t)
    d = tmp_path / "step_000000001"
    victim = next(d.glob("arr_*.npy"))
    arr = np.load(victim)
    arr_flat = arr.reshape(-1)
    arr_flat[0] += 1.0
    np.save(victim, arr)
    with pytest.raises(IOError, match="crc"):
        restore_checkpoint(tmp_path, t)


def test_structure_mismatch_raises(tmp_path):
    t = tree_example()
    save_checkpoint(tmp_path, 1, t)
    other = {"params": {"w": jnp.zeros((8, 4))}}
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, other)


def test_restore_with_resharding_device_put(tmp_path):
    t = tree_example()
    save_checkpoint(tmp_path, 2, t)
    shard = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), t
    )
    restored, _ = restore_checkpoint(tmp_path, t, shardings=shard)
    assert all(
        a.sharding == jax.sharding.SingleDeviceSharding(jax.devices()[0])
        for a in jax.tree.leaves(restored)
    )
