"""Unified solver API: registry, pipelines, batched solve, serving service.

Coverage contract (ISSUE 1):
  * every registered solver round-trips through the event-level simulator
    on random doubly-substochastic demand matrices;
  * batched JAX ``solve_many`` agrees with per-instance ``solve`` makespans
    within 1e-4 relative tolerance over a batch of ≥ 8 matrices.
"""

import numpy as np
import pytest

from repro.api import (
    Pipeline,
    Problem,
    SolveOptions,
    SolveReport,
    list_solvers,
    register_solver,
    solve,
    solve_many,
)
from repro.fabric.simulator import simulate

EXPECTED_SOLVERS = {
    "spectra",
    "spectra_no_eq",
    "spectra_pp",
    "spectra_eclipse",
    "baseline_less",
    "spectra_jax",
}


def doubly_substochastic(rng, n, density=0.5):
    """Random D with every row/column sum ≤ 1 (scaled by the max line sum)."""
    D = rng.random((n, n)) * (rng.random((n, n)) < density)
    if not (D > 0).any():
        D[rng.integers(n), rng.integers(n)] = 0.5
    T = max(D.sum(axis=0).max(), D.sum(axis=1).max())
    return D / (T * (1.0 + 0.1 * rng.random()))


def test_registry_lists_all_builtin_solvers():
    assert EXPECTED_SOLVERS <= set(list_solvers())


@pytest.mark.parametrize("solver", sorted(EXPECTED_SOLVERS))
@pytest.mark.parametrize("seed", [0, 1])
def test_every_solver_roundtrips_through_simulator(solver, seed):
    rng = np.random.default_rng(seed)
    D = doubly_substochastic(rng, 10)
    problem = Problem(D, s=3, delta=0.01)
    report = solve(problem, solver=solver)
    # Uniform report shape.
    assert isinstance(report, SolveReport)
    assert report.solver == solver
    assert report.backend == ("jax" if solver == "spectra_jax" else "numpy")
    assert report.validated
    assert report.num_configs == report.schedule.num_configs()
    assert np.isfinite(report.makespan) and report.runtime_s >= 0
    # Makespan is sound vs the §IV lower bound (float32 slack for jax).
    assert report.makespan >= report.lower_bound - 1e-3
    # Event-level replay serves all demand at the claimed makespan.
    tol = 1e-4 if report.backend == "jax" else 1e-9
    sim = simulate(report, D, tol=tol)
    assert sim.demand_met
    assert sim.finish_time == pytest.approx(report.makespan, rel=1e-6)


def test_solve_many_jax_matches_per_instance():
    rng = np.random.default_rng(7)
    Ds = np.stack([doubly_substochastic(rng, 8) for _ in range(8)])
    batched = solve_many(Ds, 2, 0.02, solver="spectra_jax")
    assert len(batched) == 8
    for b, rep in enumerate(batched):
        single = solve(Problem(Ds[b], 2, 0.02), solver="spectra_jax")
        rel = abs(rep.makespan - single.makespan) / max(single.makespan, 1e-12)
        assert rel < 1e-4
        assert rep.extras["batched"] and rep.extras["batch_size"] == 8


def test_solve_many_numpy_loop_and_ragged_shapes():
    rng = np.random.default_rng(3)
    Ds = [doubly_substochastic(rng, n) for n in (6, 9, 6)]  # ragged is fine
    reports = solve_many(Ds, 2, 0.01, solver="spectra")
    singles = [solve(Problem(D, 2, 0.01), solver="spectra") for D in Ds]
    for rep, single in zip(reports, singles):
        assert rep.makespan == pytest.approx(single.makespan, rel=1e-12)


def test_solve_many_multiprocess_matches_serial():
    rng = np.random.default_rng(4)
    Ds = [doubly_substochastic(rng, 7) for _ in range(4)]
    serial = solve_many(Ds, 2, 0.01, solver="baseline_less")
    parallel = solve_many(Ds, 2, 0.01, solver="baseline_less", processes=2)
    assert [r.makespan for r in parallel] == pytest.approx(
        [r.makespan for r in serial]
    )


def test_declarative_pipeline_matches_registered_variant():
    rng = np.random.default_rng(5)
    D = doubly_substochastic(rng, 8)
    problem = Problem(D, 2, 0.01)
    via_registry = solve(problem, solver="spectra_eclipse")
    via_pipeline = Pipeline(decompose="eclipse")(problem)
    assert via_pipeline.makespan == pytest.approx(via_registry.makespan)
    # Wrap-around scheduling is a stage config, not a closure.
    wrapped = Pipeline(schedule="wrap", equalize="none")(problem)
    simulate(wrapped, D)


def test_register_solver_extension_and_duplicate_rejection():
    name = "_test_identity_solver"
    if name not in list_solvers():
        register_solver(name, Pipeline(equalize="none"))
    rng = np.random.default_rng(6)
    D = doubly_substochastic(rng, 6)
    rep = solve(Problem(D, 2, 0.01), solver=name)
    assert rep.solver == name
    with pytest.raises(ValueError):
        register_solver(name, Pipeline())
    with pytest.raises(KeyError):
        solve(Problem(D, 2, 0.01), solver="no_such_solver")


def test_options_control_validation_and_lb():
    rng = np.random.default_rng(8)
    D = doubly_substochastic(rng, 8)
    rep = solve(
        Problem(D, 2, 0.01),
        solver="spectra",
        options=SolveOptions(validate=False, compute_lb=False),
    )
    assert not rep.validated
    assert np.isnan(rep.lower_bound)


def test_optimality_gap_degenerate_zero_demand():
    from repro.core import spectra

    rep = solve(Problem(np.zeros((4, 4)), 2, 0.01), solver="spectra")
    assert rep.makespan == 0.0
    assert rep.optimality_gap == 1.0
    assert spectra(np.zeros((4, 4)), 2, 0.01).optimality_gap == 1.0


def test_solver_service_batches_by_shape():
    from repro.serve.engine import SolverService

    rng = np.random.default_rng(9)
    svc = SolverService(s=2, delta=0.01, solver="spectra")
    mats = {}
    for n in (6, 6, 8):
        D = doubly_substochastic(rng, n)
        mats[svc.submit(D)] = D
    assert len(svc) == 3
    reports = svc.flush()
    assert len(svc) == 0
    assert set(reports) == set(mats)
    for ticket, D in mats.items():
        assert reports[ticket].makespan == pytest.approx(
            solve(Problem(D, 2, 0.01), solver="spectra").makespan
        )


def test_solver_service_failed_flush_requeues_everything():
    from repro.serve.engine import SolverService

    rng = np.random.default_rng(10)
    svc = SolverService(s=2, delta=0.01, solver="spectra")
    good = svc.submit(doubly_substochastic(rng, 4))
    bad = svc.submit(np.full((6, 6), -1.0))  # negative demand → decompose raises
    with pytest.raises(Exception):
        svc.flush()
    # Nothing was delivered, so *both* tickets must survive for the next
    # flush — including ones whose shape-group had already solved.
    assert len(svc) == 2
    svc._queue = [(t, D) for t, D in svc._queue if t == good]
    reports = svc.flush()
    assert set(reports) == {good}


def test_problem_input_validation():
    with pytest.raises(ValueError):
        Problem(np.zeros((3, 4)), 2, 0.01)
    with pytest.raises(ValueError):
        Problem(np.zeros((3, 3)), 0, 0.01)
    with pytest.raises(ValueError):
        Problem(np.zeros((3, 3)), 2, -0.1)


# ----------------------------------------------- async dispatch / collect


def test_dispatch_many_returns_before_collect_and_matches_sync():
    """The dispatch/collect split: dispatch returns a PendingBatch without
    a device barrier; collect() is idempotent and yields exactly the
    synchronous solve_many_jax reports."""
    jax = pytest.importorskip("jax")
    del jax
    from repro.api.jax_backend import dispatch_many_jax, solve_many_jax

    rng = np.random.default_rng(3)
    Ds = np.stack([doubly_substochastic(rng, 8) for _ in range(4)])
    opts = SolveOptions(validate=True)
    pb = dispatch_many_jax(Ds, 2, 0.01, opts)
    assert len(pb) == 4
    assert isinstance(pb.ready, bool)  # non-blocking probe, any phase
    reports = pb.collect()
    assert pb.ready  # collected → concrete
    assert reports is pb.collect()  # idempotent, same object
    sync = solve_many_jax(Ds, 2, 0.01, opts)
    for a, b in zip(reports, sync):
        assert a.makespan == pytest.approx(b.makespan, rel=1e-6)
        assert a.extras["batched"] and a.extras["batch_size"] == 4
        assert a.validated


def test_solver_service_flush_midbatch_exception_requeues_all(monkeypatch):
    """A failure *inside* the batched solve (device error, OOM, a poisoned
    group) must leave every ticket queued — none delivered, none lost —
    and the very next flush must drain them all."""
    from repro.serve import engine as serve_engine
    from repro.serve.engine import SolverService

    rng = np.random.default_rng(12)
    svc = SolverService(s=2, delta=0.01, solver="spectra")
    tickets = [svc.submit(doubly_substochastic(rng, 6)) for _ in range(3)]

    calls = {"n": 0}

    def boom(*args, **kwargs):
        calls["n"] += 1
        raise RuntimeError("mid-batch device failure")

    monkeypatch.setattr(serve_engine, "solve_many", boom)
    with pytest.raises(RuntimeError, match="mid-batch"):
        svc.flush()
    assert calls["n"] == 1
    assert len(svc) == 3  # every ticket survived, in order
    assert [t for t, _ in svc._queue] == tickets

    monkeypatch.undo()
    reports = svc.flush()
    assert set(reports) == set(tickets)
    assert len(svc) == 0
    for rep in reports.values():
        assert np.isfinite(rep.makespan)
