"""Observability: tracer invariants, makespan attribution, artifacts.

Fast lane (the CI ``obs-smoke`` job runs exactly this file under
``-m "not slow"``):

  * the span tracer round-trips through Chrome trace-event JSON, keeps
    spans well-nested (child ⊆ parent interval), and — disabled — returns
    a shared no-op singleton without allocating;
  * the attribution identity ``transmission + δ paid + idle ≡ s·makespan``
    holds with residual ≈ 0 on every registered scenario (stateless host),
    on the fused device path, and on the credit-aware online pass;
  * ``repro.serve.metrics`` re-exports ``repro.obs.metrics`` unchanged,
    warning counters categorize ``SolveReport.extras["warnings"]``, and
    the benchmark artifact writer round-trips its envelope.
"""

import json
import tracemalloc

import numpy as np
import pytest

from repro.api import Problem, solve
from repro.obs import (
    Counters,
    MakespanAttribution,
    ServeMetrics,
    Tracer,
    attribute_scenario,
    get_tracer,
    timeline_table,
    warning_category,
    warning_counts,
)
from repro.obs.trace import _NULL_SPAN
from repro.scenarios import list_scenarios, run_scenario

TINY = dict(n=8, periods=3)


@pytest.fixture(autouse=True)
def _clean_global_tracer():
    """No test leaves the module-level tracer enabled or populated."""
    tracer = get_tracer()
    yield
    tracer.disable()
    tracer.reset()


def _solve(seed: int = 0, delta: float = 0.01):
    n = 8
    rng = np.random.default_rng(seed)
    D = np.zeros((n, n))
    for _ in range(4):
        D[np.arange(n), rng.permutation(n)] += rng.uniform(0.5, 2.0, size=n)
    return solve(Problem(D, s=4, delta=delta))


# --------------------------------------------------------------- tracer


class TestTracer:
    def test_disabled_returns_shared_null_span(self):
        t = Tracer()
        assert t.span("a") is _NULL_SPAN
        assert t.span("b", {"k": 1}) is _NULL_SPAN
        assert t.events == []

    def test_disabled_span_is_allocation_free(self):
        t = Tracer()
        t.span("warmup")  # materialize the method/local caches
        tracemalloc.start()
        try:
            snap0 = tracemalloc.take_snapshot()
            for _ in range(100):
                with t.span("hot"):
                    pass
            snap1 = tracemalloc.take_snapshot()
        finally:
            tracemalloc.stop()
        import repro.obs.trace as trace_mod

        flt = tracemalloc.Filter(True, trace_mod.__file__)
        stats = snap1.filter_traces([flt]).compare_to(
            snap0.filter_traces([flt]), "lineno"
        )
        grew = [s for s in stats if s.size_diff > 0]
        assert not grew, f"disabled spans allocated: {grew}"

    def test_nesting_and_parents(self):
        t = Tracer(enabled=True)
        with t.span("outer"):
            with t.span("mid"):
                with t.span("inner"):
                    pass
            with t.span("mid2"):
                pass
        spans = t.spans()
        by_name = {s.name: s for s in spans}
        assert set(by_name) == {"outer", "mid", "inner", "mid2"}
        assert by_name["outer"].parent is None
        assert t.events[by_name["mid"].parent] is by_name["outer"]
        assert t.events[by_name["inner"].parent] is by_name["mid"]
        assert t.events[by_name["mid2"].parent] is by_name["outer"]
        # Containment: every child's interval lies inside its parent's.
        for s in spans:
            if s.parent is not None:
                p = t.events[s.parent]
                assert p.start <= s.start and s.end <= p.end

    def test_exception_closes_children(self):
        t = Tracer(enabled=True)
        with pytest.raises(RuntimeError):
            with t.span("outer"):
                with t.span("inner"):
                    raise RuntimeError("boom")
        assert all(e.end is not None for e in t.events)
        assert t._stack() == []

    def test_chrome_round_trip(self, tmp_path):
        t = Tracer(enabled=True)
        with t.span("solve", {"n": 8}):
            t.instant("marker")
        t.counter("queue_depth", 3)
        path = t.save(tmp_path / "trace.json")
        doc = json.loads(path.read_text())
        events = doc["traceEvents"]
        assert {e["ph"] for e in events} == {"X", "i", "C"}
        x = next(e for e in events if e["ph"] == "X")
        assert x["name"] == "solve" and x["args"] == {"n": 8}
        assert x["ts"] >= 0 and x["dur"] >= 0
        c = next(e for e in events if e["ph"] == "C")
        assert c["args"]["value"] == 3.0

    def test_set_attaches_args_at_exit(self):
        t = Tracer(enabled=True)
        with t.span("stage", {"in": 1}) as sp:
            sp.set(out=2)
        assert t.spans()[0].args == {"in": 1, "out": 2}

    def test_reset_and_reenable(self):
        t = Tracer(enabled=True)
        with t.span("a"):
            pass
        t.reset()
        assert t.events == []
        t.disable()
        assert t.span("b") is _NULL_SPAN


class TestPipelineWiring:
    def test_traced_run_scenario_emits_stage_spans(self):
        tracer = get_tracer()
        tracer.enable()
        run_scenario("gpt", **TINY)
        names = {s.name for s in tracer.spans()}
        assert {"solve_many", "decompose", "schedule", "equalize",
                "matcher", "install", "period"} <= names
        # Stage spans nest under the solve_many loop; matcher under decompose.
        by_name = {}
        for s in tracer.spans():
            by_name.setdefault(s.name, []).append(s)
        for s in by_name["matcher"]:
            chain = set()
            p = s.parent
            while p is not None:
                chain.add(tracer.events[p].name)
                p = tracer.events[p].parent
            assert "decompose" in chain

    def test_traced_online_run_emits_online_spans(self):
        tracer = get_tracer()
        tracer.enable()
        run_scenario("gpt", online=True, **TINY)
        names = {s.name for s in tracer.spans()}
        assert "online.period" in names


# ---------------------------------------------------------- attribution


class TestAttribution:
    def test_identity_on_single_solve(self):
        rep = _solve()
        table = timeline_table(rep)
        a = table.attribution
        a.check(1e-9)
        assert a.s == 4
        assert a.makespan == pytest.approx(rep.makespan)
        assert np.isfinite(a.lower_bound)  # picked up from the SolveReport
        assert a.transmission_share + a.delta_share + a.idle_share == pytest.approx(1.0)
        # Exact LB-gap decomposition.
        assert (
            a.gap_from_transmission + a.gap_from_delta + a.gap_from_idle
            == pytest.approx(a.lb_gap, abs=1e-12)
        )

    def test_rows_cover_horizon_exactly(self):
        rep = _solve(seed=3, delta=0.05)
        table = timeline_table(rep)
        for row in table.rows:
            assert row.serve_time + row.reconf_time + row.idle_time == pytest.approx(
                table.horizon, abs=1e-12
            )
            assert 0.0 <= row.utilization <= 1.0 + 1e-12
            # Intervals tile [0, horizon) in order without gaps.
            t = 0.0
            for iv in row.intervals:
                assert iv.start == pytest.approx(t, abs=1e-9)
                t = iv.end
            assert t == pytest.approx(table.horizon, abs=1e-9)

    def test_horizon_extension_grows_idle_only(self):
        rep = _solve()
        base = timeline_table(rep)
        longer = timeline_table(rep, horizon=base.horizon * 1.5)
        assert longer.attribution.transmission == pytest.approx(
            base.attribution.transmission
        )
        assert longer.attribution.delta_paid == pytest.approx(
            base.attribution.delta_paid
        )
        assert longer.attribution.idle > base.attribution.idle
        longer.attribution.check(1e-9)
        with pytest.raises(ValueError, match="shorter than the timeline"):
            timeline_table(rep, horizon=base.horizon * 0.5)

    @pytest.mark.parametrize("name", list_scenarios())
    def test_identity_on_every_registered_scenario(self, name):
        rep = run_scenario(name, **TINY)
        att = attribute_scenario(rep)
        att.check()
        agg = att.summary()
        assert agg["periods"] == len(rep.reports)
        assert agg["max_identity_residual"] <= att.tol
        assert 0.0 - att.tol <= agg["util_min"]
        assert agg["transmission_share"] + agg["delta_share"] + agg[
            "idle_share"
        ] == pytest.approx(1.0)

    def test_identity_online_pass(self):
        rep = run_scenario("gpt", online=True, **TINY)
        att = attribute_scenario(rep)
        att.check()
        assert len(att.online_tables) == len(rep.online_periods)
        agg = att.summary()
        assert agg["online_reuse_count"] == sum(
            p.reuse_count for p in rep.online_periods
        )
        assert agg["online_delta_avoided"] == pytest.approx(
            sum(p.delta_avoided for p in rep.online_periods)
        )
        # Reused switches start serving δ-free at t=0.
        reused_rows = [
            row for table in att.online_tables for row in table.rows if row.reused
        ]
        assert reused_rows, "gpt TINY online pass reuses configurations"
        for row in reused_rows:
            first = row.intervals[0]
            assert first.kind == "serve" and first.start == 0.0

    def test_identity_device_pass(self):
        rep = run_scenario("gpt", solver="spectra_jax", **TINY)
        att = attribute_scenario(rep)
        att.check()
        assert att.tol == 1e-4  # float32 device tolerance auto-resolved

    def test_per_round_spread(self):
        rep = _solve()
        rounds = timeline_table(rep).per_round()
        assert rounds and all(r["spread"] >= 0 for r in rounds)
        assert sum(r["alpha_total"] for r in rounds) == pytest.approx(
            timeline_table(rep).attribution.transmission
        )

    def test_render_ascii_shape(self):
        rep = _solve()
        art = timeline_table(rep).render_ascii(width=40)
        lines = art.splitlines()
        assert len(lines) == 5  # 4 switch strips + the axis line
        assert all("|" in ln for ln in lines)

    def test_check_raises_on_cooked_books(self):
        a = MakespanAttribution(
            s=4, makespan=1.0, transmission=3.0, delta_paid=0.5, idle=0.2
        )
        with pytest.raises(AssertionError, match="identity violated"):
            a.check(1e-9)


# -------------------------------------------------------------- metrics


class TestMetricsUnification:
    def test_serve_reexports_obs_metrics(self):
        import repro.obs.metrics as obs_metrics
        import repro.serve.metrics as serve_metrics

        assert serve_metrics.ServeMetrics is obs_metrics.ServeMetrics
        assert serve_metrics.ServeMetrics is ServeMetrics
        assert serve_metrics.LatencyHistogram is obs_metrics.LatencyHistogram
        assert serve_metrics.STAGES is obs_metrics.STAGES

    def test_warning_category(self):
        assert warning_category("matcher budget exhausted at round 3") == (
            "matcher_budget_exhausted"
        )
        assert warning_category("equalize: headroom exhausted") == (
            "equalize_headroom_exhausted"
        )
        assert warning_category("something else") == "other"

    def test_warning_counts_and_counters(self):
        rep = _solve()
        rep.extras["warnings"] = [
            "matcher budget exhausted",
            "equalize headroom exhausted",
            "equalize headroom exhausted",
        ]
        counters = warning_counts([rep])
        assert counters.get("matcher_budget_exhausted") == 1
        assert counters.get("equalize_headroom_exhausted") == 2
        assert counters.total == 3
        assert counters.export() == {
            "matcher_budget_exhausted": 1,
            "equalize_headroom_exhausted": 2,
        }

    def test_counters_basics(self):
        c = Counters()
        assert not c
        c.inc("a")
        c.inc("a", 2)
        assert c and c.get("a") == 3 and c.get("missing") == 0

    def test_scenario_summary_surfaces_warnings(self):
        rep = run_scenario("gpt", **TINY)
        rep.reports[0].extras.setdefault("warnings", []).append(
            "matcher budget exhausted"
        )
        row = rep.summary()
        assert row["warnings"] >= 1
        assert row["warning_counts"]["matcher_budget_exhausted"] >= 1


# ------------------------------------------------------------ artifacts


class TestBenchArtifacts:
    def test_round_trip(self, tmp_path):
        from benchmarks.artifact import SCHEMA, read_artifact, write_artifact

        path = write_artifact(
            "demo",
            {"rows": [{"n": 8, "us": 1.5}]},
            git_sha="deadbeef",
            timestamp="2026-01-01T00:00:00+00:00",
            workload="unit",
            out_dir=tmp_path,
        )
        assert path.name == "BENCH_demo.json"
        doc = read_artifact(path)
        assert doc["schema"] == SCHEMA
        assert doc["git_sha"] == "deadbeef"
        assert doc["workload"] == "unit"
        assert doc["metrics"]["rows"][0]["n"] == 8

    def test_read_rejects_unknown_schema(self, tmp_path):
        from benchmarks.artifact import read_artifact

        bad = tmp_path / "BENCH_bad.json"
        bad.write_text(json.dumps({"schema": "nope/v0"}))
        with pytest.raises(ValueError, match="unknown benchmark artifact schema"):
            read_artifact(bad)

    def test_git_sha_resolves_here(self):
        from benchmarks.artifact import git_sha

        sha = git_sha()
        assert sha is None or (len(sha) == 40 and set(sha) <= set("0123456789abcdef"))


# ------------------------------------------------------------ dashboard


class TestDashboard:
    def test_cli_smoke_writes_reports(self, tmp_path, capsys):
        from repro.obs.dashboard import main

        trace = tmp_path / "trace.json"
        html = tmp_path / "report.html"
        rc = main([
            "gpt", "--n", "8", "--periods", "2",
            "--trace", str(trace), "--html", str(html), "--width", "40",
        ])
        assert rc == 0
        out = capsys.readouterr().out
        assert "ocs0" in out and "horizon=" in out
        doc = json.loads(trace.read_text())
        assert doc["traceEvents"]
        text = html.read_text()
        assert "<html" in text and "obs-root" in text

    def test_flowsim_summary_attribution_keys(self):
        rep = run_scenario("gpt", flowsim=True, **TINY)
        fs = rep.flowsim_summary()
        assert 0.0 <= fs["delta_share"] <= 1.0
        assert 0.0 <= fs["idle_share"] <= 1.0
