"""Online cross-period scheduling: state carry, reuse credit, rolling solve.

Fast lane (CI ``online-scheduling`` job):

  * carry-over correctness — every online period's schedule still fully
    serves its demand matrix (validator parity with stateless), and the
    online effective makespan is ≤ the stateless makespan per period on
    ALL nine built-in scenarios;
  * the device ``lax.scan`` rolling solve matches the Python-loop online
    path within 1e-4 and the host controller on tiny traces;
  * the event simulator replays carried configurations (δ-free first
    config) and confirms demand service;
  * trace-aware δ schedules thread through ``solve_many``/``run_scenario``
    and are rejected with clear errors where they would be silently
    dropped;
  * matcher autotuning picks the device matcher per shape bucket.

The ``slow`` tests run the paper-scale gpt/moe acceptance (T=8, seed 0):
the online controller must reduce total trace makespan vs the stateless
per-period solve with measurable reuse credit, and the single-dispatch scan
must be at least as fast per period as the fused per-period dispatch.
"""

import time

import numpy as np
import pytest

from repro.api import Problem, SolveOptions, solve, solve_many
from repro.online import (
    OnlineController,
    SwitchState,
    apply_reuse_order,
    effective_makespan,
)
from repro.scenarios import (
    OnlineReport,
    TrafficSpec,
    list_scenarios,
    make_trace,
    run_scenario,
)
from repro.serve.engine import SolverService

TINY = dict(n=8, periods=3)
_NO_VALIDATE = SolveOptions(validate=False, compute_lb=False)


# ------------------------------------------------------------ state model

def test_switch_state_and_credit_accounting():
    from repro.core.schedule import ParallelSchedule, SwitchSchedule

    p0 = np.array([1, 0, 2])
    p1 = np.array([2, 1, 0])
    sched = ParallelSchedule(
        switches=[
            SwitchSchedule(perms=[p1, p0], alphas=[1.0, 2.0]),
            SwitchSchedule(perms=[p1], alphas=[3.0]),
        ],
        delta=0.5,
    )
    state = SwitchState(installed=[p0, None])
    ordered, marks = apply_reuse_order(sched, state)
    # switch 0's p0 config moved first and is credited; switch 1 has no
    # installed configuration yet.
    assert marks.tolist() == [True, False]
    assert np.array_equal(ordered.switches[0].perms[0], p0)
    # nominal loads: sw0 = 1+2+2δ = 4, sw1 = 3+δ = 3.5; credit removes one
    # δ from sw0 only.
    assert effective_makespan(sched, state) == pytest.approx(3.5)
    assert effective_makespan(sched, SwitchState.initial(2)) == pytest.approx(4.0)


def test_initial_state_and_validation():
    st = SwitchState.initial(3)
    assert st.s == 3 and all(p is None for p in st.installed)
    with pytest.raises(ValueError, match="at least one switch"):
        SwitchState.initial(0)
    with pytest.raises(ValueError, match="at least one switch"):
        OnlineController(s=0, delta=0.1)
    with pytest.raises(ValueError, match="nonnegative"):
        OnlineController(s=2, delta=-1.0)


# ---------------------------------------------- carry-over correctness

def test_online_serves_demand_and_never_worse_all_scenarios():
    """The headline invariant on ALL nine built-ins (tiny variants): every
    online period still fully covers its demand matrix, and the effective
    makespan never exceeds the stateless baseline (the stateless schedule
    with the credit applied is always a candidate)."""
    for name in list_scenarios():
        rep = run_scenario(name, solver="spectra", online=True, **TINY)
        assert isinstance(rep, OnlineReport)
        units, _, _ = rep.trace.normalized()
        for t, p in enumerate(rep.online_periods):
            p.schedule.validate(units[t], tol=1e-9)  # validator parity
            assert p.makespan <= p.stateless_makespan + 1e-12, (name, t)
            assert p.delta_avoided >= 0 and p.delta_paid >= 0
        assert rep.total_improvement >= -1e-12, name


def test_online_state_advances_to_last_served():
    tr = make_trace("gpt", **TINY)
    ctl = OnlineController(s=tr.spec.s, delta=tr.spec.delta)
    prev_installed = list(ctl.state.installed)
    assert all(p is None for p in prev_installed)
    out = ctl.step(tr.demands[0])
    # After one period every switch that served anything has its last
    # config installed.
    for h, sw in enumerate(out.schedule.switches):
        if sw.perms:
            assert np.array_equal(ctl.state.installed[h], sw.perms[-1])
        else:
            assert ctl.state.installed[h] is None
    # Period 1 now earns credit on this workload.
    out1 = ctl.step(tr.demands[1])
    assert out1.reuse_count > 0
    assert out1.makespan < out1.stateless_makespan


def test_online_simulator_replays_carried_configs():
    rep = run_scenario("gpt", solver="spectra", online=True, simulate=True,
                       n=8, periods=4)
    assert all(p.demand_met for p in rep.online_periods)
    assert rep.reuse_counts[1:].sum() > 0  # credit actually exercised


def test_simulator_installed_replay_direct():
    from repro.fabric.simulator import simulate

    tr = make_trace("gpt", **TINY)
    ctl = OnlineController(s=tr.spec.s, delta=tr.spec.delta)
    out0 = ctl.step(tr.demands[0])
    installed_after_0 = list(ctl.state.installed)
    out1 = ctl.step(tr.demands[1])
    sim = simulate(out1.schedule, tr.demands[1], tol=1e-9,
                   installed=installed_after_0,
                   expected_makespan=out1.makespan)
    assert sim.demand_met
    assert int(sim.reused_switches.sum()) == out1.reuse_count
    # replay without state pays full δ everywhere → strictly later finish
    # whenever credit was earned
    sim_cold = simulate(out1.schedule, tr.demands[1], tol=1e-9)
    if out1.reuse_count:
        assert sim_cold.finish_time > sim.finish_time
    with pytest.raises(ValueError, match="per switch"):
        simulate(out1.schedule, tr.demands[1], installed=[None])


def test_warm_start_decomposition_reuses_previous_set():
    # moe's support is stable period-to-period: the warm path must kick in
    # (no fresh MWM solves) and still cover the demand exactly.
    tr = make_trace("moe", n=16, periods=3, tokens_per_gpu=512)
    ctl = OnlineController(s=tr.spec.s, delta=tr.spec.delta)
    outs = ctl.solve_trace(tr.demands)
    assert not outs[0].warm and outs[1].warm and outs[2].warm
    for t, o in enumerate(outs):
        o.schedule.validate(tr.demands[t], tol=1e-9)
    # warm start also means full per-switch reuse on this workload
    assert outs[1].reuse_count == tr.spec.s
    # disabling warm start must still be correct (credit may drop)
    ctl2 = OnlineController(s=tr.spec.s, delta=tr.spec.delta, warm_start=False)
    outs2 = ctl2.solve_trace(tr.demands)
    assert not any(o.warm for o in outs2)
    for t, o in enumerate(outs2):
        o.schedule.validate(tr.demands[t], tol=1e-9)
        assert o.makespan <= o.stateless_makespan + 1e-12


def _drifting_trace(seed: int, T: int = 5, n: int = 8):
    """Stable support, wildly drifting weights: the adversarial shape for
    warm-start (a stale permutation set still covers, but re-REFINE badly
    over-provisions)."""
    rng = np.random.default_rng(seed)
    S = rng.random((n, n)) < 0.5
    np.fill_diagonal(S, True)
    return np.stack([np.where(S, rng.random((n, n)) * 10, 0.0)
                     for _ in range(T)])


def test_warm_quality_gate_bounds_drifting_weight_regression():
    """Review regression: warm-start must not silently degrade quality on
    weight-drifting traces. The session path (no donated baseline) is
    gated by the running-min weight/gap references; the measured unguarded
    regression was 1.74x — the gate keeps it within warm_slack of the
    fresh solve whenever the period is no easier than the easiest seen,
    and well under the unguarded blowup always."""
    for seed in range(4):
        demands = _drifting_trace(seed)
        ctl = OnlineController(s=2, delta=0.2)
        for t, D in enumerate(demands):
            out = ctl.step(D)
            fresh = solve(Problem(D, 2, 0.2), solver="spectra",
                          options=_NO_VALIDATE)
            assert out.makespan <= fresh.makespan * 1.15, (seed, t)
    # disabling warm start is always strict vs fresh
    demands = _drifting_trace(2)
    ctl = OnlineController(s=2, delta=0.2, warm_start=False)
    for t, D in enumerate(demands):
        out = ctl.step(D)
        fresh = solve(Problem(D, 2, 0.2), solver="spectra",
                      options=_NO_VALIDATE)
        assert out.makespan <= fresh.makespan + 1e-9, (2, t)


def test_run_scenario_online_reports_true_stateless_baseline():
    """Review regression: OnlinePeriod.stateless_makespan must be the
    independently solved baseline from the SAME report (not the warm
    decomposition's internal reference), and online ≤ that baseline, on
    both backends — even on adversarial drifting traces."""
    from repro.scenarios import DemandTrace

    demands = _drifting_trace(2, T=4)
    spec = TrafficSpec(family="benchmark", n=8, s=2, delta=0.2, periods=4)
    tr = DemandTrace(spec=spec, demands=demands,
                     period_meta=[{"period": t} for t in range(4)])
    solvers = ["spectra"]
    try:
        import jax  # noqa: F401
        solvers.append("spectra_jax")
    except Exception:
        pass
    for solver in solvers:
        rep = run_scenario(tr, solver=solver, online=True,
                           options=_NO_VALIDATE)
        for t, p in enumerate(rep.online_periods):
            assert p.stateless_makespan == pytest.approx(
                rep.periods[t].makespan, rel=1e-9
            ), (solver, t)
            assert p.makespan <= p.stateless_makespan * (1 + 1e-6), (solver, t)


def test_online_session_rejects_bytes_and_delta_schedules():
    """Review regression: the stateful session path must reject exactly
    what submit_trace rejects (byte traces, per-period δ) instead of
    silently mis-pricing them."""
    ses = SolverService(s=2, delta=0.01, solver="spectra").open_session()
    with pytest.raises(ValueError, match="bytes"):
        ses.run(make_trace("collective_ring", n=8, periods=2))
    with pytest.raises(ValueError, match="delta_schedule"):
        ses.run(make_trace("gpt", n=8, periods=2,
                           delta_schedule=(0.01, 0.02)))
    assert len(ses) == 0  # nothing was scheduled


def test_support_pattern_matching_cache():
    # A workload alternating between two support patterns: after one full
    # cycle the cache supplies the warm set even though the *previous*
    # period's support differs.
    rng = np.random.default_rng(0)
    n = 8
    base_a = np.zeros((n, n))
    base_a[np.arange(n), np.roll(np.arange(n), 1)] = 1.0
    base_a[np.arange(n), np.roll(np.arange(n), 2)] = 0.5
    base_b = np.zeros((n, n))
    base_b[np.arange(n), np.roll(np.arange(n), 3)] = 2.0
    base_b[np.arange(n), np.roll(np.arange(n), 4)] = 0.25
    trace = []
    for t in range(6):
        base = base_a if t % 2 == 0 else base_b
        trace.append(base * (1.0 + 0.01 * rng.random((n, n))))
    ctl = OnlineController(s=2, delta=0.05)
    outs = ctl.solve_trace(np.stack(trace))
    # periods 0 and 1 are cold (new patterns); 2+ hit the cache
    assert [o.warm for o in outs] == [False, False, True, True, True, True]
    for t, o in enumerate(outs):
        o.schedule.validate(trace[t], tol=1e-9)
    # the cache travels on SwitchState, so per-call controllers (registry
    # solver / sessions) keep it too
    ses = SolverService(s=2, delta=0.05, solver="spectra").open_session()
    warms = [r.extras["warm"] for r in ses.run(np.stack(trace))]
    assert warms == [False, False, True, True, True, True]


# --------------------------------------------------- registry solvers

def test_registry_online_solver_threads_state():
    tr = make_trace("gpt", **TINY)
    state = None
    mks = []
    for D in tr.demands:
        rep = solve(
            Problem(D, tr.spec.s, tr.spec.delta),
            solver="spectra_online",
            options=SolveOptions(extra={"online": state}),
        )
        assert rep.validated and rep.extras["online"]
        state = rep.extras["online_state"]
        mks.append(rep.makespan)
        assert rep.makespan <= rep.extras["stateless_makespan"] + 1e-12
    assert isinstance(state, SwitchState)
    # matches the controller run bit-for-bit
    ctl = OnlineController(s=tr.spec.s, delta=tr.spec.delta)
    outs = ctl.solve_trace(tr.demands)
    assert mks == [o.makespan for o in outs]
    with pytest.raises(TypeError, match="SwitchState"):
        solve(Problem(tr.demands[0], 2, 0.01), solver="spectra_online",
              options=SolveOptions(extra={"online": object()}))
    # carried state pins the fabric size — mismatches fail loudly
    ctl2 = OnlineController(s=2, delta=0.01)
    ctl2.step(tr.demands[0])
    with pytest.raises(ValueError, match="carried"):
        ctl2.step(np.ones((tr.n + 4, tr.n + 4)))


def test_solver_service_open_session():
    svc = SolverService(s=4, delta=0.01, solver="spectra",
                        options=_NO_VALIDATE)
    ses = svc.open_session()
    assert ses.solver == "spectra_online"
    reports = ses.run(make_trace("gpt", **TINY))
    assert len(ses) == 3 and ses.state is not None
    assert ses.total_delta_avoided > 0
    assert all(r.extras["online"] for r in reports)
    with pytest.raises(ValueError, match="demand stack"):
        ses.run(np.zeros((3, 4)))


# -------------------------------------------------------- device path

def test_online_scan_matches_python_loop():
    """The lax.scan rolling solve is the SAME computation as the stepwise
    jitted loop — makespans agree ≤ 1e-4 (in practice bit-identical)."""
    jax = pytest.importorskip("jax")
    from repro.core.jaxopt.online_jax import (
        online_initial_state,
        online_step_jax,
        spectra_online_scan,
    )

    tr = make_trace("gpt", n=8, periods=4)
    s, delta = tr.spec.s, tr.spec.delta
    res, fin = spectra_online_scan(tr.demands, s, delta)
    state = online_initial_state(tr.n, s)
    for t in range(tr.T):
        step, state = online_step_jax(state, tr.demands[t], s, delta)
        scan_mk = float(np.asarray(res.makespan)[t])
        assert abs(float(step.makespan) - scan_mk) <= 1e-4 * max(scan_mk, 1.0)
        assert int(step.reuse_count) == int(np.asarray(res.reuse_count)[t])
    # final carry matches too
    assert np.array_equal(np.asarray(fin.installed), np.asarray(state.installed))


def test_online_scan_never_worse_and_covers():
    jax = pytest.importorskip("jax")
    del jax
    from repro.core.jaxopt.online_jax import spectra_online_scan
    from repro.core.schedule_ir import ir_coverage
    import jax as _jax

    tr = make_trace("moe", n=16, periods=4, tokens_per_gpu=512)
    res, _ = spectra_online_scan(tr.demands, tr.spec.s, tr.spec.delta)
    mks = np.asarray(res.makespan)
    stateless = np.asarray(res.stateless_makespan)
    assert (mks <= stateless + 1e-6).all()
    assert np.asarray(res.warm)[1:].all()  # stable support → warm periods
    assert (np.asarray(res.reuse_count)[1:] > 0).all()
    for t in range(tr.T):
        ds = _jax.tree_util.tree_map(
            lambda x: np.asarray(x)[t], res.schedule
        )
        gap = float((tr.demands[t] - ir_coverage(ds)).max())
        assert gap <= 1e-4 * tr.demands[t].max(), t


def test_online_scan_vs_host_controller_tiny():
    pytest.importorskip("jax")
    rep_h = run_scenario("gpt", solver="spectra", online=True, n=8, periods=4)
    rep_d = run_scenario("gpt", solver="spectra_jax", online=True,
                         n=8, periods=4, options=_NO_VALIDATE)
    assert rep_d.online_solver == "scan" and rep_h.online_solver == "host"
    rel = np.abs(rep_d.online_makespans - rep_h.online_makespans)
    rel /= np.maximum(rep_h.online_makespans, 1e-12)
    assert (rel < 1e-4).all()
    assert rep_d.reuse_counts.tolist() == rep_h.reuse_counts.tolist()


def test_registry_online_jax_solver_threads_state():
    pytest.importorskip("jax")
    from repro.core.jaxopt.online_jax import OnlineDeviceState

    tr = make_trace("gpt", **TINY)
    state = None
    for t, D in enumerate(tr.demands):
        rep = solve(
            Problem(D, tr.spec.s, tr.spec.delta),
            solver="spectra_online_jax",
            options=SolveOptions(extra={"online": state}),
        )
        rep.schedule.validate(D, tol=1e-4)
        state = rep.extras["online_state"]
        assert rep.makespan <= rep.extras["stateless_makespan"] + 1e-6
        if t:
            assert rep.extras["reuse_count"] > 0
    assert isinstance(state, OnlineDeviceState)
    with pytest.raises(TypeError, match="OnlineDeviceState"):
        solve(Problem(tr.demands[0], 2, 0.01), solver="spectra_online_jax",
              options=SolveOptions(extra={"online": object()}))
    with pytest.raises(ValueError, match="fresh session"):
        solve(Problem(np.ones((tr.n + 4, tr.n + 4)), tr.spec.s, 0.01),
              solver="spectra_online_jax",
              options=SolveOptions(extra={"online": state}))


def test_warm_prices_carry_still_optimal():
    """The auction's cross-period dual-price warm start must not change
    what the matcher returns on an exact-arithmetic instance."""
    pytest.importorskip("jax")
    from scipy.optimize import linear_sum_assignment

    from repro.core.jaxopt.matching import match_auction, match_auction_fr

    rng = np.random.default_rng(0)
    W = rng.integers(0, 50, size=(12, 12)).astype(np.float32)
    for matcher in (match_auction, match_auction_fr):
        perm, ok, prices = matcher(W, with_prices=True)
        assert bool(ok)
        rows, cols = linear_sum_assignment(W, maximize=True)
        opt = W[rows, cols].sum()
        assert W[np.arange(12), np.asarray(perm)].sum() == pytest.approx(opt)
        # warm restart on a perturbed instance: still optimal for ITS weights
        W2 = W + rng.integers(0, 3, size=W.shape).astype(np.float32)
        perm2, ok2 = matcher(W2, prices0=prices)
        assert bool(ok2)
        rows2, cols2 = linear_sum_assignment(W2, maximize=True)
        assert W2[np.arange(12), np.asarray(perm2)].sum() == pytest.approx(
            W2[rows2, cols2].sum()
        )


# ------------------------------------------------ trace-aware δ sweeps

def test_delta_schedule_threads_through_trace_and_reports():
    tr = make_trace("gpt", n=8, periods=4, delta_schedule=(0.01, 0.03))
    assert tr.varying_delta
    assert tr.deltas.tolist() == [0.01, 0.03, 0.01, 0.03]
    assert [m["delta"] for m in tr.period_meta] == [0.01, 0.03, 0.01, 0.03]
    rep = run_scenario(tr, solver="spectra")
    assert rep.deltas_units.tolist() == [0.01, 0.03, 0.01, 0.03]
    # per-period makespans actually reflect per-period δ: solving each
    # period alone at its own δ gives the same result
    for t, D in enumerate(tr.demands):
        single = solve(Problem(D, tr.spec.s, float(tr.deltas[t])),
                       solver="spectra")
        assert rep.periods[t].makespan == pytest.approx(single.makespan)
    # pinning: delta_schedule=None restores the constant spec δ
    pinned = make_trace("gpt", n=8, periods=2, delta_schedule=None)
    assert not pinned.varying_delta


def test_delta_schedule_device_parity_and_online():
    pytest.importorskip("jax")
    tr = make_trace("gpt", n=8, periods=4, delta_schedule=(0.01, 0.03))
    host = run_scenario(tr, solver="spectra", options=_NO_VALIDATE)
    dev = run_scenario(tr, solver="spectra_jax", options=_NO_VALIDATE)
    rel = np.abs(dev.makespans - host.makespans) / host.makespans
    assert (rel < 1e-4).all()
    # online honors the per-period δ in its credit accounting
    rep = run_scenario(tr, solver="spectra", online=True)
    for t, p in enumerate(rep.online_periods):
        d = float(tr.deltas[t])
        assert p.delta_avoided == pytest.approx(d * p.reuse_count)
        assert p.delta_paid == pytest.approx(
            d * (p.num_configs - p.reuse_count)
        )


def test_delta_schedule_rejected_where_it_would_be_dropped():
    # byte traces: δ is the fabric's physical constant
    tr = make_trace("collective_ring", n=8, periods=2,
                    delta_schedule=(1e-5, 2e-5))
    with pytest.raises(ValueError, match="delta_schedule"):
        tr.normalized()
    with pytest.raises(ValueError, match="delta_schedule"):
        run_scenario(tr, solver="spectra")
    # the queue-and-drain service solves at ONE δ
    svc = SolverService(s=2, delta=0.01, solver="spectra")
    unit_tr = make_trace("gpt", n=8, periods=2, delta_schedule=(0.01, 0.02))
    with pytest.raises(ValueError, match="delta_schedule"):
        svc.submit_trace(unit_tr)
    # malformed schedules fail fast at trace build
    with pytest.raises(ValueError, match="nonnegative"):
        make_trace("gpt", n=8, periods=2, delta_schedule=(0.01, -0.5))
    with pytest.raises(ValueError, match="not be empty"):
        make_trace("gpt", n=8, periods=2, delta_schedule=())


def test_solve_many_per_instance_delta_vector():
    tr = make_trace("gpt", n=8, periods=3)
    deltas = np.array([0.01, 0.05, 0.1])
    reports = solve_many(tr.demands, 2, deltas, solver="spectra")
    for t, rep in enumerate(reports):
        single = solve(Problem(tr.demands[t], 2, float(deltas[t])),
                       solver="spectra")
        assert rep.makespan == pytest.approx(single.makespan)
    with pytest.raises(ValueError, match="length 3"):
        solve_many(tr.demands, 2, np.array([0.01, 0.02]), solver="spectra")


# ------------------------------------------------- matcher autotuning

def test_default_matcher_policy_by_shape():
    from repro.core.jaxopt.matching import (
        default_matcher,
        set_default_matcher_policy,
    )

    assert default_matcher(8) == "auction"
    assert default_matcher(32) == "auction"
    assert default_matcher(33) == "auction_fr"
    assert default_matcher(100) == "auction_fr"
    try:
        set_default_matcher_policy(lambda n: "auction")
        assert default_matcher(100) == "auction"
        with pytest.raises(KeyError, match="unknown matcher"):
            set_default_matcher_policy(lambda n: "nope")
    finally:
        set_default_matcher_policy(None)
    assert default_matcher(100) == "auction_fr"


def test_autotune_picks_matcher_per_bucket():
    pytest.importorskip("jax")
    from repro.traffic.workloads import benchmark_workload

    rng = np.random.default_rng(0)
    Ds = [
        benchmark_workload(n=8, m=4, num_big=1, rng=rng),
        benchmark_workload(n=40, m=4, num_big=1, rng=rng),
    ]
    reports = solve_many(Ds, 2, 0.02, solver="spectra_jax",
                         options=_NO_VALIDATE)
    assert reports[0].extras["matcher"] == "auction"      # n=8 bucket
    assert reports[1].extras["matcher"] == "auction_fr"   # n=40 bucket
    # explicit override pins the matcher for every bucket
    pinned = solve_many(Ds, 2, 0.02, solver="spectra_jax",
                        options=SolveOptions(validate=False, compute_lb=False,
                                             extra={"matcher": "auction"}))
    assert all(r.extras["matcher"] == "auction" for r in pinned)
    # quality parity against the host solver either way
    for D, rep in zip(Ds, reports):
        host = solve(Problem(D, 2, 0.02), solver="spectra",
                     options=_NO_VALIDATE)
        assert rep.makespan <= host.makespan * 1.10


# ---------------------------------------------------- acceptance (slow)

@pytest.mark.slow
def test_acceptance_gpt_moe_online_reduces_trace_makespan():
    """ISSUE acceptance: on gpt and moe (T=8, seed 0) the online controller
    reduces TOTAL trace makespan vs the stateless per-period solve, with
    measurable reuse credit, on both the host controller and the device
    scan."""
    for name in ("gpt", "moe"):
        rep = run_scenario(name, solver="spectra", online=True)
        assert rep.trace.T == 8 and rep.spec.seed == 0
        s = rep.online_summary()
        assert s["online_total_makespan"] < s["stateless_total_makespan"], name
        assert s["total_delta_avoided"] > 0, name
        assert rep.total_reuse > 0, name

    pytest.importorskip("jax")
    for name in ("gpt", "moe"):
        rep = run_scenario(name, solver="spectra_jax", online=True,
                           options=_NO_VALIDATE)
        s = rep.online_summary()
        assert s["online_total_makespan"] < s["stateless_total_makespan"], name
        assert s["total_delta_avoided"] > 0, name


@pytest.mark.slow
def test_acceptance_scan_parity_and_speed_vs_per_period_dispatch():
    """The single-dispatch rolling solve agrees with the stepwise online
    loop ≤ 1e-4 at paper scale and is at least as fast per period as the
    fused per-period dispatch (PR 4's hot path), both measured warm."""
    jax = pytest.importorskip("jax")
    from repro.core.jaxopt.e2e import spectra_jax_e2e
    from repro.core.jaxopt.online_jax import (
        online_initial_state,
        online_step_jax,
        spectra_online_scan,
    )

    tr = make_trace("gpt")  # n=32, T=8, seed 0
    s, delta = tr.spec.s, tr.spec.delta

    # warm both paths (compile outside the timed region)
    res, _ = spectra_online_scan(tr.demands, s, delta)
    jax.block_until_ready(res.makespan)
    e2e = spectra_jax_e2e(tr.demands[0], s, np.float32(delta))
    jax.block_until_ready(e2e.makespan)

    # parity: scan vs stepwise jitted loop
    state = online_initial_state(tr.n, s)
    for t in range(tr.T):
        step, state = online_step_jax(state, tr.demands[t], s, delta)
        scan_mk = float(np.asarray(res.makespan)[t])
        assert abs(float(step.makespan) - scan_mk) <= 1e-4 * max(scan_mk, 1.0)

    # speed: one scan dispatch over T periods vs T per-period dispatches
    t0 = time.perf_counter()
    res2, _ = spectra_online_scan(tr.demands, s, delta)
    jax.block_until_ready(res2.makespan)
    scan_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    for t in range(tr.T):
        out = spectra_jax_e2e(tr.demands[t], s, np.float32(delta))
    jax.block_until_ready(out.makespan)
    loop_s = time.perf_counter() - t0

    # "at least as fast per period", with CI-noise headroom
    assert scan_s / tr.T <= (loop_s / tr.T) * 1.25, (scan_s, loop_s)


# ------------------------------------------- device support-pattern cache


def test_online_scan_device_cache_matches_host_semantics():
    """Phase-cycling traffic: adjacent periods never share a support (so
    adjacency warm-start can't fire), but period t-2 does — the device
    support-pattern cache carried in the scan state must serve exactly
    the periods the host controller's cache serves, and disabling it
    (cache_size=0) must kill all warm periods."""
    jax = pytest.importorskip("jax")
    del jax
    from repro.core.jaxopt.online_jax import spectra_online_scan

    tr = make_trace("moe_phases", n=16, periods=6, phases=2)
    s, delta = tr.spec.s, tr.spec.delta

    res, _ = spectra_online_scan(tr.demands, s, delta, cache_size=8)
    dev_warm = np.asarray(res.warm).astype(bool)
    dev_hit = np.asarray(res.cache_hit).astype(bool)

    # Host controller, same cache capacity, same trace.
    opts = SolveOptions(validate=False, compute_lb=False,
                        extra={"cache_size": 8})
    state = None
    host_warm = []
    for t in range(tr.T):
        o = SolveOptions(validate=False, compute_lb=False,
                         extra={"cache_size": 8, "online": state})
        rep = solve(Problem(tr.demands[t], s, delta),
                    solver="spectra_online", options=o)
        state = rep.extras["online_state"]
        host_warm.append(bool(rep.extras["warm"]))

    # Phases alternate → the first occurrence of each phase is cold, every
    # revisit is cache-warm. Device and host must agree period-by-period.
    assert host_warm == [False, False, True, True, True, True]
    assert dev_warm.tolist() == host_warm
    # On this trace every device warm period IS a cache hit (adjacency
    # never matches across alternating phases).
    assert dev_hit.tolist() == dev_warm.tolist()

    # Cache disabled: no tier left to warm from.
    res0, _ = spectra_online_scan(tr.demands, s, delta, cache_size=0)
    assert not np.asarray(res0.warm).any()
    assert not np.asarray(res0.cache_hit).any()
    # Quality: cached-decomposition periods stay within the online bound.
    mks = np.asarray(res.makespan)
    stateless = np.asarray(res.stateless_makespan)
    assert (mks <= stateless + 1e-6).all()
