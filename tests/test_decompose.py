"""DECOMPOSE: exactly-k permutations, coverage, REFINE variants."""

import numpy as np
import pytest

from repro.core import decompose, degree, refine_greedy, refine_lp, refine_signed

FIG2 = np.array([
    [0.6, 0.3, 0, 0.1],
    [0, 0.61, 0.39, 0],
    [0, 0.09, 0.61, 0.3],
    [0.4, 0, 0, 0.6],
])


def random_demand(rng, n, density=0.3, doubly_stochastic=False):
    D = rng.random((n, n)) * (rng.random((n, n)) < density)
    if not (D > 0).any():
        D[rng.integers(n), rng.integers(n)] = 1.0
    if doubly_stochastic:
        for _ in range(50):  # Sinkhorn on the support
            D = D / np.maximum(D.sum(1, keepdims=True), 1e-12)
            D = D / np.maximum(D.sum(0, keepdims=True), 1e-12)
    return D


def sum_of_permutations(rng, n, k):
    D = np.zeros((n, n))
    for _ in range(k):
        D[np.arange(n), rng.permutation(n)] += rng.random() + 0.05
    return D


def test_fig2_example():
    dec = decompose(FIG2)
    assert dec.k == 3 == degree(FIG2)
    assert dec.covers(FIG2)
    # Total weight should be near-minimal (paper example: 1.01).
    assert dec.total_weight() <= 1.10


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("n", [5, 12, 24])
def test_exactly_degree_permutations(seed, n):
    rng = np.random.default_rng(seed)
    D = random_demand(rng, n)
    dec = decompose(D)
    assert dec.k == degree(D)
    assert dec.covers(D)


@pytest.mark.parametrize("k", [1, 3, 7])
def test_sum_of_k_perms_decomposes_into_k(k):
    rng = np.random.default_rng(k)
    D = sum_of_permutations(rng, 20, k)
    dec = decompose(D)
    assert dec.k == degree(D) <= k
    assert dec.covers(D)


def test_alpha_modes_both_cover():
    rng = np.random.default_rng(0)
    D = random_demand(rng, 16, density=0.2)
    for mode in ("covered_support", "all_matched"):
        dec = decompose(D, alpha_mode=mode)
        assert dec.covers(D)
        assert dec.k == degree(D)


def test_refine_lp_not_worse_than_greedy():
    rng = np.random.default_rng(3)
    D = random_demand(rng, 10, density=0.4)
    dec = decompose(D)  # greedy-refined
    lp = refine_lp(D, dec.alphas, dec.perms)
    greedy_total = dec.total_weight()
    assert sum(lp) <= greedy_total + 1e-9
    # LP result still covers.
    from repro.core import Decomposition
    assert Decomposition(dec.perms, list(lp)).covers(D)


def test_refine_signed_covers_and_not_worse():
    rng = np.random.default_rng(4)
    D = random_demand(rng, 10, density=0.5)
    dec = decompose(D, refine="signed")
    assert dec.covers(D)
    dec_g = decompose(D, refine="greedy")
    assert dec.total_weight() <= dec_g.total_weight() + 1e-9


def test_refine_greedy_certifies_coverage():
    rng = np.random.default_rng(5)
    D = random_demand(rng, 8, density=0.6)
    dec = decompose(D)
    raw = [a * 0.5 for a in dec.alphas]  # break coverage
    fixed = refine_greedy(D, raw, dec.perms)
    from repro.core import Decomposition
    assert Decomposition(dec.perms, fixed).covers(D)


def test_dense_matrix():
    rng = np.random.default_rng(6)
    D = rng.random((12, 12)) + 0.01
    dec = decompose(D)
    assert dec.k == 12
    assert dec.covers(D)


def test_diagonal_matrix():
    D = np.diag([1.0, 2.0, 3.0])
    dec = decompose(D)
    assert dec.k == 1
    assert dec.covers(D)
    assert dec.total_weight() == pytest.approx(3.0)


def test_rejects_bad_input():
    with pytest.raises(ValueError):
        decompose(np.ones((2, 3)))
    with pytest.raises(ValueError):
        decompose(-np.ones((2, 2)))
