"""Training loop: convergence, crash/restore determinism, OCS integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCfg
from repro.configs.registry import ARCHS
from repro.data.pipeline import make_stream
from repro.fabric.ocs import OCSFabric
from repro.models.registry import build_model
from repro.parallel.steps import make_train_step
from repro.train.fault_tolerance import fail_at, largest_mesh
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import AdamW, cosine_schedule


def tiny_setup(tmp_path=None, moe=False, total_steps=24):
    arch = "qwen3-moe-30b-a3b" if moe else "granite-3-8b"
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg, attn_impl="chunked", ssd_impl="chunked")
    opt = AdamW(schedule=cosine_schedule(3e-3, total_steps), weight_decay=0.0)
    stream = make_stream(cfg.vocab_size, seq_len=32, global_batch=4)
    step = jax.jit(make_train_step(model, opt))
    loop_cfg = LoopConfig(
        total_steps=total_steps,
        ckpt_dir=str(tmp_path) if tmp_path else None,
        ckpt_every=8,
        log_every=4,
    )
    return model, opt, stream, step, loop_cfg


def test_loss_decreases(tmp_path):
    model, opt, stream, step, loop_cfg = tiny_setup(None)
    tr = Trainer(model, opt, stream, step, loop_cfg)
    state = tr.run(jax.random.PRNGKey(0))
    first = state.history[0]["loss"]
    last = state.history[-1]["loss"]
    assert last < first - 0.3, (first, last)


def test_crash_restore_bit_identical(tmp_path):
    seed = jax.random.PRNGKey(0)
    # Uninterrupted run.
    model, opt, stream, step, cfg_a = tiny_setup(tmp_path / "a")
    ref = Trainer(model, opt, stream, step, cfg_a).run(seed)
    # Run with two injected crashes; restores from checkpoints.
    model, opt, stream, step, cfg_b = tiny_setup(tmp_path / "b")
    tr = Trainer(
        model, opt, stream, step, cfg_b,
        failure_injector=fail_at({13, 19}),
    )
    state = tr.run(seed)
    assert state.restarts == 2
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_restart_budget_enforced(tmp_path):
    model, opt, stream, step, cfg = tiny_setup(tmp_path, total_steps=12)
    cfg.max_restarts = 2

    def always_fail(step_i):
        from repro.train.loop import SimulatedFailure

        if step_i == 3:
            raise SimulatedFailure("boom")

    tr = Trainer(model, opt, stream, step, cfg, failure_injector=always_fail)
    with pytest.raises(Exception):
        tr.run(jax.random.PRNGKey(0))


def test_ocs_controller_logs_cct_moe(tmp_path):
    model, opt, stream, step, cfg = tiny_setup(None, moe=True, total_steps=8)
    cfg.ocs_every = 4
    cfg.ocs_num_racks = 8
    fabric = OCSFabric(num_switches=4, reconfig_delay_s=20e-6)
    tr = Trainer(model, opt, stream, step, cfg, fabric=fabric)
    state = tr.run(jax.random.PRNGKey(0))
    assert len(state.cct_log) == 2
    for rec in state.cct_log:
        assert rec["cct_s"] > 0
        assert rec["makespan"] >= rec["lb"] - 1e-9


def test_straggler_watchdog_counts(tmp_path):
    import time as _time

    model, opt, stream, step, cfg = tiny_setup(None, total_steps=16)
    cfg.straggler_zscore = 3.0
    hits = []

    def slow_step(params, opt_state, batch):
        out = step(params, opt_state, batch)
        jax.block_until_ready(out[2]["loss"])
        if len(hits) == 0 and float(out[2]["loss"]) >= 0:  # after warmup
            pass
        return out

    def injector(step_i):
        if step_i == 12:
            _time.sleep(1.0)  # simulated straggler

    tr = Trainer(
        model, opt, stream, slow_step, cfg,
        failure_injector=injector,
        remap_hook=lambda s, dt: hits.append((s, dt)),
    )
    state = tr.run(jax.random.PRNGKey(0))
    assert state.stragglers >= 1
    assert 12 in [h[0] for h in hits]


def test_largest_mesh_elastic():
    assert largest_mesh(512) == (32, 16)
    assert largest_mesh(511) == (511, 1)  # prime fallback
    assert largest_mesh(256) == (16, 16)
    assert largest_mesh(48, prefer_model=16) == (3, 16)
    assert largest_mesh(24, prefer_model=16) == (3, 8)
