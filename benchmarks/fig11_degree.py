"""Fig. 11 / Appendix: P(degree(D) = k) for D = sum of k random permutations.

Validates Proposition 2's i.i.d. approximation 1 − (1 − p)^{2n} with
p = n! / ((n−k)!·n^k) against simulation.
"""

from __future__ import annotations

import math

from .common import FAST, OUT_DIR, timed, write_csv


def p_line(n: int, k: int) -> float:
    """Proposition 1: probability a given line has exactly k nonzeros."""
    return math.exp(
        math.lgamma(n + 1) - math.lgamma(n - k + 1) - k * math.log(n)
    )


def p_degree_model(n: int, k: int) -> float:
    """Proposition 2 approximation."""
    return 1.0 - (1.0 - p_line(n, k)) ** (2 * n)


def simulate_p_degree(n: int, k: int, trials: int) -> float:
    """P(degree = k) over ``trials`` draws of the "permutations" scenario.

    Each trial is one period of a sum-of-k-random-permutations trace from
    the scenario registry; the seed is derived from (n, k) so every figure
    cell draws independent trials rather than sharing period streams.
    """
    from repro.scenarios import make_trace

    trace = make_trace(
        "permutations", n=n, periods=trials, k=k, seed=n * 10007 + k * 101
    )
    hits = 0
    for D in trace:
        S = D > 0
        deg = max(S.sum(1).max(), S.sum(0).max())
        hits += deg == k
    return hits / trials


def run():
    trials = 60 if FAST else 200

    def _go():
        rows = []
        for k in (2, 4, 8, 12, 16, 20, 24, 32):  # panel (a): n = 100
            rows.append(
                {
                    "panel": "a",
                    "n": 100,
                    "k": k,
                    "model": p_degree_model(100, k),
                    "sim": simulate_p_degree(100, k, trials),
                }
            )
        for n in (20, 30, 50, 75, 100, 150):  # panel (b): k = 16
            if n <= 16:
                continue
            rows.append(
                {
                    "panel": "b",
                    "n": n,
                    "k": 16,
                    "model": p_degree_model(n, 16),
                    "sim": simulate_p_degree(n, 16, trials),
                }
            )
        return rows

    data, dt = timed(_go)
    write_csv(OUT_DIR / "fig11_degree.csv", data)
    max_dev = max(abs(r["model"] - r["sim"]) for r in data)
    n100 = [r for r in data if r["panel"] == "b" and r["n"] >= 50]
    return [
        {
            "name": "fig11_degree",
            "us_per_call": f"{1e6 * dt / max(len(data), 1):.0f}",
            "derived": (
                f"max|model-sim|={max_dev:.3f};"
                f"min_p_deg16_n>=50={min(r['sim'] for r in n100):.2f}"
            ),
        }
    ]
