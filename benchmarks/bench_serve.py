"""Serving-plane SLO benchmark: double-buffering, cache, admission.

Three experiments against the ``repro.serve`` control plane, written to
``out/BENCH_serve.json`` and gated in CI (``--check``):

1. **speedup** — identical request stream served by the synchronous and
   the async double-buffered loop, with the OCS install latency
   calibrated to the measured device solve time (the regime where
   overlap matters; ideal is ~2x, gate is ≥ {SPEEDUP_GATE}x).
2. **cache** — open-loop Poisson mixed-tenant profile with the two-tier
   schedule cache; gates cache hit rate (phase-cycling profile), sustained
   schedules/sec, and end-to-end p99.
3. **overload** — 2x overload burst through the admission controller;
   gates that requests are SHED, the queue stays bounded, and every
   ticket is accounted for.

Usage:
    python -m benchmarks.bench_serve          # full (tiny + mixed profiles)
    python -m benchmarks.bench_serve --fast   # CI: tiny profile only
    python -m benchmarks.bench_serve --check  # exit 1 on SLO gate failures
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from .artifact import git_sha, now_iso, write_artifact

# --- CI gates (headroom vs the slow-test assertions, which are stricter) --
SPEEDUP_GATE = 1.2       # async vs sync drain (slow test asserts 1.3)
CACHE_HIT_GATE = 0.70    # on the phase-cycling (tiny) profile
THROUGHPUT_FLOOR = 10.0  # schedules/sec, warm, tiny profile
P99_CEILING = 2.0        # end-to-end seconds, warm, tiny profile


def _perm_demand(n: int, rng: np.random.Generator, k: int = 4) -> np.ndarray:
    """Rotations of one random permutation — dense enough to be non-trivial."""
    sigma = rng.permutation(n)
    D = np.zeros((n, n))
    for j in range(k):
        D[np.arange(n), np.roll(sigma, j)] += rng.uniform(1.0, 4.0, size=n)
    return D


def _fast_options():
    from repro.api import SolveOptions

    return SolveOptions(validate=False, compute_lb=False)


def bench_speedup(n: int = 16, B: int = 4, batches: int = 6) -> dict:
    """Sync vs async drain on an identical stream, install ≈ solve time."""
    from repro.api.jax_backend import dispatch_many_jax
    from repro.serve.server import ScheduleServer

    opts = _fast_options()
    rng = np.random.default_rng(0)
    mats = [_perm_demand(n, rng) for _ in range(B * batches)]

    # Warm the compile cache at the serving shape, then measure the
    # steady-state per-batch solve time to calibrate the install latency.
    dispatch_many_jax(np.stack(mats[:B]), 4, 0.01, opts).collect()
    t0 = time.perf_counter()
    dispatch_many_jax(np.stack(mats[:B]), 4, 0.01, opts).collect()
    solve_s = time.perf_counter() - t0
    install = max(solve_s, 0.01)

    def drain(mode: str) -> float:
        srv = ScheduleServer(
            4, 0.01, mode=mode, solver="spectra_jax", options=opts,
            install_latency_s=install, max_batch=B,
        )
        for i, D in enumerate(mats):
            srv.submit(f"t{i % 2}", D)
        t0 = time.perf_counter()
        srv.drain()
        dt = time.perf_counter() - t0
        assert len(srv.results) == len(mats)
        return dt

    sync_s = drain("sync")
    async_s = drain("async")
    return {
        "experiment": "speedup",
        "n": n,
        "batch": B,
        "batches": batches,
        "solve_ms": 1e3 * solve_s,
        "install_ms": 1e3 * install,
        "sync_s": sync_s,
        "async_s": async_s,
        "speedup": sync_s / async_s,
    }


def bench_cache(profile: str, duration: float, rate: float) -> dict:
    """Open-loop profile through the cache-enabled async server.

    Two identical passes: the first warms XLA's compile cache (burst
    submit + drain is deterministic, so both passes see the same batch
    shapes); only the second pass is measured.
    """
    from repro.serve.cache import ScheduleCache
    from repro.serve.loadgen import (
        make_workload, mixed_profile, submit_all, tiny_profile,
    )
    from repro.serve.server import ScheduleServer

    tenants = (
        tiny_profile(n=8, rate=rate) if profile == "tiny"
        else mixed_profile(rate=rate)
    )
    wl = make_workload(tenants, duration=duration, seed=3)
    opts = _fast_options()

    def run_pass():
        srv = ScheduleServer(
            4, 0.01, mode="async", solver="spectra_jax", options=opts,
            cache=ScheduleCache(capacity=64), max_batch=4,
        )
        submit_all(srv, wl)
        srv.drain()
        return srv

    run_pass()  # warm compile cache
    srv = run_pass()
    m = srv.metrics.export()
    assert m["schedules"] == len(wl)
    by_source = {"device": 0, "cache": 0}
    for r in srv.results.values():
        by_source["cache" if r.source.startswith("cache") else "device"] += 1
    return {
        "experiment": "cache",
        "profile": profile,
        "requests": len(wl),
        "duration_s": duration,
        "cache_hit_rate": m["cache_hit_rate"],
        "schedules_per_sec": m["schedules_per_sec"],
        "p50_e2e_s": m["stages"]["e2e"]["p50_s"],
        "p99_e2e_s": m["stages"]["e2e"]["p99_s"],
        "served_from": by_source,
        "metrics": m,
    }


def bench_overload(rate: float = 120.0, duration: float = 0.5) -> dict:
    """2x overload burst: shed verdicts must appear, queue stays bounded."""
    from repro.serve.admission import AdmissionController
    from repro.serve.loadgen import make_workload, tiny_profile
    from repro.serve.server import ScheduleServer

    max_queue = 8
    wl = make_workload(tiny_profile(n=8, rate=rate), duration=duration, seed=5)
    srv = ScheduleServer(
        4, 0.01, mode="async", solver="spectra_jax", options=_fast_options(),
        admission=AdmissionController(rate=rate / 4, burst=10,
                                      max_queue=max_queue),
        max_batch=4,
    )
    max_depth = 0
    for i, a in enumerate(wl):
        srv.submit(a.tenant, a.D, now=a.t)
        max_depth = max(max_depth, len(srv))
        if i % 12 == 11:  # server drains ~3x slower than the burst offers
            srv.step()
    srv.drain()
    m = srv.metrics.export()
    return {
        "experiment": "overload",
        "requests": len(wl),
        "max_queue": max_queue,
        "max_depth": max_depth,
        "shed": m["shed"],
        "admitted": m["admitted"],
        "degraded": m["degraded"],
        "completed": len(srv.results),
        "accounted": len(srv.results) + len(srv.shed_tickets),
    }


def run(fast: bool) -> list[dict]:
    rows = []
    row = bench_speedup()
    print(f"speedup    async {row['speedup']:.2f}x vs sync "
          f"(solve {row['solve_ms']:.1f}ms, install {row['install_ms']:.1f}ms)",
          flush=True)
    rows.append(row)

    profiles = [("tiny", 0.6, 60.0)]
    if not fast:
        profiles.append(("mixed", 0.8, 40.0))
    for profile, duration, rate in profiles:
        row = bench_cache(profile, duration, rate)
        print(f"cache      {profile:6s} hit={row['cache_hit_rate']:.2f} "
              f"{row['schedules_per_sec']:.0f} sched/s "
              f"p99={row['p99_e2e_s'] * 1e3:.0f}ms "
              f"({row['requests']} reqs)", flush=True)
        rows.append(row)

    row = bench_overload()
    print(f"overload   shed={row['shed']}/{row['requests']} "
          f"max_depth={row['max_depth']} (bound {row['max_queue']})",
          flush=True)
    rows.append(row)
    return rows


def check(rows: list[dict]) -> list[str]:
    """SLO gates; see module docstring."""
    failures = []
    for r in rows:
        if r["experiment"] == "speedup" and r["speedup"] < SPEEDUP_GATE:
            failures.append(
                f"double-buffering speedup {r['speedup']:.2f}x < "
                f"{SPEEDUP_GATE}x (solve {r['solve_ms']:.1f}ms)"
            )
        if r["experiment"] == "cache" and r["profile"] == "tiny":
            if r["cache_hit_rate"] < CACHE_HIT_GATE:
                failures.append(
                    f"cache hit rate {r['cache_hit_rate']:.2f} < "
                    f"{CACHE_HIT_GATE} on phase-cycling profile"
                )
            if r["schedules_per_sec"] < THROUGHPUT_FLOOR:
                failures.append(
                    f"throughput {r['schedules_per_sec']:.1f} sched/s < "
                    f"{THROUGHPUT_FLOOR} floor"
                )
            if r["p99_e2e_s"] > P99_CEILING:
                failures.append(
                    f"e2e p99 {r['p99_e2e_s']:.2f}s > {P99_CEILING}s ceiling"
                )
        if r["experiment"] == "overload":
            if r["shed"] == 0:
                failures.append("overload burst shed nothing")
            if r["max_depth"] > r["max_queue"]:
                failures.append(
                    f"queue depth {r['max_depth']} exceeded bound "
                    f"{r['max_queue']}"
                )
            if r["accounted"] != r["requests"]:
                failures.append(
                    f"{r['accounted']} tickets accounted != "
                    f"{r['requests']} submitted"
                )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="tiny profile only (CI)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on SLO gate failures")
    args = ap.parse_args(argv)

    rows = run(fast=args.fast)
    out = write_artifact(
        "serve",
        {"rows": rows},
        git_sha=git_sha(),
        timestamp=now_iso(),
        workload="serve-control-plane",
    )
    print(f"wrote {out}")
    if args.check:
        failures = check(rows)
        for f in failures:
            print(f"GATE FAIL: {f}", file=sys.stderr)
        return 1 if failures else 0
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
