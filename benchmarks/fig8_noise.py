"""Fig. 8: sensitivity to noise (σ = 0.3% vs 1%) on the AI workloads."""

from __future__ import annotations

import functools

from .common import OUT_DIR, ratio, sweep, timed, write_csv

ALGOS = {"spectra": "spectra", "spectra_eclipse": "spectra_eclipse"}


def run():
    from repro.traffic.workloads import gpt3b_workload, moe_workload

    rows_out = []
    cases = [
        ("gpt_03", functools.partial(gpt3b_workload, noise=0.003)),
        ("gpt_1", functools.partial(gpt3b_workload, noise=0.01)),
        ("moe_03", functools.partial(moe_workload, noise=0.003)),
        ("moe_1", functools.partial(moe_workload, noise=0.01)),
    ]
    results = {}
    for wname, wfn in cases:
        data, dt = timed(sweep, wfn, ALGOS, s_values=(2, 4))
        write_csv(OUT_DIR / f"fig8_{wname}.csv", data)
        results[wname] = (data, dt)
    for fam in ("gpt", "moe"):
        lo, dt_lo = results[f"{fam}_03"]
        hi, dt_hi = results[f"{fam}_1"]
        merged = [
            {"s": a["s"], "delta": a["delta"], "hi": b["spectra"], "lo": a["spectra"]}
            for a, b in zip(lo, hi)
        ]
        rows_out.append(
            {
                "name": f"fig8_{fam}",
                "us_per_call": f"{1e6 * (dt_lo + dt_hi) / max(len(lo) + len(hi), 1):.0f}",
                "derived": f"noise1pct/noise03pct={ratio(merged, 'hi', 'lo'):.3f}x",
            }
        )
    return rows_out
