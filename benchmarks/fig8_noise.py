"""Fig. 8: sensitivity to noise (σ = 0.3% vs 1%) on the AI workloads."""

from __future__ import annotations

from .common import OUT_DIR, ratio, sweep, timed, write_csv

ALGOS = {"spectra": "spectra", "spectra_eclipse": "spectra_eclipse"}


def run():
    rows_out = []
    # Scenario registry names: the *_noisy variants pin 1% noise. The gpt
    # family defaults to the paper's 0.3% noise so "gpt" ≡ the old
    # noise=0.003 case, but the moe family defaults to noise=0.0 (its
    # tokens are exact counts) — Fig. 8's moe_03 case must pin 0.003
    # explicitly.
    cases = [
        ("gpt_03", "gpt"),
        ("gpt_1", "gpt_noisy"),
        ("moe_03", {"scenario": "moe", "noise": 0.003}),
        ("moe_1", "moe_noisy"),
    ]
    results = {}
    for wname, sc in cases:
        overrides = dict(sc) if isinstance(sc, dict) else {"scenario": sc}
        scenario = overrides.pop("scenario")
        data, dt = timed(sweep, scenario, ALGOS, s_values=(2, 4), **overrides)
        write_csv(OUT_DIR / f"fig8_{wname}.csv", data)
        results[wname] = (data, dt)
    for fam in ("gpt", "moe"):
        lo, dt_lo = results[f"{fam}_03"]
        hi, dt_hi = results[f"{fam}_1"]
        merged = [
            {"s": a["s"], "delta": a["delta"], "hi": b["spectra"], "lo": a["spectra"]}
            for a, b in zip(lo, hi)
        ]
        rows_out.append(
            {
                "name": f"fig8_{fam}",
                "us_per_call": f"{1e6 * (dt_lo + dt_hi) / max(len(lo) + len(hi), 1):.0f}",
                "derived": f"noise1pct/noise03pct={ratio(merged, 'hi', 'lo'):.3f}x",
            }
        )
    return rows_out
