"""Fig. 10: sensitivity to sparsity — m flows per port, δ = 0.04."""

from __future__ import annotations

import numpy as np

from .common import OUT_DIR, SEEDS, ratio, scenario_matrices, solver_fn, timed, write_csv

M_VALUES = (4, 8, 12, 16, 24, 32)
DELTA = 0.04
ALGOS = {
    "spectra": "spectra",
    "baseline": "baseline_less",
    "spectra_eclipse": "spectra_eclipse",
    "lb": "lb",
}


def _sweep_m(s: int):
    rows = []
    fns = {name: solver_fn(spec) for name, spec in ALGOS.items()}
    for m in M_VALUES:
        # "benchmark" scenario at this sparsity; the family's num_big
        # default already tracks max(1, m // 4).
        mats = scenario_matrices("benchmark", SEEDS, m=m)
        acc = {name: [] for name in fns}
        for D in mats:
            for name, fn in fns.items():
                acc[name].append(fn(D, s, DELTA))
        row = {"s": s, "m": m}
        row.update({k: float(np.mean(v)) for k, v in acc.items()})
        rows.append(row)
    return rows


def run():
    data, dt = timed(lambda: _sweep_m(4) + _sweep_m(2))
    write_csv(OUT_DIR / "fig10_sparsity.csv", data)
    return [
        {
            "name": "fig10_sparsity",
            "us_per_call": f"{1e6 * dt / max(len(data), 1):.0f}",
            "derived": (
                f"baseline/spectra={ratio(data, 'baseline', 'spectra'):.2f}x;"
                f"eclipse/spectra={ratio(data, 'spectra_eclipse', 'spectra'):.2f}x"
            ),
        }
    ]
