"""Matcher microbenchmark: ms/dispatch + quality per n × matcher.

Workload is the DECOMPOSE inner-loop regime — sum-of-16-permutations demand
with the node-coverage M-bonus folded into the weights — so the timings are
what ``decompose_jax`` actually pays per matching round, not a synthetic
dense-uniform instance. Quality is reported as the optimality ratio
``scipy_optimal / matched_weight`` (1.0 = exact).

Usage::

    python -m benchmarks.bench_matching [--fast] [--check] [--reps N]

Writes ``benchmarks/out/BENCH_matching.json``. ``--fast`` caps n at 256
(the CI configuration); ``--check`` exits 1 when any matcher's quality
ratio exceeds 1.10 or ``auction_fused`` fails to beat ``auction`` by ≥1.5×
per dispatch at n ≥ 256 — the kernel-parity CI gate.

``auction_fr`` (forward-reverse) is dropped above n=256: its dual-side
rounds cost ~5× the forward auction and it is never the autotuned pick in
that regime (see ``core.jaxopt.matching.AUTOTUNE_FUSED_N_THRESHOLD``).
Likewise ``auction`` is dropped at n=1024 unless ``--check`` needs it —
66.9 s/dispatch buys no information the n ∈ {256, 512} points don't.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from .artifact import git_sha, now_iso, write_artifact

SIZES = (100, 256, 512, 1024)
FAST_SIZES = (100, 256)
QUALITY_GATE = 1.10
SPEEDUP_GATE = 1.5


def bench_weights(n: int, k: int = 16, seed: int = 0) -> np.ndarray:
    """Sum-of-k-permutations demand + DECOMPOSE M-bonus weights."""
    rng = np.random.default_rng(seed)
    D = np.zeros((n, n))
    for _ in range(k):
        D[np.arange(n), rng.permutation(n)] += rng.random() + 0.05
    S = D > 0
    rd, cd = S.sum(1), S.sum(0)
    kk = max(rd.max(), cd.max())
    M = np.maximum(D, 0).max(axis=1).sum() + 1.0
    bonus = M * ((rd == kk)[:, None].astype(float) + (cd == kk)[None, :])
    return (np.maximum(D, 0) + np.where(S, bonus, 0)).astype(np.float32)


def _matchers_for(n: int) -> list[str]:
    if n <= 256:
        return ["auction", "auction_fr", "auction_fused"]
    if n <= 512:
        return ["auction", "auction_fused"]
    return ["auction", "auction_fused"]


def run(sizes, reps: int) -> list[dict]:
    import jax
    import jax.numpy as jnp
    from scipy.optimize import linear_sum_assignment

    from repro.core.jaxopt.matching import get_matcher

    rows = []
    for n in sizes:
        W = bench_weights(n)
        ri, ci = linear_sum_assignment(W, maximize=True)
        opt = float(W[ri, ci].sum())
        r = max(1, reps if n <= 256 else 1)
        for name in _matchers_for(n):
            fn = get_matcher(name)
            Wd = jnp.asarray(W)
            t0 = time.perf_counter()
            perm, conv = fn(Wd)
            jax.block_until_ready(perm)
            compile_s = time.perf_counter() - t0
            times = []
            for _ in range(r):
                t0 = time.perf_counter()
                perm, conv = fn(Wd)
                jax.block_until_ready(perm)
                times.append(time.perf_counter() - t0)
            got = float(W[np.arange(n), np.asarray(perm)].sum())
            row = {
                "n": n,
                "matcher": name,
                "ms_per_dispatch": 1e3 * float(np.mean(times)),
                "compile_s": compile_s,
                "quality_ratio": opt / got,
                "converged": bool(conv),
                "reps": r,
            }
            rows.append(row)
            print(
                f"n={n:5d} {name:14s} {row['ms_per_dispatch']:10.1f} ms"
                f"  quality={row['quality_ratio']:.6f}"
                f"  converged={row['converged']}",
                flush=True,
            )
    return rows


def check(rows: list[dict]) -> list[str]:
    """CI gates: quality ≤ 1.10 everywhere; fused ≥1.5× vs auction at n ≥ 256."""
    failures = []
    by = {(r["n"], r["matcher"]): r for r in rows}
    for r in rows:
        if r["quality_ratio"] > QUALITY_GATE:
            failures.append(
                f"n={r['n']} {r['matcher']}: quality ratio "
                f"{r['quality_ratio']:.4f} > {QUALITY_GATE}"
            )
        if not r["converged"]:
            failures.append(f"n={r['n']} {r['matcher']}: did not converge")
    for n in sorted({r["n"] for r in rows}):
        if n < 256:
            continue
        base, fused = by.get((n, "auction")), by.get((n, "auction_fused"))
        if base is None or fused is None:
            continue
        speedup = base["ms_per_dispatch"] / fused["ms_per_dispatch"]
        if speedup < SPEEDUP_GATE:
            failures.append(
                f"n={n}: auction_fused only {speedup:.2f}x faster than "
                f"auction (< {SPEEDUP_GATE}x)"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true", help="cap n at 256 (CI)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on quality/speedup gate failures")
    ap.add_argument("--reps", type=int, default=3,
                    help="timed reps per point at n <= 256 (default 3)")
    args = ap.parse_args(argv)

    sizes = FAST_SIZES if args.fast else SIZES
    rows = run(sizes, args.reps)
    out = write_artifact(
        "matching",
        {"rows": rows},
        git_sha=git_sha(),
        timestamp=now_iso(),
        workload="perm16+M-bonus",
    )
    print(f"wrote {out}")
    if args.check:
        failures = check(rows)
        for f in failures:
            print(f"GATE FAIL: {f}", file=sys.stderr)
        return 1 if failures else 0
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
