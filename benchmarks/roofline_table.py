"""Roofline summary over dry-run artifacts (+ SPECTRA fabric CCT per cell).

Reads benchmarks/out/dryrun/*.json (written by repro.launch.dryrun),
prints the §Roofline table rows, and — the paper tie-in — schedules each
cell's HLO-derived collective demand on the parallel-OCS fabric with
SPECTRA vs BASELINE.
"""

from __future__ import annotations

import json
from pathlib import Path

OUT = Path(__file__).resolve().parent / "out"
DRYRUN = OUT / "dryrun"


def load_artifacts(mesh: str = "pod1") -> list[dict]:
    arts = []
    for p in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        try:
            arts.append(json.loads(p.read_text()))
        except json.JSONDecodeError:
            continue
    return arts


def run():
    import numpy as np

    from repro.api import Problem, solve
    from repro.traffic.hlo_traffic import schedule_cell_demand

    arts = load_artifacts("pod1")
    if not arts:
        return [{
            "name": "roofline_table",
            "us_per_call": "nan",
            "derived": "no dryrun artifacts (run repro.launch.dryrun first)",
        }]
    rows, table = [], []
    for art in arts:
        r = art["roofline"]
        cell = f"{art['arch']}×{art['shape']}"
        try:
            res, cct, D = schedule_cell_demand(art)
            # Registry path validates the BASELINE schedule (Eq. 3 coverage)
            # like every other benchmark does.
            bl = solve(
                Problem(D / max(D.max(), 1e-30), 4, res.schedule.delta),
                solver="baseline_less",
            ).makespan
            ratio = bl / max(res.makespan, 1e-12)
            ocs = f"{cct*1e3:.2f}ms(x{ratio:.2f})"
        except Exception:
            ocs = "n/a"
        table.append({
            "cell": cell,
            "dominant": r["dominant"],
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "fraction": r["roofline_fraction"],
            "useful": r["useful_ratio"],
            "ocs_cct": ocs,
        })
    fracs = [t["fraction"] for t in table]
    dominants = {}
    for t in table:
        dominants[t["dominant"]] = dominants.get(t["dominant"], 0) + 1
    rows.append({
        "name": "roofline_table",
        "us_per_call": "0",
        "derived": (
            f"cells={len(table)};median_frac={float(np.median(fracs)):.3f};"
            f"dominant={dominants}"
        ),
    })
    # Write the detailed table for EXPERIMENTS.md.
    with open(OUT / "roofline_table.csv", "w") as f:
        f.write("cell,dominant,compute_s,memory_s,collective_s,fraction,"
                "useful,ocs_cct\n")
        for t in table:
            f.write(
                f"{t['cell']},{t['dominant']},{t['compute_s']:.4e},"
                f"{t['memory_s']:.4e},{t['collective_s']:.4e},"
                f"{t['fraction']:.3f},{t['useful']:.3f},{t['ocs_cct']}\n"
            )
    return rows
