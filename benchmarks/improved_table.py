"""Beyond-paper: SPECTRA++ vs paper-faithful SPECTRA (DESIGN.md §5).

Geometric-mean makespan improvement across the paper's δ×s grid on all
three workloads. SPECTRA++ is guaranteed ≤ SPECTRA (best-of includes the
paper-faithful candidate), so the ratio is ≥ 1.0; the question is how much.
"""

from __future__ import annotations

from .common import OUT_DIR, ratio, sweep, timed, write_csv

ALGOS = {"spectra": "spectra", "spectra_pp": "spectra_pp"}


def run():
    rows_out = []
    for wname in ("gpt", "moe", "benchmark"):  # repro.scenarios registry names
        data, dt = timed(sweep, wname, ALGOS, s_values=(2, 4))
        write_csv(OUT_DIR / f"improved_{wname}.csv", data)
        rows_out.append(
            {
                "name": f"improved_{wname}",
                "us_per_call": f"{1e6 * dt / max(len(data), 1):.0f}",
                "derived": f"spectra/spectra_pp={ratio(data, 'spectra', 'spectra_pp'):.3f}x",
            }
        )
    return rows_out
