"""§V-A runtime table: SPECTRA end-to-end runtimes per workload.

Paper reports 1–14 ms on a 3.7 GHz Threadripper; we report mean/p95 for the
host path plus a batched-device column: a whole stack of demand matrices
through the fused DECOMPOSE→SCHEDULE→EQUALIZE JAX call (one vmapped device
dispatch), amortized per instance, and a device-vs-host quality column
(geomean of per-instance makespan ratios on the same matrices). The n-aware
matcher ε-schedule keeps per-dispatch cost bounded at n ≥ 64, so the device
column now runs at every workload size even under FAST.

Large-n tier: ``benchmark_large`` (n=256) and ``permutations_large``
(n=512) exercise the ``auction_fused`` autotune bucket with reduced
reps/batch; FAST keeps n ≤ 256 (the n=512 row is skipped).

AUTOTUNE thresholds re-measured 2026-08 on the ``bench_matching`` workload
(sum-of-16-permutations + DECOMPOSE M-bonus, CPU host, jnp matcher paths):
per-dispatch ``auction_fused`` vs ``auction`` = 0.37 s vs 0.72 s at n=256
(1.9×), 2.8 s vs 10.8 s at n=512 (3.8×), 22.9 s vs 66.9 s at n=1024
(2.9×), all at optimality ratio 1.0000 — confirming
``AUTOTUNE_FUSED_N_THRESHOLD = 128`` (``auction`` still wins ≤ 32;
``auction_fr`` stays the robust mid-range pick; fused owns n > 128).
"""

from __future__ import annotations

import time

import numpy as np

from .common import FAST, OUT_DIR, write_csv


def _batched_device(scenario: str, s: int, delta: float, B: int):
    """(per-instance ms, geomean device/host makespan ratio) for one fused
    vmapped device call over B matrices.

    One timed repetition after the compile warmup: on CPU hosts the device
    matcher loop dominates, so a single steady dispatch is the honest,
    affordable sample. The quality ratio reuses the warmup call's reports
    against per-instance host solves of the same matrices.
    """
    try:
        from repro.api import Problem, SolveOptions, solve, solve_many
        from repro.scenarios import make_trace
    except Exception:  # pragma: no cover - jax missing
        return None, None
    opts = SolveOptions(validate=False, compute_lb=False)
    Ds = make_trace(scenario, periods=B, seed=1000).demands
    try:
        reports = solve_many(Ds, s, delta, solver="spectra_jax", options=opts)
    except Exception:  # pragma: no cover - jax missing / no device
        return None, None
    ratios = []
    for D, rep in zip(Ds, reports):
        host = solve(Problem(D, s, delta), solver="spectra", options=opts)
        ratios.append(rep.makespan / host.makespan)
    quality = float(np.exp(np.mean(np.log(ratios))))
    t0 = time.perf_counter()
    solve_many(Ds, s, delta, solver="spectra_jax", options=opts)
    return 1e3 * (time.perf_counter() - t0) / B, quality


def run():
    from repro.api import Problem, SolveOptions, solve
    from .common import scenario_matrices

    reps = 3 if FAST else 10
    batch = 4 if FAST else 16
    # Large-n rows amortize one expensive dispatch instead of many cheap
    # ones: the point is the per-instance cost of the fused-matcher bucket,
    # not tight percentiles.
    large_reps = 2
    large_batch = 2
    opts = SolveOptions(validate=False, compute_lb=False)
    workloads = [
        ("gpt_s4", "gpt", 4, False),
        ("moe_s4", "moe", 4, False),
        ("benchmark_s4", "benchmark", 4, False),
        ("benchmark_large_s4", "benchmark_large", 4, True),
    ]
    if not FAST:  # FAST keeps n ≤ 256
        workloads.append(("permutations_large_s4", "permutations_large", 4, True))
    rows, out = [], []
    for wname, scenario, s, large in workloads:
        w_reps = large_reps if large else reps
        w_batch = large_batch if large else batch
        times = []
        for D in scenario_matrices(scenario, w_reps):
            t0 = time.perf_counter()
            solve(Problem(D, s, 0.01), solver="spectra", options=opts)
            times.append(time.perf_counter() - t0)
        mean_ms = 1e3 * float(np.mean(times))
        p95_ms = 1e3 * float(np.percentile(times, 95))
        dev_ms, quality = _batched_device(scenario, s, 0.01, w_batch)
        rows.append(
            {
                "workload": wname,
                "mean_ms": mean_ms,
                "p95_ms": p95_ms,
                "batched_device_ms_per_instance": (
                    float("nan") if dev_ms is None else dev_ms
                ),
                "device_quality_vs_host": (
                    float("nan") if quality is None else quality
                ),
                "batch_size": w_batch,
            }
        )
        derived = f"p95_ms={p95_ms:.1f}"
        if dev_ms is not None:
            derived += (
                f" batched_device_ms/inst={dev_ms:.2f} (B={w_batch})"
                f" quality_vs_host={quality:.3f}"
            )
        out.append(
            {
                "name": f"runtime_{wname}",
                "us_per_call": f"{1e3 * mean_ms:.0f}",
                "derived": derived,
            }
        )
    write_csv(OUT_DIR / "runtime.csv", rows)
    return out
