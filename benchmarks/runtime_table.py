"""§V-A runtime table: SPECTRA end-to-end runtimes per workload.

Paper reports 1–14 ms on a 3.7 GHz Threadripper; we report mean/p95 here.
"""

from __future__ import annotations

import time

import numpy as np

from .common import FAST, OUT_DIR, write_csv


def run():
    from repro.api import Problem, SolveOptions, solve
    from repro.traffic.workloads import benchmark_workload, gpt3b_workload, moe_workload

    reps = 3 if FAST else 10
    opts = SolveOptions(validate=False, compute_lb=False)
    rows, out = [], []
    for wname, wfn, s in (
        ("gpt_s4", gpt3b_workload, 4),
        ("moe_s4", moe_workload, 4),
        ("benchmark_s4", benchmark_workload, 4),
    ):
        times = []
        for seed in range(reps):
            D = wfn(rng=np.random.default_rng(seed))
            t0 = time.perf_counter()
            solve(Problem(D, s, 0.01), solver="spectra", options=opts)
            times.append(time.perf_counter() - t0)
        mean_ms = 1e3 * float(np.mean(times))
        p95_ms = 1e3 * float(np.percentile(times, 95))
        rows.append({"workload": wname, "mean_ms": mean_ms, "p95_ms": p95_ms})
        out.append(
            {
                "name": f"runtime_{wname}",
                "us_per_call": f"{1e3 * mean_ms:.0f}",
                "derived": f"p95_ms={p95_ms:.1f}",
            }
        )
    write_csv(OUT_DIR / "runtime.csv", rows)
    return out
