"""Generate the data-driven sections of EXPERIMENTS.md from artifacts.

Usage: PYTHONPATH=src python -m benchmarks.gen_experiments
Prints markdown for §Dry-run and §Roofline (paste/pipe into EXPERIMENTS.md).
"""

from __future__ import annotations

import json
from pathlib import Path

OUT = Path(__file__).resolve().parent / "out"
DRYRUN = OUT / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def load(mesh: str) -> dict:
    arts = {}
    for p in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        a = json.loads(p.read_text())
        arts[(a["arch"], a["shape"])] = a
    return arts


def _true_params(arch: str) -> float:
    """Exact param count from the abstract init (display; some artifacts
    stored an int32-overflowed count)."""
    import jax

    from repro.configs.registry import get_arch
    from repro.models.registry import build_model

    cfg = get_arch(arch)
    shapes = jax.eval_shape(lambda: build_model(cfg).init(jax.random.PRNGKey(0)))
    import math

    return sum(math.prod(a.shape) for a in jax.tree.leaves(shapes))


def dryrun_section() -> str:
    import functools

    true_params = functools.lru_cache(maxsize=None)(_true_params)
    lines = ["### §Dry-run tables", ""]
    for mesh, label in (("pod1", "16×16 single-pod (256 chips)"),
                        ("pod2", "2×16×16 multi-pod (512 chips)")):
        arts = load(mesh)
        lines.append(f"### {label}")
        lines.append("")
        lines.append("| arch | shape | kind | params | compile_s | "
                     "bytes/device | collective ops |")
        lines.append("|---|---|---|---|---|---|---|")
        for (arch, shape), a in sorted(
            arts.items(), key=lambda kv: (kv[0][0], SHAPE_ORDER.index(kv[0][1]))
        ):
            ops = a["roofline"]["collectives"]["ops"]
            ops_s = " ".join(f"{k}:{v}" for k, v in sorted(ops.items()))
            lines.append(
                f"| {arch} | {shape} | {a['kind']} | "
                f"{true_params(arch)/1e9:.2f}B | {a['compile_s']} | "
                f"{fmt_bytes(a['bytes_per_device_est'])} | {ops_s} |"
            )
        lines.append("")
    return "\n".join(lines)


def roofline_section() -> str:
    arts = load("pod1")
    lines = [
        "### §Roofline table (single-pod 16×16, per chip; TPU v5e: "
        "197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)",
        "",
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL_FLOPS | useful | fraction |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), a in sorted(
        arts.items(), key=lambda kv: (kv[0][0], SHAPE_ORDER.index(kv[0][1]))
    ):
        r = a["roofline"]
        lines.append(
            f"| {arch} | {shape} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['model_flops']:.2e} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |"
        )
    lines.append("")
    return "\n".join(lines)


def main():
    print(dryrun_section())
    print()
    print(roofline_section())


if __name__ == "__main__":
    main()
