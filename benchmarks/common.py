"""Shared helpers for the paper-figure benchmarks.

Algorithms are addressed by their ``repro.api`` registry names (plus the
pseudo-solver ``"lb"`` for the §IV lower bound) and workloads by their
``repro.scenarios`` registry names; ``sweep`` resolves both through the
unified entry points (``solve`` / ``make_trace``), so there are no
per-algorithm adapters or fig-local generators here.
"""

from __future__ import annotations

import csv
import os
import time
import warnings
from pathlib import Path

import numpy as np

from repro.api import Problem, SolveOptions, solve
from repro.core import lower_bound

OUT_DIR = Path(__file__).resolve().parent / "out"
FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
DELTAS = np.array([1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1])
SEEDS = 3 if FAST else 8  # paper: 50 runs / datapoint

# Makespan sweeps don't need the lower bound attached to every report; the
# "lb" column computes it directly.
_SWEEP_OPTIONS = SolveOptions(compute_lb=False)


def solver_fn(spec):
    """Resolve a sweep column: registry solver name, ``"lb"``, or callable."""
    if callable(spec):
        return spec
    if spec == "lb":
        return lambda D, s, delta: lower_bound(D, s, delta)
    return lambda D, s, delta, _name=spec: solve(
        Problem(D, s, delta), solver=_name, options=_SWEEP_OPTIONS
    ).makespan


def scenario_matrices(scenario, seeds: int, **overrides) -> list[np.ndarray]:
    """Materialize the per-seed matrices of a registered scenario name.

    One trace of ``seeds`` periods: period ``t`` is exactly the matrix the
    fig scripts historically drew as ``workload_fn(rng=default_rng(t))``
    (the registry seeds period ``t`` with ``seed + t``).
    """
    from repro.scenarios import make_trace

    return list(make_trace(scenario, periods=seeds, **overrides).demands)


def sweep(scenario, algos, s_values, deltas=DELTAS, seeds=None, **overrides):
    """→ rows of dict(workload-ready) mean makespans over seeds.

    ``scenario`` is a ``repro.scenarios`` registry name (extra keyword
    arguments override its spec/params — e.g. ``noise=0.01``) or, for
    legacy call sites, a callable ``workload_fn(rng=...)`` sampled once per
    seed. ``algos`` maps column name → registry solver name (or callable).
    """
    seeds = SEEDS if seeds is None else seeds
    if callable(scenario):
        if overrides:  # only the registry path can apply spec overrides
            raise TypeError(
                f"overrides {sorted(overrides)} require a scenario name; "
                "bind kwargs into the callable (functools.partial) instead"
            )
        mats = [scenario(rng=np.random.default_rng(t)) for t in range(seeds)]
    else:
        mats = scenario_matrices(scenario, seeds, **overrides)
    fns = {name: solver_fn(spec) for name, spec in algos.items()}
    rows = []
    for s in s_values:
        for delta in deltas:
            acc = {name: [] for name in fns}
            for D in mats:
                for name, fn in fns.items():
                    acc[name].append(fn(D, s, float(delta)))
            row = {"s": s, "delta": float(delta)}
            row.update({name: float(np.mean(v)) for name, v in acc.items()})
            rows.append(row)
    return rows


def write_csv(path: Path, rows: list[dict]) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    if not rows:
        return
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)


def ratio(rows: list[dict], a: str, b: str) -> float:
    """Geometric-mean ratio a/b across sweep rows (the paper's 'average')."""
    vals = [r[a] / r[b] for r in rows if r.get(b, 0) > 0]
    return float(np.exp(np.mean(np.log(vals)))) if vals else float("nan")


def timed(fn, *args, reps: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return out, dt


# Deprecation shims: the old per-algorithm adapters resolve through the
# registry. Old call sites keep working; new code addresses solvers by name.
_DEPRECATED_ALGOS = {
    "algo_spectra": "spectra",
    "algo_spectra_no_eq": "spectra_no_eq",
    "algo_spectra_pp": "spectra_pp",
    "algo_baseline": "baseline_less",
    "algo_eclipse_variant": "spectra_eclipse",
    "algo_lb": "lb",
}


def __getattr__(name: str):
    if name in _DEPRECATED_ALGOS:
        target = _DEPRECATED_ALGOS[name]
        warnings.warn(
            f"benchmarks.common.{name} is deprecated; use "
            f'solver_fn("{target}") or repro.api.solve(..., solver="{target}")',
            DeprecationWarning,
            stacklevel=2,
        )
        return solver_fn(target)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
