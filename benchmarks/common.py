"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import csv
import os
import time
from pathlib import Path

import numpy as np

from repro.core import baseline_less, eclipse_decompose, lower_bound, spectra, spectra_pp

OUT_DIR = Path(__file__).resolve().parent / "out"
FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
DELTAS = np.array([1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1])
SEEDS = 3 if FAST else 8  # paper: 50 runs / datapoint


def algo_spectra(D, s, delta):
    return spectra(D, s, delta).makespan


def algo_spectra_no_eq(D, s, delta):
    return spectra(D, s, delta, do_equalize=False).makespan


def algo_spectra_pp(D, s, delta):
    return spectra_pp(D, s, delta).makespan


def algo_baseline(D, s, delta):
    sched = baseline_less(D, s, delta)
    sched.validate(D)
    return sched.makespan()


def algo_eclipse_variant(D, s, delta):
    return spectra(
        D, s, delta, decompose_fn=lambda M: eclipse_decompose(M, delta)
    ).makespan


def algo_lb(D, s, delta):
    return lower_bound(D, s, delta)


def sweep(workload_fn, algos, s_values, deltas=DELTAS, seeds=None):
    """→ rows of dict(workload-ready) mean makespans over seeds."""
    seeds = SEEDS if seeds is None else seeds
    rows = []
    for s in s_values:
        for delta in deltas:
            acc = {name: [] for name in algos}
            for seed in range(seeds):
                D = workload_fn(rng=np.random.default_rng(seed))
                for name, fn in algos.items():
                    acc[name].append(fn(D, s, float(delta)))
            row = {"s": s, "delta": float(delta)}
            row.update({name: float(np.mean(v)) for name, v in acc.items()})
            rows.append(row)
    return rows


def write_csv(path: Path, rows: list[dict]) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    if not rows:
        return
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)


def ratio(rows: list[dict], a: str, b: str) -> float:
    """Geometric-mean ratio a/b across sweep rows (the paper's 'average')."""
    vals = [r[a] / r[b] for r in rows if r.get(b, 0) > 0]
    return float(np.exp(np.mean(np.log(vals)))) if vals else float("nan")


def timed(fn, *args, reps: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return out, dt
