"""Flow-level FCT/CCT comparison: SPECTRA vs rotor vs rotor+VLB (beyond-paper).

For each workload trace (gpt / moe / uniform) run the scheduled fabric
(spectra), the demand-oblivious rotor, and the VLB-sized rotor through the
flow-level replay (``run_scenario(..., flowsim=True)``) and report pooled
FCT percentiles, worst-period CCT, mean utilization, δ overhead, indirect
fraction, and the conservation verdict. One CSV row per (scenario, solver);
the derived column carries the headline p50/p99 and conservation.

The figure the subsystem exists for: on skewed AI traffic (gpt/moe) the
scheduled fabric's p99 FCT beats the rotor family outright, while on
uniform all-to-all the oblivious rotor closes to within ~3% — matching the
RotorNet/Opus framing that rotors win exactly when demand is featureless.

FAST mode shrinks to n=8, T=2 variants.
"""

from __future__ import annotations

from .common import FAST, OUT_DIR, write_csv

SCENARIOS = ("gpt", "moe", "uniform")
SOLVERS = ("spectra", "rotor", "rotor_vlb")


def run():
    import time

    from repro.api import SolveOptions
    from repro.scenarios import run_scenario

    options = SolveOptions(compute_lb=False)
    overrides = {"n": 8, "periods": 2} if FAST else {}
    data = []
    rows_out = []
    for name in SCENARIOS:
        for solver in SOLVERS:
            t0 = time.perf_counter()
            rep = run_scenario(
                name, solver=solver, flowsim=True, options=options,
                **overrides,
            )
            dt = time.perf_counter() - t0
            s = rep.flowsim_summary()
            data.append(
                {
                    "scenario": name,
                    "solver": solver,
                    "T": s["periods"],
                    "n": rep.trace.n,
                    "flows": s["flows"],
                    "fct_p50": s["fct_p50"],
                    "fct_p90": s["fct_p90"],
                    "fct_p99": s["fct_p99"],
                    "fct_mean": s["fct_mean"],
                    "cct_max": s["cct_max"],
                    "cct_mean": s["cct_mean"],
                    "util_mean": s["util_mean"],
                    "delta_overhead": s["delta_overhead"],
                    "indirect_frac": s["indirect_frac"],
                    "conserved": s["conserved"],
                    "runtime_s": dt,
                }
            )
            rows_out.append(
                {
                    "name": f"fig_flowsim_{name}_{solver}",
                    "us_per_call": f"{1e6 * dt / max(s['periods'], 1):.0f}",
                    "derived": (
                        f"fct_p50={s['fct_p50']:.4f};"
                        f"fct_p99={s['fct_p99']:.4f};"
                        f"cct={s['cct_max']:.4f};"
                        f"indirect={s['indirect_frac']:.3f};"
                        f"conserved={s['conserved']}"
                    ),
                }
            )
    write_csv(OUT_DIR / "fig_flowsim.csv", data)
    return rows_out
