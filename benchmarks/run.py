# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: python -m benchmarks.run [--fast] [--scenario NAME ...]

Default mode runs every paper-figure benchmark (Fig. 6-11), the runtime
table, the beyond-paper SPECTRA++ table, and — if dry-run artifacts exist
under benchmarks/out/dryrun — the roofline summary, writing per-figure CSVs
to benchmarks/out/.

``--scenario`` mode instead drives named ``repro.scenarios`` registry
entries end-to-end through ``run_scenario`` (whole trace → one batched
``solve_many``): ``--scenario gpt moe`` or ``--scenario all``, with
``--solver`` picking the registry solver (default spectra) and ``--periods``
overriding the trace length. ``--online`` additionally runs the stateful
cross-period controller over each trace and exits 1 if any online period
comes out worse than its stateless baseline (the CI online gate).
``--flowsim`` replays each trace at the flow level for both ``--solver``
and the ``rotor_vlb`` baseline, prints FCT percentiles, and exits 1 if any
period fails bytes conservation (the CI flowsim gate).
``--obs`` turns on the span tracer for the whole run, validates the
makespan-attribution identity (``transmission + δ paid + idle ≡
s·makespan``), per-switch utilization ∈ [0, 1], and LB gap ≥ 0 on every
scenario, writes the Chrome trace to ``benchmarks/out/TRACE_scenarios.json``,
re-parses it, and exits 1 on any violation (the CI obs-smoke gate).
``--fast`` shrinks scenario mode to tiny (n=8, T=3) variants — the
smoke-lane configuration.

Either mode prints one ``name,us_per_call,derived`` line per table.
"""

from __future__ import annotations

import argparse
import os
import sys


def _run_scenarios(
    names: list[str], solver: str, periods: int | None, fast: bool,
    online: bool = False, flowsim: bool = False, obs: bool = False,
) -> None:
    from repro.scenarios import list_scenarios, run_scenario

    if names == ["all"]:
        names = list_scenarios()
    # Flowsim mode compares the requested solver against the oblivious
    # rotor+VLB baseline on every trace (deduped if they coincide).
    solvers = [solver]
    if flowsim and "rotor_vlb" not in solvers:
        solvers.append("rotor_vlb")
    overrides: dict = {}
    if fast:
        overrides.update(n=8, periods=3)
    if periods is not None:
        overrides["periods"] = periods
    if obs:
        from repro.obs import get_tracer

        get_tracer().enable()
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        for sv in solvers:
            failures += _run_one_scenario(
                run_scenario, name, sv, overrides,
                online=online, flowsim=flowsim, obs=obs,
            )
    if obs:
        failures += _check_trace(solver)
    if failures:  # scenario mode gates CI — a broken scenario must fail the job
        sys.exit(1)


def _check_obs(rep, name: str, solver: str) -> int:
    """Attribution gate for one report; prints its CSV row; return #failures.

    Validates (a) the identity ``transmission + δ paid + idle ≡ s·makespan``
    on every period of both passes (``attribute_scenario`` raises), (b)
    per-switch utilization ∈ [0, 1], and (c) LB gap ≥ 0 — all within the
    backend tolerance.
    """
    from repro.obs import attribute_scenario

    try:
        att = attribute_scenario(rep)
        att.check()
    except (AssertionError, ValueError) as exc:
        print(f"obs_{name}_{solver},nan,ERROR:{type(exc).__name__}:{exc}")
        return 1
    failures = 0
    agg = att.summary()
    for t, table in enumerate(att.tables + att.online_tables):
        a = table.attribution
        utils = table.utilization
        if len(utils) and (utils.min() < -att.tol or utils.max() > 1 + att.tol):
            print(f"obs_{name}_{solver},nan,"
                  f"ERROR:period {t} utilization outside [0,1]: "
                  f"[{utils.min():.6f}, {utils.max():.6f}]")
            failures += 1
        # Stateless makespans can't beat the §IV bound; online credit-aware
        # makespans can, by at most the per-switch δ the reuse avoided (the
        # bound charges δ for every configuration, reused or not).
        floor = -(a.delta_avoided / a.s + att.tol * max(1.0, a.makespan))
        gap = a.lb_gap
        if gap == gap and gap < floor:  # finite and below the floor
            print(f"obs_{name}_{solver},nan,"
                  f"ERROR:period {t} makespan beats the lower bound: "
                  f"gap {gap:.6g} < floor {floor:.6g}")
            failures += 1
    derived = (
        f"residual={agg['max_identity_residual']:.3g};"
        f"tx={agg['transmission_share']:.3f};d={agg['delta_share']:.3f};"
        f"idle={agg['idle_share']:.3f};util_min={agg['util_min']:.3f}"
    )
    if att.online_tables:
        derived += (
            f";online_reuse={agg['online_reuse_count']}"
            f";online_d_avoided={agg['online_delta_avoided']:.4f}"
        )
    if not failures:
        print(f"obs_{name}_{solver},0,{derived}")
    return failures


def _check_trace(solver: str) -> int:
    """Export + re-parse the Chrome trace; gate on the expected span names."""
    import json

    from repro.obs import get_tracer

    from .common import OUT_DIR

    tracer = get_tracer()
    path = tracer.save(OUT_DIR / "TRACE_scenarios.json")
    failures = 0
    try:
        doc = json.loads(path.read_text())
        events = doc["traceEvents"]
        assert isinstance(events, list) and events, "no trace events"
        for e in events:
            assert e["ph"] in ("X", "i", "C"), f"bad phase {e['ph']!r}"
            assert e["ts"] >= 0, f"negative timestamp in {e['name']!r}"
            if e["ph"] == "X":
                assert e["dur"] >= 0, f"negative duration in {e['name']!r}"
    except (AssertionError, KeyError, ValueError) as exc:
        print(f"obs_trace,nan,ERROR:{type(exc).__name__}:{exc}")
        return 1
    names = {s.name for s in tracer.spans()}
    want = {"solve_many", "install"}
    if solver == "spectra":  # host pipeline: per-stage spans must appear
        want |= {"decompose", "schedule", "equalize", "matcher"}
    missing = want - names
    if missing:
        print(f"obs_trace,nan,ERROR:missing spans {sorted(missing)}")
        failures += 1
    else:
        print(f"obs_trace,0,events={len(events)};spans={len(tracer.spans())};"
              f"path={path}")
    return failures


def _run_one_scenario(
    run_scenario, name: str, solver: str, overrides: dict,
    *, online: bool, flowsim: bool, obs: bool = False,
) -> int:
    """Run one (scenario, solver) pair; print its CSV row; return #failures."""
    try:
        rep = run_scenario(
            name, solver=solver, online=online, flowsim=flowsim, **overrides
        )
    except Exception as exc:
        print(f"scenario_{name}_{solver},nan,ERROR:{type(exc).__name__}:{exc}")
        return 1
    failures = 0
    if obs:
        failures += _check_obs(rep, name, solver)
    s = rep.summary()
    derived = (
        f"T={s['periods']};n={s['n']};mean_mk={s['mean_makespan']:.4f};"
        f"gap={s['geomean_gap']:.3f};buckets={s['buckets']}"
    )
    if rep.spec.units == "bytes":
        derived += f";cct_s={s['total_cct_s']:.4g}"
    if flowsim:
        fs = rep.flowsim_summary()
        derived += (
            f";fct_p50={fs['fct_p50']:.4f};fct_p99={fs['fct_p99']:.4f};"
            f"indirect={fs['indirect_frac']:.3f};conserved={fs['conserved']}"
        )
        # The structural guarantee this mode gates in CI: every byte of
        # every period's demand must be delivered.
        if not fs["conserved"]:
            derived += f";VIOLATION_residual={fs['residual']:.3g}"
            failures += 1
    if online:
        o = rep.online_summary()
        derived += (
            f";online_mk={o['online_total_makespan']:.4f};"
            f"stateless_mk={o['stateless_total_makespan']:.4f};"
            f"reuse={o['total_reuse']};"
            f"d_avoided={o['total_delta_avoided']:.4f}"
        )
        # The structural guarantee this mode gates in CI: no online
        # period may come out worse than its stateless baseline.
        bad = [
            p.period for p in rep.online_periods
            if p.makespan > p.stateless_makespan * (1 + 1e-6) + 1e-9
        ]
        if bad:
            derived += f";VIOLATION_periods={bad}"
            failures += 1
    print(f"scenario_{name}_{solver},{1e6 * s['runtime_s'] / max(s['periods'], 1):.0f},{derived}")
    sys.stdout.flush()
    return failures


def _run_figures() -> None:
    from . import (
        fig6_ai_workloads,
        fig7_equalize,
        fig8_noise,
        fig9_benchmark,
        fig10_sparsity,
        fig11_degree,
        fig_flowsim,
        fig_online,
        improved_table,
        runtime_table,
    )

    modules = [
        fig6_ai_workloads,
        fig7_equalize,
        fig8_noise,
        fig9_benchmark,
        fig10_sparsity,
        fig11_degree,
        fig_online,
        fig_flowsim,
        runtime_table,
        improved_table,
    ]
    try:  # roofline summary only if dry-run artifacts are present
        from . import roofline_table

        modules.append(roofline_table)
    except ImportError:
        pass

    print("name,us_per_call,derived")
    for mod in modules:
        try:
            rows = mod.run()
        except Exception as exc:  # pragma: no cover
            print(f"{mod.__name__.split('.')[-1]},nan,ERROR:{type(exc).__name__}:{exc}")
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']},{r['derived']}")
        sys.stdout.flush()


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="cheap settings (fewer seeds; tiny scenario variants)")
    ap.add_argument("--scenario", nargs="+", metavar="NAME", default=None,
                    help="run these repro.scenarios names (or 'all') instead of the fig tables")
    ap.add_argument("--solver", default="spectra",
                    help="repro.api solver for --scenario mode (default: spectra)")
    ap.add_argument("--periods", type=int, default=None,
                    help="override trace length T in --scenario mode")
    ap.add_argument("--online", action="store_true",
                    help="scenario mode: run the stateful cross-period "
                         "controller too; exit 1 if any online period is "
                         "worse than its stateless baseline")
    ap.add_argument("--flowsim", action="store_true",
                    help="scenario mode: flow-level replay of --solver and "
                         "the rotor_vlb baseline; exit 1 if any period "
                         "fails bytes conservation")
    ap.add_argument("--obs", action="store_true",
                    help="scenario mode: trace the run, validate the "
                         "makespan-attribution identity / utilization / LB "
                         "gap per scenario, write and re-parse the Chrome "
                         "trace; exit 1 on any violation")
    args = ap.parse_args(argv)

    if args.obs and not args.scenario:
        ap.error("--obs requires --scenario")
    if args.fast:
        os.environ["REPRO_BENCH_FAST"] = "1"
    if args.scenario:
        _run_scenarios(args.scenario, args.solver, args.periods, args.fast,
                       online=args.online, flowsim=args.flowsim, obs=args.obs)
    else:
        _run_figures()


if __name__ == "__main__":
    main()
