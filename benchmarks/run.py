# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: python -m benchmarks.run [--fast]

Runs every paper-figure benchmark (Fig. 6-11), the runtime table, the
beyond-paper SPECTRA++ table, and — if dry-run artifacts exist under
benchmarks/out/dryrun — the roofline summary. Writes per-figure CSVs to
benchmarks/out/ and prints one ``name,us_per_call,derived`` line per table.
"""

from __future__ import annotations

import os
import sys


def main() -> None:
    if "--fast" in sys.argv:
        os.environ["REPRO_BENCH_FAST"] = "1"

    from . import (
        fig6_ai_workloads,
        fig7_equalize,
        fig8_noise,
        fig9_benchmark,
        fig10_sparsity,
        fig11_degree,
        improved_table,
        runtime_table,
    )

    modules = [
        fig6_ai_workloads,
        fig7_equalize,
        fig8_noise,
        fig9_benchmark,
        fig10_sparsity,
        fig11_degree,
        runtime_table,
        improved_table,
    ]
    try:  # roofline summary only if dry-run artifacts are present
        from . import roofline_table

        modules.append(roofline_table)
    except ImportError:
        pass

    print("name,us_per_call,derived")
    for mod in modules:
        try:
            rows = mod.run()
        except Exception as exc:  # pragma: no cover
            print(f"{mod.__name__.split('.')[-1]},nan,ERROR:{type(exc).__name__}:{exc}")
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']},{r['derived']}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
