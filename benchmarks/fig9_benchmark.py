"""Fig. 9: the standard 100×100 benchmark workload vs δ, s ∈ {2, 4}.

Paper: SPECTRA ≈ 2.4× shorter than BASELINE, ≈ 1.2× shorter than the
ECLIPSE-decomposition variant, and close to the lower bound.
"""

from __future__ import annotations

from .common import OUT_DIR, ratio, sweep, timed, write_csv

ALGOS = {
    "spectra": "spectra",
    "baseline": "baseline_less",
    "spectra_eclipse": "spectra_eclipse",
    "lb": "lb",
}


def run():
    data, dt = timed(sweep, "benchmark", ALGOS, s_values=(2, 4))
    write_csv(OUT_DIR / "fig9_benchmark.csv", data)
    return [
        {
            "name": "fig9_benchmark",
            "us_per_call": f"{1e6 * dt / max(len(data), 1):.0f}",
            "derived": (
                f"baseline/spectra={ratio(data, 'baseline', 'spectra'):.2f}x;"
                f"eclipse/spectra={ratio(data, 'spectra_eclipse', 'spectra'):.2f}x;"
                f"spectra/lb={ratio(data, 'spectra', 'lb'):.3f}"
            ),
        }
    ]
