"""Fig. 7: sensitivity to the EQUALIZE step (SPECTRA with/without).

Paper: equalization matters for the skewed GPT traffic (large elements must
be split), but not for the dense near-uniform MoE traffic.
"""

from __future__ import annotations

from .common import OUT_DIR, ratio, sweep, timed, write_csv

ALGOS = {"spectra": "spectra", "spectra_no_eq": "spectra_no_eq"}


def run():
    rows_out = []
    for wname in ("gpt", "moe"):  # repro.scenarios registry names
        data, dt = timed(sweep, wname, ALGOS, s_values=(2, 4))
        write_csv(OUT_DIR / f"fig7_{wname}.csv", data)
        rows_out.append(
            {
                "name": f"fig7_{wname}",
                "us_per_call": f"{1e6 * dt / max(len(data), 1):.0f}",
                "derived": f"no_eq/with_eq={ratio(data, 'spectra_no_eq', 'spectra'):.3f}x",
            }
        )
    return rows_out
