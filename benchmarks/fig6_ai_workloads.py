"""Fig. 6: makespan vs δ on the GPT and MoE AI workloads, s ∈ {2, 4}.

Paper's claims to validate: SPECTRA ≈ 1.4× (GPT) / 1.9× (MoE) shorter than
BASELINE on average; the ECLIPSE-based DECOMPOSE is ≈1.1× (GPT) / 1.8× (MoE)
worse than SPECTRA; SPECTRA tracks the lower bound.
"""

from __future__ import annotations

from .common import OUT_DIR, ratio, sweep, timed, write_csv

ALGOS = {
    "spectra": "spectra",
    "baseline": "baseline_less",
    "spectra_eclipse": "spectra_eclipse",
    "lb": "lb",
}


def run():
    rows_out = []
    for wname in ("gpt", "moe"):  # repro.scenarios registry names
        data, dt = timed(sweep, wname, ALGOS, s_values=(2, 4))
        write_csv(OUT_DIR / f"fig6_{wname}.csv", data)
        rows_out.append(
            {
                "name": f"fig6_{wname}",
                "us_per_call": f"{1e6 * dt / max(len(data), 1):.0f}",
                "derived": (
                    f"baseline/spectra={ratio(data, 'baseline', 'spectra'):.2f}x;"
                    f"eclipse/spectra={ratio(data, 'spectra_eclipse', 'spectra'):.2f}x;"
                    f"spectra/lb={ratio(data, 'spectra', 'lb'):.3f}"
                ),
            }
        )
    return rows_out
