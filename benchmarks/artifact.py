"""Shared benchmark-artifact writer: one schema for every ``BENCH_*.json``.

Every perf benchmark writes its results through ``write_artifact`` so the
files under ``benchmarks/out/`` are machine-comparable across commits:
the same envelope (schema version, benchmark name, git SHA, timestamp,
workload tag) around the benchmark's own metrics payload. ``git_sha`` and
``timestamp`` are computed by the caller (see ``git_sha()`` /
``now_iso()`` — callers in tests pass fixed values for reproducible
round-trips).
"""

from __future__ import annotations

import json
import subprocess
from datetime import datetime, timezone
from pathlib import Path
from typing import Any

from .common import OUT_DIR

SCHEMA = "repro-bench/v1"

__all__ = ["SCHEMA", "git_sha", "now_iso", "read_artifact", "write_artifact"]


def git_sha(cwd: str | Path | None = None) -> str | None:
    """Current commit SHA, or None outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=cwd or Path(__file__).resolve().parent,
            capture_output=True,
            text=True,
            timeout=10,
        )
    except OSError:  # pragma: no cover - git missing
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def now_iso() -> str:
    """UTC timestamp in ISO-8601 (the envelope's ``timestamp`` format)."""
    return datetime.now(timezone.utc).isoformat(timespec="seconds")


def write_artifact(
    name: str,
    metrics: dict[str, Any],
    *,
    git_sha: str | None,
    timestamp: str,
    workload: str | None = None,
    out_dir: Path | None = None,
) -> Path:
    """Write ``BENCH_<name>.json`` in the shared envelope; returns the path.

    ``metrics`` is the benchmark's own payload (rows, gates, whatever —
    must be JSON-serializable). ``git_sha``/``timestamp`` are passed in
    so the writer itself stays deterministic and testable.
    """
    out_dir = Path(out_dir) if out_dir is not None else OUT_DIR
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"BENCH_{name}.json"
    doc = {
        "schema": SCHEMA,
        "name": name,
        "git_sha": git_sha,
        "timestamp": timestamp,
        "workload": workload,
        "metrics": metrics,
    }
    path.write_text(json.dumps(doc, indent=2))
    return path


def read_artifact(path: str | Path) -> dict[str, Any]:
    """Load one artifact, checking the schema tag."""
    doc = json.loads(Path(path).read_text())
    if doc.get("schema") != SCHEMA:
        raise ValueError(
            f"{path}: unknown benchmark artifact schema {doc.get('schema')!r}"
            f" (expected {SCHEMA!r})"
        )
    return doc
