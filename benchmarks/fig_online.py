"""Online vs stateless scheduling across whole traces (beyond-paper).

For each workload trace (gpt / moe / benchmark) and trace length
T ∈ {8, 32}, run the stateless per-period solve and the stateful online
controller over the same trace and compare: total trace makespan, δ paid vs
δ avoided, per-switch reuse. One CSV row per (scenario, T, backend); the
derived column reports the online/stateless total-makespan ratio — < 1
whenever the reuse credit lands on bottleneck switches.

FAST mode shrinks to (n=8, T ∈ {3, 6}) and the host backend only.
"""

from __future__ import annotations

from .common import FAST, OUT_DIR, write_csv

SCENARIOS = ("gpt", "moe", "benchmark")
PERIODS = (3, 6) if FAST else (8, 32)


def _backends():
    yield "spectra", {}
    if not FAST:
        try:
            import jax  # noqa: F401
        except Exception:
            return
        yield "spectra_jax", {}


def run():
    import time

    from repro.api import SolveOptions
    from repro.scenarios import run_scenario

    options = SolveOptions(validate=False, compute_lb=False)
    overrides = {"n": 8} if FAST else {}
    data = []
    rows_out = []
    for name in SCENARIOS:
        for T in PERIODS:
            for solver, extra in _backends():
                t0 = time.perf_counter()
                rep = run_scenario(
                    name, solver=solver, online=True, periods=T,
                    options=options, **overrides, **extra,
                )
                dt = time.perf_counter() - t0
                s = rep.online_summary()
                ratio = (
                    s["online_total_makespan"] / s["stateless_total_makespan"]
                    if s["stateless_total_makespan"]
                    else float("nan")
                )
                data.append(
                    {
                        "scenario": name,
                        "T": T,
                        "solver": solver,
                        "online_backend": s["online_solver"],
                        "stateless_total_makespan": s["stateless_total_makespan"],
                        "online_total_makespan": s["online_total_makespan"],
                        "ratio": ratio,
                        "delta_paid": s["total_delta_paid"],
                        "delta_avoided": s["total_delta_avoided"],
                        "reuse": s["total_reuse"],
                        "runtime_s": dt,
                    }
                )
                rows_out.append(
                    {
                        "name": f"fig_online_{name}_T{T}_{solver}",
                        "us_per_call": f"{1e6 * dt / max(T, 1):.0f}",
                        "derived": (
                            f"ratio={ratio:.4f};reuse={s['total_reuse']};"
                            f"d_avoided={s['total_delta_avoided']:.3f}"
                        ),
                    }
                )
    write_csv(OUT_DIR / "fig_online.csv", data)
    return rows_out
