"""Scenario & trace API: from a workload name to a scheduled report.

The paper's setting is *time-varying* traffic — the controller re-solves
scheduling every period. The scenario registry makes that a one-liner:
materialize a (T, n, n) demand trace, push it through the batched solver,
get per-period makespans / gaps / CCT back.

    PYTHONPATH=src python examples/scenario_trace.py
"""

from repro.scenarios import get_scenario, list_scenarios, make_trace, run_scenario
from repro.serve.engine import SolverService

print("registered scenarios:")
for name in list_scenarios():
    sc = get_scenario(name)
    spec = sc.spec
    print(f"  {name:16s} family={spec.family:12s} n={spec.n:<3d} T={spec.periods} "
          f"units={spec.units:6s} — {sc.description}")

# A whole training run of GPT traffic through one batched solve_many call.
print("\n=== run_scenario('gpt'): 8 periods, one batched dispatch ===")
rep = run_scenario("gpt", solver="spectra")
for p in rep.periods:
    print(f"  period {p.period}: makespan={p.makespan:.4f} "
          f"LB={p.lower_bound:.4f} gap={p.gap:.3f}x configs={p.num_configs}")
print(f"aggregate: mean={rep.makespans.mean():.4f} "
      f"geomean gap={rep.geomean_gap:.3f}x shape buckets={rep.num_shape_buckets}")

# Byte traffic: the collective_ring scenario is denominated in bytes; the
# trace is normalized fabric-globally and CCT comes back in seconds.
print("\n=== run_scenario('collective_ring'): bytes → CCT seconds ===")
rep = run_scenario("collective_ring", solver="spectra", simulate=True)
print(f"unit_s={rep.unit_s:.3e} δ_units={rep.delta_units:.3e}")
for p in rep.periods:
    print(f"  period {p.period}: CCT={p.cct_s*1e3:.2f} ms "
          f"(gap {p.gap:.3f}x, demand met: {p.demand_met})")
print(f"total CCT over the run: {rep.total_cct_s*1e3:.1f} ms")

# The serving story: a client submits a whole trace; flush drains it through
# one batched solve_many group per shape.
print("\n=== SolverService.submit_trace: a training run as tickets ===")
svc = SolverService(s=4, delta=0.01, solver="spectra")
tickets = svc.submit_trace(make_trace("moe", n=16, periods=4, tokens_per_gpu=512))
reports = svc.flush()
for t in tickets:
    print(f"  ticket {t}: makespan={reports[t].makespan:.4f}")

# Online cross-period scheduling: the controller carries each switch's
# installed permutation between periods — matching configurations serve
# δ-free (reuse credit), decompositions warm-start from the previous set.
print("\n=== run_scenario('gpt', online=True): stateful controller ===")
rep = run_scenario("gpt", solver="spectra", online=True)
for p in rep.online_periods:
    print(f"  period {p.period}: online={p.makespan:.4f} "
          f"stateless={p.stateless_makespan:.4f} reuse={p.reuse_count} "
          f"δ_avoided={p.delta_avoided:.4f} δ_paid={p.delta_paid:.4f}"
          f"{' (warm dec)' if p.warm else ''}")
o = rep.online_summary()
print(f"trace total: online={o['online_total_makespan']:.4f} vs "
      f"stateless={o['stateless_total_makespan']:.4f} "
      f"(δ avoided {o['total_delta_avoided']:.4f} over "
      f"{o['total_reuse']} switch-periods)")

# The same controller as a stateful serving session (state threads through
# SolveOptions.extra["online"] automatically).
print("\n=== SolverService.open_session: stateful serving ===")
ses = svc.open_session()
for rep_t in ses.run(make_trace("moe", n=16, periods=4, tokens_per_gpu=512)):
    print(f"  step: makespan={rep_t.makespan:.4f} "
          f"reuse={rep_t.extras['reuse_count']} warm={rep_t.extras['warm']}")
print(f"total δ avoided this session: {ses.total_delta_avoided:.4f}")
