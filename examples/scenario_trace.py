"""Scenario & trace API: from a workload name to a scheduled report.

The paper's setting is *time-varying* traffic — the controller re-solves
scheduling every period. The scenario registry makes that a one-liner:
materialize a (T, n, n) demand trace, push it through the batched solver,
get per-period makespans / gaps / CCT back.

    PYTHONPATH=src python examples/scenario_trace.py
"""

from repro.scenarios import get_scenario, list_scenarios, make_trace, run_scenario
from repro.serve.engine import SolverService

print("registered scenarios:")
for name in list_scenarios():
    sc = get_scenario(name)
    spec = sc.spec
    print(f"  {name:16s} family={spec.family:12s} n={spec.n:<3d} T={spec.periods} "
          f"units={spec.units:6s} — {sc.description}")

# A whole training run of GPT traffic through one batched solve_many call.
print("\n=== run_scenario('gpt'): 8 periods, one batched dispatch ===")
rep = run_scenario("gpt", solver="spectra")
for p in rep.periods:
    print(f"  period {p.period}: makespan={p.makespan:.4f} "
          f"LB={p.lower_bound:.4f} gap={p.gap:.3f}x configs={p.num_configs}")
print(f"aggregate: mean={rep.makespans.mean():.4f} "
      f"geomean gap={rep.geomean_gap:.3f}x shape buckets={rep.num_shape_buckets}")

# Byte traffic: the collective_ring scenario is denominated in bytes; the
# trace is normalized fabric-globally and CCT comes back in seconds.
print("\n=== run_scenario('collective_ring'): bytes → CCT seconds ===")
rep = run_scenario("collective_ring", solver="spectra", simulate=True)
print(f"unit_s={rep.unit_s:.3e} δ_units={rep.delta_units:.3e}")
for p in rep.periods:
    print(f"  period {p.period}: CCT={p.cct_s*1e3:.2f} ms "
          f"(gap {p.gap:.3f}x, demand met: {p.demand_met})")
print(f"total CCT over the run: {rep.total_cct_s*1e3:.1f} ms")

# The serving story: a client submits a whole trace; flush drains it through
# one batched solve_many group per shape.
print("\n=== SolverService.submit_trace: a training run as tickets ===")
svc = SolverService(s=4, delta=0.01, solver="spectra")
tickets = svc.submit_trace(make_trace("moe", n=16, periods=4, tokens_per_gpu=512))
reports = svc.flush()
for t in tickets:
    print(f"  ticket {t}: makespan={reports[t].makespan:.4f}")
