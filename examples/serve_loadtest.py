"""Serving control-plane load test: open-loop Poisson traffic against the
always-on ScheduleServer — double-buffered dispatch, two-tier schedule
cache, admission control, and the SLO metrics an operator would alarm on.

    PYTHONPATH=src python examples/serve_loadtest.py
"""

import numpy as np

from repro.api import SolveOptions
from repro.serve.admission import AdmissionController
from repro.serve.cache import ScheduleCache
from repro.serve.loadgen import (
    make_workload, run_open_loop, submit_all, tiny_profile,
)
from repro.serve.server import ScheduleServer

OPTS = SolveOptions(validate=False, compute_lb=False)


def new_server(**kw):
    return ScheduleServer(
        s=4, delta=0.01, mode="async", solver="spectra_jax", options=OPTS,
        cache=ScheduleCache(capacity=64), max_batch=4, **kw,
    )


# Two tenants: a phase-cycling MoE job (cache-friendly) and an ad-hoc
# tenant submitting fresh structure every period (cache-hostile).
workload = make_workload(tiny_profile(n=8, rate=50.0), duration=0.8, seed=0)
print(f"workload: {len(workload)} arrivals over 0.8s "
      f"({len({a.tenant for a in workload})} tenants)")

# Warm XLA's compile cache at every batch shape the server can dispatch
# (batch size is dynamic under open-loop arrivals), so the measured run
# shows steady-state numbers.
from repro.api.jax_backend import dispatch_many_jax  # noqa: E402

DEGRADED = SolveOptions(validate=False, compute_lb=False,
                        extra={"equalize": False})
proto = [a.D for a in workload[:4]]
for B in range(1, 5):
    for opts in (OPTS, DEGRADED):
        dispatch_many_jax(np.stack(proto[:B]), 4, 0.01, opts).collect()

# Open-loop replay: submit strictly by the arrival clock, pumping the
# server's double-buffered loop in between.
srv = new_server()
metrics = run_open_loop(srv, workload)

print(f"\nserved {metrics['schedules']} schedules "
      f"({metrics['schedules_per_sec']:.0f}/s sustained)")
print(f"cache: {metrics['cache_hit_exact']} exact + "
      f"{metrics['cache_hit_support']} support hits, "
      f"{metrics['cache_miss']} misses "
      f"→ hit rate {metrics['cache_hit_rate']:.2f}")
for stage in ("queue_wait", "device", "e2e"):
    h = metrics["stages"][stage]
    print(f"  {stage:>10}: p50 {h['p50_s'] * 1e3:7.2f}ms   "
          f"p99 {h['p99_s'] * 1e3:7.2f}ms")

# Same profile at 3x the rate through an admission controller: over-rate
# tenants degrade (no EQUALIZE pass), and a full queue sheds.
overload = make_workload(tiny_profile(n=8, rate=150.0), duration=0.5, seed=1)
srv2 = new_server(
    admission=AdmissionController(rate=30.0, burst=10.0, max_queue=8),
)
for i, a in enumerate(overload):
    srv2.submit(a.tenant, a.D, now=a.t)
    if i % 8 == 7:
        srv2.step()
srv2.drain()
m = srv2.metrics
print(f"\noverload ({len(overload)} arrivals at 3x): "
      f"{m.admitted} admitted, {m.degraded} degraded, {m.shed} shed")
degraded = [r for r in srv2.results.values() if r.degraded]
if degraded:
    mks = np.mean([r.makespan for r in degraded])
    print(f"  degraded tier served {len(degraded)} requests "
          f"(mean makespan {mks:.3f}, EQUALIZE skipped)")
