"""Quickstart: SPECTRA on the paper's worked example (Figs. 2-4).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    baseline_less,
    decompose,
    degree,
    equalize,
    lower_bound,
    schedule_lpt,
    spectra,
    spectra_pp,
)
from repro.fabric.simulator import simulate

# Fig. 2 demand matrix.
D = np.array([
    [0.60, 0.30, 0.00, 0.10],
    [0.00, 0.61, 0.39, 0.00],
    [0.00, 0.09, 0.61, 0.30],
    [0.40, 0.00, 0.00, 0.60],
])
s, delta = 2, 0.01

print("demand matrix D:\n", D)
print(f"degree(D) = {degree(D)}  →  exactly that many permutations\n")

# Step 1: DECOMPOSE (Alg. 1 + REFINE).
dec = decompose(D)
for i, (perm, a) in enumerate(zip(dec.perms, dec.alphas)):
    print(f"  P{i+1}: rows→cols {perm.tolist()}  α={a:.3f}")
print(f"  covers D: {dec.covers(D)}  total duration Σα = {dec.total_weight():.3f}\n")

# Step 2: SCHEDULE (LPT) — paper example lands at makespan 0.62.
sched = schedule_lpt(dec, s, delta)
print(f"after SCHEDULE: loads = {np.round(sched.loads(), 4).tolist()} "
      f"makespan = {sched.makespan():.4f}")

# Step 3: EQUALIZE — paper example lands at ~0.525.
sched = equalize(sched)
print(f"after EQUALIZE: loads = {np.round(sched.loads(), 4).tolist()} "
      f"makespan = {sched.makespan():.4f}\n")

# One-call pipeline + lower bound + independent event-level validation.
res = spectra(D, s, delta)
rep = simulate(res.schedule, D)
print(f"spectra():    makespan = {res.makespan:.4f}  "
      f"LB = {res.lower_bound:.4f}  gap = {res.optimality_gap:.3f}x  "
      f"(simulated: served={rep.demand_met})")

# Comparisons on this matrix.
bl = baseline_less(D, s, delta)
bl.validate(D)
pp = spectra_pp(D, s, delta)
print(f"BASELINE (LESS-style split): {bl.makespan():.4f}")
print(f"SPECTRA++ (beyond-paper):    {pp.makespan:.4f}")
print(f"lower bound:                 {lower_bound(D, s, delta):.4f}")
