"""Quickstart: SPECTRA on the paper's worked example (Figs. 2-4).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.api import Problem, list_solvers, solve
from repro.core import decompose, degree, equalize, lower_bound, schedule_lpt
from repro.fabric.simulator import simulate

# Fig. 2 demand matrix.
D = np.array([
    [0.60, 0.30, 0.00, 0.10],
    [0.00, 0.61, 0.39, 0.00],
    [0.00, 0.09, 0.61, 0.30],
    [0.40, 0.00, 0.00, 0.60],
])
s, delta = 2, 0.01

print("demand matrix D:\n", D)
print(f"degree(D) = {degree(D)}  →  exactly that many permutations\n")

# Step 1: DECOMPOSE (Alg. 1 + REFINE).
dec = decompose(D)
for i, (perm, a) in enumerate(zip(dec.perms, dec.alphas)):
    print(f"  P{i+1}: rows→cols {perm.tolist()}  α={a:.3f}")
print(f"  covers D: {dec.covers(D)}  total duration Σα = {dec.total_weight():.3f}\n")

# Step 2: SCHEDULE (LPT) — paper example lands at makespan 0.62.
sched = schedule_lpt(dec, s, delta)
print(f"after SCHEDULE: loads = {np.round(sched.loads(), 4).tolist()} "
      f"makespan = {sched.makespan():.4f}")

# Step 3: EQUALIZE — paper example lands at ~0.525.
sched = equalize(sched)
print(f"after EQUALIZE: loads = {np.round(sched.loads(), 4).tolist()} "
      f"makespan = {sched.makespan():.4f}\n")

# Unified solver API: one input shape, one output shape, every algorithm.
problem = Problem(D, s, delta)
res = solve(problem, solver="spectra")
rep = simulate(res, D)  # independent event-level validation
print(f'solve(problem, solver="spectra"): makespan = {res.makespan:.4f}  '
      f"LB = {res.lower_bound:.4f}  gap = {res.optimality_gap:.3f}x  "
      f"(simulated: served={rep.demand_met})")

# Every registered solver answers the same problem in the same shape.
print(f"\nall registered solvers on this matrix (LB = "
      f"{lower_bound(D, s, delta):.4f}):")
for name in list_solvers():
    r = solve(problem, solver=name)
    print(f"  {name:16s} [{r.backend:5s}] makespan = {r.makespan:.4f}  "
          f"configs = {r.num_configs}")
