"""Where does the makespan go? Attribution for GPT vs MoE traffic.

The headline metric — "the schedule is 1.07× above the §IV bound" — says
nothing about *why*. The obs layer answers that with the exact accounting
identity

    transmission + δ paid + idle  ≡  s · makespan

per period, the matching LB-gap decomposition (imbalance vs δ vs idle),
the per-switch occupancy timeline, and — for the online controller — the
δ the reuse credit avoided outright.

    PYTHONPATH=src python examples/attribution_report.py
"""

from repro.obs import attribute_scenario, timeline_table
from repro.scenarios import run_scenario

N, T = 32, 6


def report(name: str) -> None:
    rep = run_scenario(name, solver="spectra", n=N, periods=T)
    att = attribute_scenario(rep)
    att.check()  # the identity holds on every period or this raises
    agg = att.summary()
    print(f"\n=== {name}: n={N}, T={T}, s={rep.spec.s} ===")
    print(f"switch-time split: transmission={agg['transmission_share']:.1%} "
          f"δ={agg['delta_share']:.1%} idle={agg['idle_share']:.1%} "
          f"(identity residual ≤ {agg['max_identity_residual']:.2e})")
    print(f"LB gap {agg['total_lb_gap']:.4f} = "
          f"imbalance {agg['gap_from_transmission']:+.4f} "
          f"+ δ {agg['gap_from_delta']:.4f} "
          f"+ idle {agg['gap_from_idle']:.4f}")
    for t, table in enumerate(att.tables):
        a = table.attribution
        spread = max(r["spread"] for r in table.per_round())
        print(f"  period {t}: makespan={a.makespan:.4f} "
              f"tx={a.transmission_share:.1%} δ={a.delta_share:.1%} "
              f"idle={a.idle_share:.1%} worst round spread={spread:.4f}")

    # The time-expanded view of one period: per-switch occupancy strips.
    print(f"\nperiod 0 switch timeline ({name}):")
    print(timeline_table(rep.reports[0]).render_ascii(width=64))


for name in ("gpt", "moe"):
    report(name)

# The online controller's reuse credit shows up as δ *avoided*: switches
# whose installed permutation matches the next period's serve their first
# configuration δ-free, so the online makespan can even dip below the
# δ-inclusive §IV bound.
print("\n=== gpt, online controller: the δ-avoided credit ===")
rep = run_scenario("gpt", solver="spectra", n=N, periods=T, online=True)
att = attribute_scenario(rep)
att.check()
agg = att.summary()
print(f"stateless: δ paid={agg['delta_paid']:.4f} over {T} periods")
print(f"online:    δ paid={agg['online_delta_paid']:.4f}, "
      f"δ avoided={agg['online_delta_avoided']:.4f} "
      f"({agg['online_reuse_count']} reused switch-periods)")
print(f"online makespan total {agg['online_total_makespan']:.4f} vs "
      f"stateless {agg['total_makespan']:.4f}")
