"""End-to-end driver: train a ~100M-param GPT while SPECTRA schedules the
fabric — the paper's deployment scenario in one script.

Every --ocs-every steps the training loop emits the rack-level demand
matrix of its parallelism plan, and the SPECTRA controller schedules it on
the parallel-OCS core, logging the collective completion time (CCT).

    PYTHONPATH=src python examples/train_gpt_ocs.py              # ~100M run
    PYTHONPATH=src python examples/train_gpt_ocs.py --tiny       # smoke
"""

import argparse
import json

import jax

from repro.configs.base import ModelConfig
from repro.data.pipeline import make_stream
from repro.fabric.ocs import OCSFabric
from repro.models.registry import build_model
from repro.parallel.steps import make_train_step
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import AdamW, warmup_stable_decay


def gpt_100m() -> ModelConfig:
    # ~110M params: 12L × d768 × 12H, d_ff 3072, 32k vocab (GPT-2-small-ish).
    return ModelConfig(
        name="gpt-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=32000,
        dtype="float32",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="smoke-scale run")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ocs-every", type=int, default=20)
    args = ap.parse_args()

    cfg = gpt_100m()
    steps = args.steps or 300
    if args.tiny:
        cfg = cfg.reduced()
        steps = args.steps or 30

    model = build_model(cfg, attn_impl="chunked")
    params_count = sum(
        int(x.size) for x in jax.tree.leaves(
            jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        )
    )
    print(f"model: {cfg.name}  params ≈ {params_count/1e6:.0f}M  "
          f"steps={steps} batch={args.batch} seq={args.seq}")

    opt = AdamW(schedule=warmup_stable_decay(3e-4 if not args.tiny else 3e-3,
                                             steps))
    stream = make_stream(cfg.vocab_size, args.seq, args.batch)
    step_fn = jax.jit(make_train_step(model, opt))
    fabric = OCSFabric(num_switches=4, reconfig_delay_s=20e-6)
    loop_cfg = LoopConfig(
        total_steps=steps, log_every=max(steps // 20, 1),
        ocs_every=args.ocs_every, ocs_num_racks=8,
    )
    tr = Trainer(model, opt, stream, step_fn, loop_cfg, fabric=fabric)
    state = tr.run(jax.random.PRNGKey(0))

    print("\nloss curve (sampled):")
    for h in state.history:
        print(f"  step {h['step']:>4}  loss {h['loss']:.4f}  {h['time_s']*1e3:.0f} ms")
    print("\nOCS controller log (SPECTRA on the DP gradient ring):")
    for rec in state.cct_log[-5:]:
        print(f"  step {rec['step']:>4}  CCT {rec['cct_s']*1e3:.3f} ms  "
              f"makespan {rec['makespan']:.4f}  LB {rec['lb']:.4f}  "
              f"{rec['configs']} circuits")
    assert state.history[-1]["loss"] < state.history[0]["loss"]
    print("\nOK: loss decreased and the optical fabric schedule stayed "
          "within", f"{max(r['makespan']/max(r['lb'],1e-12) for r in state.cct_log):.2f}x",
          "of the lower bound.")


if __name__ == "__main__":
    main()
