"""MoE expert-routing traffic → SPECTRA, two ways.

1. *Measured*: run a reduced DeepSeek-style MoE for a few steps, read the
   router's per-expert token counts (the framework measures them as part
   of the train metrics), build the expert-to-expert demand matrix and
   schedule it — this mirrors how the paper's Qwen-57B MoE workload was
   collected on a real 64-GPU cluster.
2. *Paper-scale*: the synthetic 64×64 Qwen-like matrix from
   repro.traffic.workloads, swept over δ like Fig. 6(b).

    PYTHONPATH=src python examples/moe_traffic_schedule.py
"""

import jax
import numpy as np

from repro.api import Problem, solve
from repro.configs.registry import ARCHS
from repro.data.pipeline import make_stream
from repro.models.registry import build_model
from repro.parallel.steps import make_train_step
from repro.train.loop import _demand_from_stats
from repro.train.optimizer import AdamW, cosine_schedule
from repro.scenarios import make_trace, run_scenario

# ---------------------------------------------------------------- measured
print("=== measured routing from a live (reduced) MoE model ===")
cfg = ARCHS["deepseek-moe-16b"].reduced()
model = build_model(cfg, attn_impl="chunked")
opt = AdamW(schedule=cosine_schedule(1e-3, 10))
stream = make_stream(cfg.vocab_size, 64, 8)
step = jax.jit(make_train_step(model, opt))
params = model.init(jax.random.PRNGKey(0))
opt_state = opt.init(params)
for i in range(3):
    params, opt_state, metrics = step(params, opt_state, stream.next_batch(i))
load = np.asarray(metrics["expert_load"])
print(f"expert token loads (E={len(load)}): {load.astype(int).tolist()}")
D = _demand_from_stats(num_racks=8, metrics={"expert_load": load}, step=0)
D = D / D.max()
for s, delta in [(2, 0.01), (4, 0.01), (4, 0.05)]:
    p = Problem(D, s, delta)
    res = solve(p, solver="spectra")
    bl = solve(p, solver="baseline_less")
    print(f"  s={s} δ={delta}: SPECTRA {res.makespan:.4f} "
          f"(LB {res.lower_bound:.4f}, gap {res.optimality_gap:.3f}x) "
          f"BASELINE {bl.makespan:.4f} "
          f"→ {bl.makespan/res.makespan:.2f}x longer")

# ------------------------------------------------------------- paper-scale
print("\n=== paper-scale 64×64 Qwen-MoE-like matrix (Fig. 6b setting) ===")
D = make_trace("moe", periods=1).demands[0]  # scenario registry, period 0
for s in (2, 4):
    for delta in (1e-3, 1e-2, 1e-1):
        p = Problem(D, s, delta)
        res = solve(p, solver="spectra")
        bl = solve(p, solver="baseline_less")
        print(f"  s={s} δ={delta:g}: SPECTRA {res.makespan:.4f} "
              f"LB {res.lower_bound:.4f} BASELINE {bl.makespan:.4f} "
              f"({bl.makespan/res.makespan:.2f}x)")
print("\nNote how SPECTRA hugs the lower bound on dense MoE traffic — the "
      "paper's Fig. 6(b) observation.")

# ----------------------------------------------------------- whole trace
print("\n=== a whole training run: 8 controller periods of router drift ===")
rep = run_scenario("moe", solver="spectra")
print(f"periods={rep.trace.T}  mean makespan={rep.makespans.mean():.4f}  "
      f"geomean gap={rep.geomean_gap:.3f}x  buckets={rep.num_shape_buckets}")
