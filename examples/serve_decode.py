"""Batched serving demo: KV-cache decode across architecture families.

    PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import numpy as np

from repro.configs.registry import ARCHS
from repro.models.registry import build_model
from repro.serve.engine import DecodeEngine

for arch in ("granite-3-8b", "mamba2-2.7b", "zamba2-1.2b"):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = DecodeEngine(model, params, max_len=96)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 16)
    ).astype(np.int32)
    eng.generate(prompts, 2)  # warmup/compile
    t0 = time.perf_counter()
    res = eng.generate(prompts, 48)
    dt = time.perf_counter() - t0
    n = 4 * 48
    print(f"{arch:>16} (reduced): {n} tokens in {dt:.2f}s → "
          f"{n/dt:6.1f} tok/s | sample: {res.tokens[0, 16:24].tolist()}")
