"""Batched serving demo: KV-cache decode across architecture families,
plus the OCS SolverService draining scheduling requests through the
unified solver API.

    PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import numpy as np

from repro.configs.registry import ARCHS
from repro.models.registry import build_model
from repro.serve.engine import DecodeEngine, SolverService

for arch in ("granite-3-8b", "mamba2-2.7b", "zamba2-1.2b"):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = DecodeEngine(model, params, max_len=96)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 16)
    ).astype(np.int32)
    eng.generate(prompts, 2)  # warmup/compile
    t0 = time.perf_counter()
    res = eng.generate(prompts, 48)
    dt = time.perf_counter() - t0
    n = 4 * 48
    print(f"{arch:>16} (reduced): {n} tokens in {dt:.2f}s → "
          f"{n/dt:6.1f} tok/s | sample: {res.tokens[0, 16:24].tolist()}")

# While tokens stream out, the fabric controller serves scheduling requests:
# one demand matrix per pod per period, drained in batches.
from repro.traffic.workloads import moe_workload  # noqa: E402

svc = SolverService(s=4, delta=0.01, solver="spectra")
tickets = [
    svc.submit(moe_workload(rng=np.random.default_rng(seed)) / 64)
    for seed in range(3)
]
reports = svc.flush()
print("\nSolverService (one controller period, 3 pods):")
for t in tickets:
    r = reports[t]
    print(f"  pod {t}: makespan {r.makespan:.4f}  gap {r.optimality_gap:.3f}x "
          f"({r.num_configs} circuits, {r.solver}/{r.backend})")
