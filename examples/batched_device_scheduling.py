"""On-device (TPU-adapted) SPECTRA: batched auction-based decomposition.

The paper runs JV/Hungarian on a controller CPU. DESIGN.md §4 adapts the
matching step to accelerators with a batched ε-scaling auction — one device
schedules many demand matrices concurrently (e.g. per-pod matrices each
controller period). This example drains a whole stack of benchmark matrices
through ``solve_many`` on the JAX backend — ONE vmapped device call fusing
DECOMPOSE, SCHEDULE, and EQUALIZE, with host schedules materialized lazily —
and cross-checks against the exact numpy path through the same unified API.

    PYTHONPATH=src python examples/batched_device_scheduling.py
"""

import time

from repro.api import Problem, solve, solve_many
from repro.scenarios import make_trace

S, DELTA = 4, 0.01
# Four controller periods of the standard benchmark, shrunk to 32 ports:
# the scenario registry materializes the whole (T, n, n) stack at once.
mats = make_trace("benchmark", n=32, m=8, num_big=4, periods=4).demands

print("batched solve_many on the JAX backend: one fused vmapped device call "
      "(decompose + schedule + equalize), lazy host schedules:\n")
t0 = time.perf_counter()
reports = solve_many(mats, S, DELTA, solver="spectra_jax")
dt = time.perf_counter() - t0
for i, rep in enumerate(reports):
    ref = solve(Problem(mats[i], S, DELTA), solver="spectra")
    print(
        f"matrix {i}: k={rep.extras['k']} "
        f"device-LPT={rep.extras.get('device_lpt_makespan', rep.makespan):.4f} "
        f"equalized={rep.makespan:.4f} | exact-host={ref.makespan:.4f} "
        f"LB={ref.lower_bound:.4f}"
    )
print(f"\nbatch of {len(reports)} solved in {dt*1e3:.0f} ms total; the "
      "device path matches the exact host path within tie-breaks.")
