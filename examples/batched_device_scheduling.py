"""On-device (TPU-adapted) SPECTRA: batched auction-based decomposition.

The paper runs JV/Hungarian on a controller CPU. DESIGN.md §4 adapts the
matching step to accelerators with a batched ε-scaling auction — one device
schedules many demand matrices concurrently (e.g. per-pod matrices each
controller period). This example decomposes a batch of benchmark matrices
on-device, finishes with host-side EQUALIZE, and cross-checks optimality
against the exact numpy path.

    PYTHONPATH=src python examples/batched_device_scheduling.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import equalize, schedule_lpt, spectra
from repro.core.jaxopt.decompose_jax import spectra_jax, to_decomposition
from repro.traffic.workloads import benchmark_workload

S, DELTA = 4, 0.01
mats = [
    benchmark_workload(n=32, m=8, rng=np.random.default_rng(s)) for s in range(4)
]

print("on-device decompose+LPT (jit + while_loop auction), host EQUALIZE:\n")
for i, D in enumerate(mats):
    t0 = time.perf_counter()
    dec, assignment, loads, makespan_lpt = spectra_jax(
        jnp.asarray(D, jnp.float32), S, DELTA
    )
    host = to_decomposition(dec)
    sched = equalize(schedule_lpt(host, S, DELTA))
    sched.validate(D, tol=1e-4)
    dt = time.perf_counter() - t0
    ref = spectra(D, S, DELTA)
    print(
        f"matrix {i}: k={int(dec.k)} device-LPT={float(makespan_lpt):.4f} "
        f"equalized={sched.makespan():.4f} | exact-host={ref.makespan:.4f} "
        f"LB={ref.lower_bound:.4f} | {dt*1e3:.0f} ms"
    )
print("\nDevice path matches the exact host path within tie-breaks, and "
      "vmap (auction_maximize_batch) schedules whole batches per call.")
