"""On-device (TPU-adapted) SPECTRA: batched auction-based decomposition.

The paper runs JV/Hungarian on a controller CPU. DESIGN.md §4 adapts the
matching step to accelerators with batched device matchers — one device
schedules many demand matrices concurrently (e.g. per-pod matrices each
controller period). This example drains a whole stack of benchmark matrices
through ``solve_many`` on the JAX backend — ONE vmapped device call fusing
DECOMPOSE, SCHEDULE, and EQUALIZE, with host schedules materialized lazily —
and cross-checks against the exact numpy path through the same unified API,
printing the per-instance device/host quality ratio.

The device matcher is pluggable (``repro.core.jaxopt.matching.MATCHERS``):

    PYTHONPATH=src python examples/batched_device_scheduling.py             # auction
    PYTHONPATH=src python examples/batched_device_scheduling.py auction_fr  # fwd-reverse
    PYTHONPATH=src python examples/batched_device_scheduling.py auction 2   # + 2 repair sweeps
"""

import sys
import time

from repro.api import Problem, SolveOptions, solve, solve_many
from repro.core.jaxopt.matching import list_matchers
from repro.scenarios import make_trace

MATCHER = sys.argv[1] if len(sys.argv) > 1 else "auction"
REPAIR_ROUNDS = int(sys.argv[2]) if len(sys.argv) > 2 else 0
if MATCHER not in list_matchers():
    raise SystemExit(f"unknown matcher {MATCHER!r}; available: {list_matchers()}")

S, DELTA = 4, 0.01
# Four controller periods of the standard benchmark, shrunk to 32 ports:
# the scenario registry materializes the whole (T, n, n) stack at once.
mats = make_trace("benchmark", n=32, m=8, num_big=4, periods=4).demands

print(f"batched solve_many on the JAX backend (matcher={MATCHER!r}, "
      f"repair_rounds={REPAIR_ROUNDS}): one fused vmapped device call "
      "(decompose + schedule + equalize), lazy host schedules:\n")
opts = SolveOptions(extra={"matcher": MATCHER, "repair_rounds": REPAIR_ROUNDS})
t0 = time.perf_counter()
reports = solve_many(mats, S, DELTA, solver="spectra_jax", options=opts)
dt = time.perf_counter() - t0
worst = 0.0
for i, rep in enumerate(reports):
    ref = solve(Problem(mats[i], S, DELTA), solver="spectra")
    ratio = rep.makespan / ref.makespan
    worst = max(worst, ratio)
    print(
        f"matrix {i}: k={rep.extras['k']} "
        f"device-LPT={rep.extras.get('device_lpt_makespan', rep.makespan):.4f} "
        f"equalized={rep.makespan:.4f} | exact-host={ref.makespan:.4f} "
        f"LB={ref.lower_bound:.4f} quality={ratio:.4f}x"
    )
    if rep.extras["warnings"]:
        print(f"  !! {'; '.join(rep.extras['warnings'])}")
print(f"\nbatch of {len(reports)} solved in {dt*1e3:.0f} ms total; worst "
      f"device/host quality ratio {worst:.4f}x (matcher={MATCHER!r}).")
